"""Streaming-mutation subsystem tests: delta index, tombstones, the mutable
store's invalidation contract, flush/compaction mechanics, and the serving
integration (serve_open_loop(mutation_mix=)).

Fast tier: pure delta/store units on synthetic layouts. Default tier: the
merged search path over the session-scoped `base_index` Vamana fixture.
Slow tier (`-m slow`): the decay-and-repair property — overlap_ratio and
pages-per-query degrade under sustained inserts without compaction and
recover under it."""
import warnings

import numpy as np
import pytest

from repro.core.pages import build_layout, overlap_ratio
from repro.io import (LRUPageCache, PartitionedPageCache, TwoQPageCache,
                      build_store, make_placement)
from repro.mutation import (Compactor, DeltaIndex, MutableIndex,
                            MutablePageStore, MutationConfig, MutationMix)


# --------------------------------------------------------------------------
# fast: DeltaIndex


@pytest.mark.fast
def test_delta_bruteforce_matches_numpy_topk():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 8)).astype(np.float32)
    delta = DeltaIndex(8)
    for i, v in enumerate(X):
        delta.insert(100 + i, v)
    q = rng.normal(size=(3, 8)).astype(np.float32)
    ids, dists, evals = delta.search(q, k=5)
    assert evals == 20
    ref = np.sum((q[:, None, :] - X[None]) ** 2, axis=-1)
    for b in range(3):
        expect = 100 + np.argsort(ref[b], kind="stable")[:5]
        assert np.array_equal(ids[b], expect)
        assert np.allclose(dists[b], np.sort(ref[b])[:5], rtol=1e-4)


@pytest.mark.fast
def test_delta_padding_and_remove():
    delta = DeltaIndex(4)
    ids, dists, evals = delta.search(np.zeros((1, 4), np.float32), k=3)
    assert evals == 0 and (ids == -1).all() and np.isinf(dists).all()
    delta.insert(7, np.ones(4))
    delta.insert(8, 2 * np.ones(4))
    assert delta.remove(7) and not delta.remove(7)
    assert 8 in delta and 7 not in delta
    ids, dists, _ = delta.search(np.zeros((1, 4), np.float32), k=3)
    assert ids[0].tolist() == [8, -1, -1]
    vids, vecs = delta.drain()
    assert vids.tolist() == [8] and len(delta) == 0
    with pytest.raises(ValueError, match="dim"):
        delta.insert(9, np.ones(3))


# --------------------------------------------------------------------------
# fast: cache invalidation + placement growth


@pytest.mark.fast
@pytest.mark.parametrize("mk", [lambda: LRUPageCache(4),
                                lambda: TwoQPageCache(8)])
def test_cache_invalidate_forces_next_miss(mk):
    c = mk()
    c.access(3)
    c.access(3)
    assert 3 in c
    assert c.invalidate(3) is True
    assert 3 not in c
    assert c.access(3) is False          # rewritten bytes: charged re-read
    assert c.invalidate(99) is False


@pytest.mark.fast
def test_twoq_ghost_survives_invalidation():
    """Invalidation drops stale BYTES; the id-only re-use evidence stays,
    so a rewritten hot page re-enters the protected queue on its next
    touch cycle."""
    c = TwoQPageCache(8)
    for p in range(10):
        c.access(p)                      # pushes early pages into the ghost
    assert c.invalidate(9)
    assert 9 in c._ghost or 9 not in c   # resident copy gone either way
    assert 9 not in c


@pytest.mark.fast
def test_partitioned_invalidate_hits_every_tenant():
    c = PartitionedPageCache(8, 2)
    c.access(5, 0)
    c.access(5, 1)
    assert c.invalidate(5) is True
    assert 5 not in c
    assert c.access(5, 0) is False and c.access(5, 1) is False


@pytest.mark.fast
def test_placement_extend_keeps_homes_and_balances_appends():
    pl = make_placement("contiguous", 9, 3)
    grown = pl.extend(15)
    assert np.array_equal(grown.page_to_shard[:9], pl.page_to_shard)
    assert not grown.replicated[9:].any()
    counts = np.bincount(grown.page_to_shard, minlength=3)
    assert counts.max() - counts.min() <= 1   # appends fill the lightest
    with pytest.raises(ValueError, match="shrink"):
        grown.extend(9)
    assert grown.extend(15) is grown


# --------------------------------------------------------------------------
# fast: MutablePageStore


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


@pytest.mark.fast
def test_mutable_store_passthrough_mirrors_inner(tiny_layout):
    st = build_store(tiny_layout, batched=True, mutable=True)
    assert isinstance(st, MutablePageStore)
    st.fetch([0, 1, 1, 2])
    assert st.counters.as_dict() == st.inner.counters.as_dict()
    vis = np.zeros((2, tiny_layout.num_pages), bool)
    vis[0, [0, 1]] = True
    vis[1, [1, 2]] = True
    acct = st.coalesce(vis)
    assert acct["issued"] == 3
    assert st.counters.pages_fetched == st.inner.counters.pages_fetched
    assert st.savings() == st.inner.savings()      # public delegation


@pytest.mark.fast
def test_mutable_store_invalidation_evicts_warm_cache(tiny_layout):
    st = build_store(tiny_layout, batched=True, cache_policy="lru",
                     cache_bytes=8 * tiny_layout.page_bytes, mutable=True)
    trace = np.asarray([[[0, 1, -1], [2, -1, -1]]], np.int32)
    st.replay_batch(trace)
    warm = st.replay_batch(trace)
    assert warm["hits"] == 3                       # fully warm
    assert st.version_of(1) == 0
    evicted = st.invalidate([1])
    assert evicted == 1 and st.invalidations == 1
    assert st.version_of(1) == 1
    after = st.replay_batch(trace)
    assert after["hits"] == 2 and after["issued"] == 1   # 1 is a re-read
    with pytest.raises(IndexError, match="out of range"):
        st.invalidate([tiny_layout.num_pages + 5])


@pytest.mark.fast
def test_mutable_store_invalidation_reaches_shard_caches(tiny_layout):
    st = build_store(tiny_layout, batched=True, shards=2,
                     cache_policy="lru",
                     cache_bytes=8 * tiny_layout.page_bytes, mutable=True)
    trace = np.asarray([[[0, 1, -1], [2, 3, -1]]], np.int32)
    st.replay_batch(trace)
    assert st.replay_batch(trace)["hits"] == 4
    st.invalidate([0, 3])
    after = st.replay_batch(trace)
    assert after["hits"] == 2 and after["issued"] == 2


@pytest.mark.fast
def test_mutable_store_notify_append_extends_versions_and_placement(
        tiny_layout):
    st = build_store(tiny_layout, batched=True, shards=2, mutable=True)
    P = tiny_layout.num_pages
    st.notify_append(P + 4)
    assert len(st.page_version) == P + 4
    assert len(st.placement.page_to_shard) == P + 4
    st.note_write([0, 1, 2])
    assert st.counters.pages_written == 3
    assert st.counters.data_writes == 3
    # PR 8: writes forward down the spine like reads (conservation)
    assert st.inner.counters.pages_written == 3
    with pytest.raises(ValueError, match="shrink"):
        st.notify_append(P)


# --------------------------------------------------------------------------
# fast: build_store composition validation (satellite)


@pytest.mark.fast
def test_build_store_rejects_silently_ignored_knobs(tiny_layout):
    with pytest.raises(ValueError, match="cache_bytes=4096 with"):
        build_store(tiny_layout, cache_bytes=4096)
    with pytest.raises(ValueError, match="tenant_shares with tenants=1"):
        build_store(tiny_layout, cache_policy="lru",
                    cache_bytes=8 * tiny_layout.page_bytes,
                    tenant_shares=(0.5, 0.5))
    with pytest.raises(ValueError, match="rebalance_every=16 with"):
        build_store(tiny_layout, cache_policy="lru",
                    cache_bytes=8 * tiny_layout.page_bytes,
                    rebalance_every=16)
    with pytest.raises(ValueError, match="placement='contiguous' with"):
        build_store(tiny_layout, placement="contiguous")


@pytest.mark.fast
def test_mutation_config_validation():
    with pytest.raises(ValueError, match="flush_threshold"):
        MutationConfig(flush_threshold=0)
    with pytest.raises(ValueError, match="insert_alpha"):
        MutationConfig(insert_alpha=0.5)
    with pytest.raises(ValueError, match="leaves no reads"):
        MutationMix(insert_frac=0.7, delete_frac=0.4)
    with pytest.raises(ValueError, match="compaction="):
        MutationMix(insert_frac=0.1, compaction="eager")
    assert MutationMix(insert_frac=0.2, delete_frac=0.1).read_frac \
        == pytest.approx(0.7)
    assert not MutationMix().mutating


# --------------------------------------------------------------------------
# default tier: the merged search path over the Vamana fixture


@pytest.fixture(scope="module")
def mutable_index(base_index):
    return MutableIndex(base_index, MutationConfig(
        flush_threshold=16, growth_chunk=128, insert_L=16,
        compaction_pages=8))


def _fresh(base_index, **kw):
    cfg = dict(flush_threshold=16, growth_chunk=128, insert_L=16,
               compaction_pages=8)
    cfg.update(kw)
    return MutableIndex(base_index, MutationConfig(**cfg))


def test_unmutated_wrapper_is_bit_identical(base_index, small_dataset):
    """The golden facade contract extends to the wrapper: zero mutations =>
    the same bits as DiskIndex.search."""
    mi = _fresh(base_index)
    q = small_dataset.queries[:8]
    a = base_index.search(q)
    b = mi.search(q)
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)
    assert np.array_equal(a.page_reads, b.page_reads)
    assert np.array_equal(a.hops, b.hops)


def test_insert_is_searchable_before_and_after_flush(base_index,
                                                     small_dataset):
    mi = _fresh(base_index)
    rng = np.random.default_rng(3)
    v = (small_dataset.vectors[11]
         + 1e-3 * rng.normal(size=small_dataset.d)).astype(np.float32)
    vid = mi.insert(v)
    assert len(mi.delta) == 1
    res = mi.search(v[None])
    assert res.ids[0, 0] == vid              # delta merge wins the heap
    acct = mi.flush()
    assert acct["flushed"] == 1 and len(mi.delta) == 0
    assert mi.n_disk == vid + 1
    res2 = mi.search(v[None])
    assert res2.ids[0, 0] == vid             # now served from pages
    assert (mi.graph[vid] >= 0).any()        # wired into the graph


def test_delete_filters_and_backfills(base_index, small_dataset):
    mi = _fresh(base_index)
    q = small_dataset.queries[:4]
    before = mi.search(q)
    victim = int(before.ids[0, 0])
    assert mi.delete(victim)
    assert not mi.delete(victim)             # double delete is a no-op
    after = mi.search(q)
    assert victim not in after.ids[0]
    # overfetch backfilled: still k results with finite distances
    assert (after.ids[0] >= 0).all()
    assert np.isfinite(after.dists[0]).all()


def test_delete_of_delta_vid_resolves_in_memory(base_index, small_dataset):
    mi = _fresh(base_index)
    vid = mi.insert(small_dataset.vectors[0])
    assert mi.delete(vid)
    assert len(mi.delta) == 0
    assert len(mi.pending_tombstones) == 0   # never reached disk
    acct = mi.flush()
    assert acct["flushed"] == 0


def test_compaction_purges_tombstones_and_frees_pages(base_index,
                                                      small_dataset):
    mi = _fresh(base_index)
    lay = mi.layout
    # tombstone every record of two pages -> compaction must free them
    victims = np.concatenate([lay.page_vids[3], lay.page_vids[4]])
    for v in victims[victims >= 0]:
        mi.delete(int(v))
    pend = len(mi.pending_tombstones)
    assert pend > 0
    acct = mi.compact(max_pages=4)
    assert acct["purged"] == pend
    assert len(mi.pending_tombstones) == 0
    assert len(mi.free_pages) >= 1           # wholly-freed pages reclaimed
    # no live edge points at a purged vertex any more
    live_rows = mi.graph[:mi.n_disk][~mi.deleted[:mi.n_disk]]
    assert not np.isin(live_rows[live_rows >= 0],
                       victims[victims >= 0]).any()
    # purged vertices never come back
    res = mi.search(small_dataset.vectors[int(victims[0])][None])
    assert victims[0] not in res.ids[0]


def test_reverse_index_stays_consistent_through_mutations(base_index,
                                                          small_dataset):
    """The incrementally maintained reverse adjacency (what purge uses to
    find in-edges without a full-graph scan) must equal a from-scratch
    rebuild after any interleaving of flushes, deletes and compactions."""
    mi = _fresh(base_index)
    rng = np.random.default_rng(21)
    for wave in range(2):
        for i in range(20):
            a, b = rng.integers(0, small_dataset.n, 2)
            mi.insert(0.5 * (small_dataset.vectors[a]
                             + small_dataset.vectors[b]))
        for _ in range(6):
            vid = mi.random_live_vid(rng)
            if vid is not None:
                mi.delete(vid)
        mi.flush()
        mi.compact(max_pages=8)
    rebuilt = [set() for _ in range(mi.capacity)]
    src, col = np.nonzero(mi.graph >= 0)
    for u, v in zip(src.tolist(), mi.graph[src, col].tolist()):
        rebuilt[v].add(int(u))
    bad = [v for v in range(mi.capacity) if rebuilt[v] != mi._rev[v]]
    assert not bad, (bad[:5], [(rebuilt[v], mi._rev[v]) for v in bad[:2]])
    # and no live edge points at a PURGED vertex (pending tombstones may
    # still be routed through — only purge severs them)
    purged_mask = mi.deleted.copy()
    for t in mi.pending_tombstones:
        purged_mask[t] = False
    live_rows = mi.graph[:mi.n_disk][~mi.deleted[:mi.n_disk]]
    edges = live_rows[live_rows >= 0]
    assert not purged_mask[edges].any()


def test_purging_the_medoid_reelects_an_entry_point(base_index,
                                                    small_dataset):
    """Deleting the medoid keeps routing through its record; PURGING it
    would strand every search at an edgeless entry — compaction must
    re-elect a live medoid."""
    mi = _fresh(base_index)
    old = mi.medoid
    mi.delete(old)
    mid = mi.search(small_dataset.queries[:4])      # tombstone still routes
    assert (mid.ids[0] >= 0).all()
    mi.compact(max_pages=2)                         # its page is dirty
    assert old not in mi.pending_tombstones
    assert mi.medoid != old
    assert not mi.deleted[mi.medoid]
    res = mi.search(small_dataset.queries[:4])
    assert (res.ids >= 0).all()
    assert np.isfinite(res.dists).all()


def test_flush_reuses_freed_pages(base_index, small_dataset):
    mi = _fresh(base_index)
    lay = mi.layout
    for v in lay.page_vids[5]:
        if v >= 0:
            mi.delete(int(v))
    mi.compact(max_pages=1)
    assert 5 in mi.free_pages
    P = lay.num_pages
    for i in range(lay.n_p):
        mi.insert(small_dataset.vectors[i])
    mi.flush()
    assert lay.num_pages == P                # appended into the freed page
    assert (lay.page_vids[5] >= 0).any()


def test_serving_mutation_mix_reports_outcomes(base_index, small_dataset):
    from repro.serving import AnnServer, ServerConfig
    mi = _fresh(base_index)
    srv = AnnServer(mi, server_cfg=ServerConfig(max_batch=8))
    pool = small_dataset.vectors[:64].astype(np.float32)
    mix = MutationMix(insert_frac=0.25, delete_frac=0.1,
                      compaction="threshold", threshold=0.05, max_pages=8,
                      seed=5)
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                              duration_us=40000.0, mutation_mix=mix,
                              insert_pool=pool)
    assert rep.inserts > 0 and rep.deletes > 0
    assert rep.flushes >= 1
    assert rep.compactions >= 1
    assert rep.bg_pages_written > 0 and rep.bg_io_us > 0
    assert 0 < rep.bg_util < 1
    assert rep.overlap_ratio > 0
    row = rep.row()
    for col in ("inserts", "deletes", "flushes", "compactions", "bg_util",
                "overlap_ratio"):
        assert col in row
    # reads completed despite the mutation interleave
    assert rep.completed == rep.admitted > 0
    # a pure-read report keeps its columns clean
    rep0 = srv.serve_open_loop(small_dataset.queries, rate_qps=2000.0,
                               duration_us=10000.0)
    assert "inserts" not in rep0.row()


def test_serving_mutation_requires_mutable_index(base_index, small_dataset):
    from repro.serving import AnnServer, ServerConfig
    srv = AnnServer(base_index, server_cfg=ServerConfig(max_batch=8))
    with pytest.raises(ValueError, match="MutableIndex"):
        srv.serve_open_loop(small_dataset.queries, rate_qps=100.0,
                            duration_us=1000.0,
                            mutation_mix=MutationMix(insert_frac=0.5))
    mi = _fresh(base_index)
    srv2 = AnnServer(mi, server_cfg=ServerConfig(max_batch=8))
    with pytest.raises(ValueError, match="insert_pool"):
        srv2.serve_open_loop(small_dataset.queries, rate_qps=100.0,
                             duration_us=1000.0,
                             mutation_mix=MutationMix(insert_frac=0.5))


def test_replicated_placement_without_profile_warns_and_falls_back(
        base_index, small_dataset):
    """Satellite: AnnServer over placement='replicated' with no
    page_profile must not crash — it warns and serves round-robin."""
    from repro.serving import AnnServer, ServerConfig
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        srv = AnnServer(base_index,
                        server_cfg=ServerConfig(max_batch=4, shards=2,
                                                placement="replicated"))
    assert any("round-robin" in str(x.message) for x in w)
    assert not srv.store.placement.replicated.any()
    rep = srv.serve_closed_loop(small_dataset.queries[:8], workers=2)
    assert rep.queries == 2


def test_mutable_serving_matches_facade_results(base_index, small_dataset):
    """Through the server, a mutated index returns the same merged results
    the facade returns for the same queries (order of dispatch aside)."""
    from repro.serving import AnnServer, ServerConfig
    mi = _fresh(base_index)
    for i in range(20):
        mi.insert(small_dataset.vectors[i])
    mi.flush()
    mi.delete(int(mi.search(small_dataset.queries[:1]).ids[0, 0]))
    srv = AnnServer(mi, server_cfg=ServerConfig(max_batch=4))
    q = small_dataset.queries[:8]
    rep = srv.serve_closed_loop(q, workers=2, rounds=4)
    facade = mi.search(q)
    for qi, ids in zip(rep.query_indices, rep.stats.ids):
        assert np.array_equal(ids, facade.ids[qi])


# --------------------------------------------------------------------------
# slow: the decay-and-repair property (the PR's acceptance story)


@pytest.fixture(scope="module")
def shuffled_index(small_dataset, small_graph):
    """A page-shuffled index: high build-time overlap_ratio, so locality
    decay under appends is unambiguous."""
    from repro.core import build_index, get_preset
    G, med, _ = small_graph
    return build_index(small_dataset, get_preset("pageshuffle"),
                       graph=G, medoid_id=med)


@pytest.mark.slow
def test_overlap_decays_without_compaction_and_recovers_with_it(
        shuffled_index, small_dataset):
    """Sustained inserts through append flushes degrade live-vertex
    overlap_ratio monotonically with compaction=none; the same workload
    under bounded compaction lands strictly better on overlap AND purges
    the tombstone backlog."""
    n = small_dataset.n

    def drive(compaction: bool):
        rng = np.random.default_rng(9)
        mi = _fresh(shuffled_index, flush_threshold=16)
        ors = [mi.overlap_ratio()]
        for wave in range(4):
            for j in range(32):
                a, b = rng.integers(0, n, 2)
                mid = 0.5 * (small_dataset.vectors[a]
                             + small_dataset.vectors[b])
                mi.insert(mid.astype(np.float32))
                if j % 8 == 0:
                    vid = mi.random_live_vid(rng)
                    if vid is not None:
                        mi.delete(vid)
                if mi.needs_flush:
                    mi.flush()
                    if compaction:
                        while mi.dirty_fraction > 0.05:
                            mi.compact(max_pages=16)
            ors.append(mi.overlap_ratio())
        return mi, ors

    mi_none, ors_none = drive(False)
    mi_comp, ors_comp = drive(True)
    # monotone decay without repair
    assert all(b <= a for a, b in zip(ors_none, ors_none[1:])), ors_none
    assert ors_none[-1] < ors_none[0]
    # repair recovers locality and consumes the backlog
    assert ors_comp[-1] > ors_none[-1]
    assert len(mi_comp.pending_tombstones) < len(mi_none.pending_tombstones)
    assert len(mi_comp.dirty_pages) < len(mi_none.dirty_pages)
