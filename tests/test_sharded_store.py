"""Sharded-store subsystem (repro/io/sharded_store) unit tests: placement
construction/routing, trace profiles, the sharded replay/coalesce accounting
with per-shard counters and caches, the grown build_store surface, and the
device model's max-over-shards I/O term — including the acceptance check
that a maximally imbalanced placement yields strictly higher batch latency
than round-robin at equal total pages. Everything runs on tiny synthetic
layouts — no graph build — so it is all `-m fast`."""
import numpy as np
import pytest

from repro.core import SSDModel
from repro.core.pages import build_layout
from repro.io import (ArrayPageStore, BatchedPageStore, LRUPageCache,
                      PageStore, Placement, ShardedPageStore, build_store,
                      make_placement, make_shard_caches, profile_from_trace)

pytestmark = pytest.mark.fast


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


def _trace(*hop_rows, width=None):
    """(1, H, W) page_trace from per-hop page lists, -1 padded."""
    w = width or max(len(r) for r in hop_rows)
    t = np.full((1, len(hop_rows), w), -1, np.int32)
    for h, row in enumerate(hop_rows):
        t[0, h, :len(row)] = row
    return t


# --- placement policies ------------------------------------------------------


def test_round_robin_and_contiguous_placement():
    rr = make_placement("round-robin", 10, 3)
    assert rr.page_to_shard.tolist() == [0, 1, 2, 0, 1, 2, 0, 1, 2, 0]
    assert not rr.replicated.any()
    cg = make_placement("contiguous", 10, 3)
    assert cg.page_to_shard.tolist() == [0, 0, 0, 0, 1, 1, 1, 1, 2, 2]
    assert cg.describe()["pages_per_shard"] == [4, 4, 2]
    # every shard owns a page when pages >= shards
    assert set(cg.page_to_shard.tolist()) == {0, 1, 2}


def test_placement_validation():
    with pytest.raises(ValueError, match="shards=0"):
        make_placement("round-robin", 8, 0)
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("hash", 8, 2)
    with pytest.raises(ValueError, match="needs a per-page access"):
        make_placement("replicated", 8, 2)
    with pytest.raises(ValueError, match="4 entries for 8 pages"):
        make_placement("replicated", 8, 2, profile=np.ones(4, np.int64))


def test_profile_from_trace_counts_charges():
    trace = _trace([0, 1], [1, 2], [0])
    prof = profile_from_trace(trace, 5)
    assert prof.tolist() == [2, 2, 1, 0, 0]
    with pytest.raises(ValueError, match="beyond num_pages"):
        profile_from_trace(trace, 2)


def test_replicated_placement_routes_to_least_loaded():
    prof = np.array([9, 1, 0, 0], np.int64)     # page 0 is the hot one
    pl = make_placement("replicated", 4, 2, profile=prof, hot_pages=1)
    assert pl.replicated.tolist() == [True, False, False, False]
    # cold pages keep their round-robin home
    assert pl.route(1, np.array([0, 0])) == 1
    # the hot page goes wherever the load is lowest
    assert pl.route(0, np.array([5, 2])) == 1
    assert pl.route(0, np.array([1, 4])) == 0
    # pages the profile never saw are not replicated even inside the top-k
    pl2 = make_placement("replicated", 4, 2, profile=prof, hot_pages=3)
    assert pl2.replicated.sum() == 2


# --- sharded accounting: replay + coalesce ----------------------------------


def test_sharded_replay_splits_by_shard_and_conserves(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2)
    assert isinstance(store, (ShardedPageStore, PageStore))
    # pages 0,2 live on shard 0; 1,3 on shard 1 (round-robin)
    acct = store.replay_batch(_trace([0, 1], [2, 3], [0]))
    # no caches: every access is a charged read
    assert acct["requested"] == acct["issued"] == 5
    assert acct["shard_issued"].tolist() == [3, 2]
    assert acct["shard_depths"].tolist() == [1, 1]
    np.testing.assert_array_equal(acct["per_query_shard_pages"], [[3, 2]])
    # per-shard counters + roll-up + inner movement all agree
    assert [c.pages_fetched for c in store.shard_counters] == [3, 2]
    c = store.counters
    assert c.pages_requested == c.cache_hits + c.pages_fetched == 5
    assert store.inner.counters.pages_fetched == 5
    assert store.inner.inner.counters.pages_fetched == 5


def test_sharded_coalesce_unions_per_shard(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2)
    vis = np.zeros((2, tiny_layout.num_pages), bool)
    vis[0, [0, 1, 2]] = True
    vis[1, [1, 2, 3]] = True          # shares 1,2 with query 0
    acct = store.coalesce(vis)
    assert (acct["requested"], acct["issued"]) == (6, 4)
    assert acct["shard_issued"].tolist() == [2, 2]
    np.testing.assert_array_equal(acct["per_query_shard_pages"],
                                  [[2, 1], [1, 2]])
    assert acct["shard_depths"].tolist() == [2, 2]
    # the union is charged down the stack (conservation on the record-free
    # path), and the roll-up equals the per-shard sum
    assert store.counters.pages_fetched == 4
    assert store.inner.inner.counters.pages_fetched == 4
    assert sum(c.pages_fetched for c in store.shard_counters) == 4


def test_sharded_per_shard_caches_absorb_reuse(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2,
                        cache_policy="lru",
                        cache_bytes=8 * tiny_layout.page_bytes)
    assert store.caches is not None and len(store.caches) == 2
    assert all(c.capacity == 4 for c in store.caches)
    trace = _trace([0, 1], [2, 3])
    cold = store.replay_batch(trace)
    warm = store.replay_batch(trace)
    assert cold["issued"] == 4 and cold["hits"] == 0
    assert warm["issued"] == 0 and warm["hits"] == 4
    assert warm["hit_rate"] == 1.0
    assert store.hit_rate() == 0.5
    # per-shard hit accounting mirrors the split
    rows = store.shard_rows()
    assert all(r["cache_hits"] == 2 for r in rows)
    # conservation holds with hits in play
    c = store.counters
    assert c.pages_requested == c.cache_hits + c.pages_fetched
    assert store.inner.counters.pages_fetched == c.pages_fetched


def test_sharded_replay_tenant_accounting(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2)
    trace = np.concatenate([_trace([0, 1]), _trace([2, 3])])
    acct = store.replay_batch(trace, tenants=[0, 1])
    assert acct["per_tenant"][0]["issued"] == 2
    assert acct["per_tenant"][1]["issued"] == 2
    assert store.tenant_hit_rates() == {0: 0.0, 1: 0.0}
    with pytest.raises(ValueError, match="2 entries for a 1-query"):
        store.replay_batch(_trace([0]), tenants=[0, 1])
    with pytest.raises(ValueError, match=">= 0"):
        store.replay_batch(_trace([0]), tenants=[-1])


def test_sharded_fetch_path_routes_and_charges(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2,
                        cache_policy="lru",
                        cache_bytes=8 * tiny_layout.page_bytes)
    out = store.fetch([0, 1, 0])
    np.testing.assert_array_equal(out["vids"][0], tiny_layout.page_vids[0])
    assert store.counters.cache_hits == 1        # the repeated 0
    assert store.counters.pages_fetched == 2
    assert store.inner.counters.pages_fetched == 2
    assert store.shard_counters[0].cache_hits == 1


def test_sharded_replay_rejects_malformed_trace(tiny_layout):
    store = build_store(tiny_layout, batched=True, shards=2)
    with pytest.raises(ValueError, match="page_trace must be"):
        store.replay_batch(np.zeros((2, 5), np.int32))
    with pytest.raises(ValueError, match="visited_pages must be"):
        store.coalesce(np.zeros(5, bool))


# --- build_store surface -----------------------------------------------------


def test_build_store_shard_surface(tiny_layout):
    st = build_store(tiny_layout, batched=True, shards=4)
    assert isinstance(st, ShardedPageStore) and st.shards == 4
    assert isinstance(st.inner, BatchedPageStore)
    assert st.caches is None
    assert st.placement.name == "round-robin"
    one = build_store(tiny_layout, batched=True, shards=1)
    assert isinstance(one, BatchedPageStore)     # no sharding wrapper
    with pytest.raises(ValueError, match="shards=0"):
        build_store(tiny_layout, shards=0)
    # shards x prefetch and shards x tenants COMPOSE (PR 7): look-ahead
    # hops land in the owning shard's cache, tenant partitions split each
    # shard's slice
    pf = build_store(tiny_layout, batched=True, shards=2,
                     cache_policy="lru",
                     cache_bytes=8 * tiny_layout.page_bytes, prefetch=1)
    assert isinstance(pf, ShardedPageStore) and pf.lookahead == 1
    tn = build_store(tiny_layout, batched=True, shards=2,
                     cache_policy="lru",
                     cache_bytes=8 * tiny_layout.page_bytes, tenants=2)
    assert isinstance(tn, ShardedPageStore) and tn.tenant_aware
    assert tn.tenant_capacities() == [4, 4]      # 8 pages x 2 shards cells
    with pytest.raises(ValueError, match="needs a per-page access"):
        build_store(tiny_layout, shards=2, placement="replicated")


def test_make_shard_caches_splits_one_budget(tiny_layout):
    caches = make_shard_caches("lru", 7 * 256, 256, 3)
    assert [c.capacity for c in caches] == [3, 2, 2]
    assert all(isinstance(c, LRUPageCache) for c in caches)
    with pytest.raises(ValueError, match="1-page floor"):
        make_shard_caches("lru", 2 * 256, 256, 3)
    with pytest.raises(ValueError, match="unknown cache policy"):
        make_shard_caches("arc", 8 * 256, 256, 2)


def test_sharded_store_rejects_cache_count_mismatch(tiny_layout):
    pl = make_placement("round-robin", tiny_layout.num_pages, 3)
    with pytest.raises(ValueError, match="2 caches for 3 shards"):
        ShardedPageStore(ArrayPageStore(tiny_layout), pl,
                         caches=[LRUPageCache(2), LRUPageCache(2)])


# --- device model: max-over-shards I/O term ---------------------------------


def _lat_kw():
    return dict(hops=np.array([10.0]), full_evals=np.array([200.0]),
                pq_evals=np.array([900.0]), mem_evals=np.array([0.0]),
                d=96, pq_m=16, page_bytes=4096)


def test_shard_latency_is_max_over_shards():
    m = SSDModel()
    # all 8 pages on one shard == the single-device time for 8 pages
    single = m.concurrent_latency_us(4, pages=np.array([8.0]), **_lat_kw())
    sharded = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[8.0, 0.0, 0.0, 0.0]]),
        shard_depths=np.array([4, 0, 0, 0]), **_lat_kw())
    np.testing.assert_allclose(sharded, single)


def test_imbalanced_placement_strictly_slower_than_balanced():
    """Acceptance: at EQUAL total pages and equal depths, a maximally
    imbalanced split (everything on one shard) yields strictly higher
    latency than the round-robin-balanced split."""
    m = SSDModel()
    depths = np.array([4, 4, 4, 4])
    balanced = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[2.0, 2.0, 2.0, 2.0]]),
        shard_depths=depths, **_lat_kw())
    imbalanced = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[8.0, 0.0, 0.0, 0.0]]),
        shard_depths=depths, **_lat_kw())
    assert float(imbalanced[0]) > float(balanced[0])


def test_store_level_imbalance_is_visible_end_to_end(tiny_layout):
    """The same acceptance through the store: a contiguous placement with
    every traced page in one shard's range replays to strictly higher
    modeled latency than round-robin, at identical total pages."""
    m = SSDModel()
    # trace touches pages 0..5 of 16 — contiguous concentrates them 4/2/0/0
    # across 4 shards; round-robin spreads them 2/2/1/1
    trace = _trace([0, 1, 2], [3, 4, 5])
    lats = {}
    for pol in ("contiguous", "round-robin"):
        store = build_store(tiny_layout, batched=True, shards=4,
                            placement=pol)
        acct = store.replay_batch(trace)
        assert acct["issued"] == 6                 # equal total pages
        lat = m.concurrent_latency_us(
            4, pages=acct["per_query_issued"],
            shard_pages=acct["per_query_shard_pages"],
            shard_depths=acct["shard_depths"], **_lat_kw())
        lats[pol] = float(lat[0])
    assert lats["contiguous"] > lats["round-robin"]


def test_shard_latency_validation():
    m = SSDModel()
    with pytest.raises(ValueError, match="shard_pages must be"):
        m.concurrent_latency_us(4, pages=np.array([1.0]),
                                shard_pages=np.array([1.0]), **_lat_kw())
    with pytest.raises(ValueError, match="2 entries for 4 shards"):
        m.concurrent_latency_us(
            4, pages=np.array([1.0]),
            shard_pages=np.zeros((1, 4)), shard_depths=np.array([1, 1]),
            **_lat_kw())


# --- PR 7 composition + fleet store surfaces ---------------------------------


def test_shard_prefetch_composition_accounts(tiny_layout):
    """shards x prefetch: look-ahead pages land in the OWNING shard's
    cache and the conservation identity picks up the prefetch term."""
    store = build_store(tiny_layout, batched=True, shards=2,
                        cache_policy="lru",
                        cache_bytes=8 * tiny_layout.page_bytes, prefetch=1)
    acct = store.replay_batch(_trace([0, 1], [2, 3], [4, 5]))
    assert acct["prefetch_issued"] > 0
    assert 0.0 < acct["overlap_frac"] <= 1.0
    assert acct["shard_issued"].sum() == acct["issued"]
    c = store.counters
    assert c.pages_fetched == (c.pages_requested - c.cache_hits
                               + store.prefetch_issued)


def test_profile_from_counters_online_seeding(tiny_layout):
    """The online twin of profile_from_trace: live per-page read counts
    off a sharded store seed a replicated placement with no offline
    trace; non-sharded stores are rejected with a pointer to the
    offline path."""
    from repro.io import profile_from_counters
    store = build_store(tiny_layout, batched=True, shards=2)
    store.replay_batch(_trace([0, 1], [0, 2]))
    prof = profile_from_counters(store)
    assert prof.sum() == store.counters.pages_fetched
    assert prof[0] == 2 and prof[3] == 0
    # it is a copy — the live counters keep counting independently
    store.replay_batch(_trace([0]))
    assert profile_from_counters(store)[0] == 3 and prof[0] == 2
    # good enough to build the placement that needed a profile
    assert make_placement("replicated", tiny_layout.num_pages, 2,
                          profile=prof, hot_pages=1).replicated[0]
    plain = build_store(tiny_layout, batched=True)
    with pytest.raises(ValueError, match="live per-page read counts"):
        profile_from_counters(plain)


def test_set_replicated_swaps_hot_set_in_place(tiny_layout):
    """Migration's store half: the replicated mask swaps without moving
    homes, reporting exactly the promoted/demoted delta."""
    store = build_store(tiny_layout, batched=True, shards=2)
    homes = store.placement.page_to_shard.copy()
    m1 = np.zeros(tiny_layout.num_pages, bool)
    m1[[0, 1]] = True
    d1 = store.set_replicated(m1)
    assert d1["promoted"].tolist() == [0, 1]
    assert d1["demoted"].tolist() == []
    m2 = np.zeros(tiny_layout.num_pages, bool)
    m2[[1, 2]] = True
    d2 = store.set_replicated(m2)
    assert d2["promoted"].tolist() == [2]
    assert d2["demoted"].tolist() == [0]
    np.testing.assert_array_equal(store.placement.page_to_shard, homes)
    assert store.placement.replicated.sum() == 2
    with pytest.raises(ValueError, match="entries for"):
        store.set_replicated(np.ones(3, bool))


def test_replica_latency_lifts_and_maxes():
    """The fleet's (B, R, S) device grid: a single replica lifted to 3-D
    prices identically to the 2-D path, and at equal total pages an
    imbalanced replica split is strictly slower (max over replicas THEN
    shards)."""
    m = SSDModel()
    flat = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[6.0, 2.0]]),
        shard_depths=np.array([4, 4]), **_lat_kw())
    lifted = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[[6.0, 2.0]]]),
        shard_depths=np.array([[4, 4]]), **_lat_kw())
    np.testing.assert_allclose(lifted, flat)
    depths = np.array([[4], [4]])
    balanced = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[[4.0], [4.0]]]),
        shard_depths=depths, **_lat_kw())
    imbalanced = m.concurrent_latency_us(
        4, pages=np.array([8.0]),
        shard_pages=np.array([[[8.0], [0.0]]]),
        shard_depths=depths, **_lat_kw())
    assert float(imbalanced[0]) > float(balanced[0])
    with pytest.raises(ValueError, match="shard_pages must be"):
        m.concurrent_latency_us(
            4, pages=np.array([1.0]),
            shard_pages=np.zeros((1, 2, 2, 2)),
            shard_depths=np.zeros((2, 2)), **_lat_kw())
    with pytest.raises(ValueError, match="shard_depths must be"):
        m.concurrent_latency_us(
            4, pages=np.array([1.0]),
            shard_pages=np.zeros((1, 2, 2)),
            shard_depths=np.array([1, 1]), **_lat_kw())
