"""Serving layer: closed-loop AnnServer behaviour on a real (small) index.

Uses the session-scoped base_index fixture (2048-vector deep-like dataset),
so these are not `-m fast` — the graph build dominates."""
import numpy as np
import pytest

from repro.core import get_preset, recall_at_k
from repro.serving import AnnServer, ServerConfig


def _server(idx, cfg, max_batch=8, max_wait_us=200.0):
    return AnnServer(idx, cfg, server_cfg=ServerConfig(
        max_batch=max_batch, max_wait_us=max_wait_us))


def test_server_results_match_facade(base_index, small_dataset):
    """Batch padding / scheduling must not change per-query results: the
    server returns exactly what DiskIndex.search returns for each query."""
    cfg = get_preset("baseline", L=32)
    srv = _server(base_index, cfg, max_batch=8)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=5, rounds=2)
    want = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(rep.stats.ids,
                                  want.ids[rep.query_indices])
    np.testing.assert_array_equal(rep.stats.page_reads,
                                  want.page_reads[rep.query_indices])


def test_batched_store_beats_per_query_accounting(base_index, small_dataset):
    """Acceptance: on a shared-entry workload (every query starts at the
    medoid) the cross-query coalescer issues strictly fewer page reads than
    per-query accounting says were requested."""
    srv = _server(base_index, get_preset("baseline", L=32), max_batch=8)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=16, rounds=1)
    assert rep.dedup_saved_frac > 0.0
    assert rep.batched_pages_per_query < rep.pages_per_query
    c = srv.store.counters
    assert 0 < c.pages_fetched < c.pages_requested


def test_qps_monotone_nonincreasing_in_pages(base_index, small_dataset):
    """Acceptance: closed-loop QPS is monotone non-increasing in mean
    pages/query (sweep L, which drives page volume up)."""
    rows = []
    for L in (16, 32, 64):
        srv = _server(base_index, get_preset("baseline", L=L), max_batch=8)
        rep = srv.serve_closed_loop(small_dataset.queries, workers=8,
                                    rounds=2)
        rows.append((rep.pages_per_query, rep.qps))
    rows.sort(key=lambda r: r[0])
    pages = [r[0] for r in rows]
    qps = [r[1] for r in rows]
    assert pages[0] < pages[-1]                  # the sweep actually moved
    assert all(b <= a * 1.001 for a, b in zip(qps, qps[1:])), rows


def test_latency_grows_with_workers_past_knee(base_index, small_dataset):
    """Closed loop: more clients -> deeper device queues -> higher per-query
    latency, while QPS never degrades below the single-client point."""
    cfg = get_preset("baseline", L=32)
    srv = _server(base_index, cfg, max_batch=8)
    reps = [srv.serve_closed_loop(small_dataset.queries, workers=w, rounds=1)
            for w in (1, 16, 64)]
    lats = [r.mean_latency_us for r in reps]
    assert lats[0] < lats[-1], lats
    assert reps[-1].qps >= reps[0].qps


def test_server_recall_reasonable(base_index, small_dataset):
    cfg = get_preset("baseline", L=64)
    srv = _server(base_index, cfg)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=8, rounds=2)
    rec = recall_at_k(rep.stats.ids, small_dataset.gt[rep.query_indices],
                      cfg.k)
    assert rec >= 0.9, rec


def test_dynamic_batcher_respects_max_batch(base_index, small_dataset):
    srv = _server(base_index, get_preset("baseline", L=16), max_batch=4)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=16, rounds=1)
    assert rep.mean_batch_size <= 4.0
    assert rep.queries == 16
