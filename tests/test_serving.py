"""Serving layer: closed- and open-loop AnnServer behaviour on a real
(small) index — batching, stateful shared-cache policies, look-ahead
prefetch, SLO-aware dispatch, and argument validation.

Uses the session-scoped base_index fixture (2048-vector deep-like dataset),
so these are not `-m fast` (the graph build dominates) — except the pure
ServerConfig validation cases."""
import numpy as np
import pytest

from repro.core import get_preset, recall_at_k
from repro.serving import AnnServer, ServerConfig


def _server(idx, cfg, max_batch=8, max_wait_us=200.0):
    return AnnServer(idx, cfg, server_cfg=ServerConfig(
        max_batch=max_batch, max_wait_us=max_wait_us))


def test_server_results_match_facade(base_index, small_dataset):
    """Batch padding / scheduling must not change per-query results: the
    server returns exactly what DiskIndex.search returns for each query."""
    cfg = get_preset("baseline", L=32)
    srv = _server(base_index, cfg, max_batch=8)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=5, rounds=2)
    want = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(rep.stats.ids,
                                  want.ids[rep.query_indices])
    np.testing.assert_array_equal(rep.stats.page_reads,
                                  want.page_reads[rep.query_indices])


def test_batched_store_beats_per_query_accounting(base_index, small_dataset):
    """Acceptance: on a shared-entry workload (every query starts at the
    medoid) the cross-query coalescer issues strictly fewer page reads than
    per-query accounting says were requested."""
    srv = _server(base_index, get_preset("baseline", L=32), max_batch=8)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=16, rounds=1)
    assert rep.dedup_saved_frac > 0.0
    assert rep.batched_pages_per_query < rep.pages_per_query
    c = srv.store.counters
    assert 0 < c.pages_fetched < c.pages_requested


def test_qps_monotone_nonincreasing_in_pages(base_index, small_dataset):
    """Acceptance: closed-loop QPS is monotone non-increasing in mean
    pages/query (sweep L, which drives page volume up)."""
    rows = []
    for L in (16, 32, 64):
        srv = _server(base_index, get_preset("baseline", L=L), max_batch=8)
        rep = srv.serve_closed_loop(small_dataset.queries, workers=8,
                                    rounds=2)
        rows.append((rep.pages_per_query, rep.qps))
    rows.sort(key=lambda r: r[0])
    pages = [r[0] for r in rows]
    qps = [r[1] for r in rows]
    assert pages[0] < pages[-1]                  # the sweep actually moved
    assert all(b <= a * 1.001 for a, b in zip(qps, qps[1:])), rows


def test_latency_grows_with_workers_past_knee(base_index, small_dataset):
    """Closed loop: more clients -> deeper device queues -> higher per-query
    latency, while QPS never degrades below the single-client point."""
    cfg = get_preset("baseline", L=32)
    srv = _server(base_index, cfg, max_batch=8)
    reps = [srv.serve_closed_loop(small_dataset.queries, workers=w, rounds=1)
            for w in (1, 16, 64)]
    lats = [r.mean_latency_us for r in reps]
    assert lats[0] < lats[-1], lats
    assert reps[-1].qps >= reps[0].qps


def test_server_recall_reasonable(base_index, small_dataset):
    cfg = get_preset("baseline", L=64)
    srv = _server(base_index, cfg)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=8, rounds=2)
    rec = recall_at_k(rep.stats.ids, small_dataset.gt[rep.query_indices],
                      cfg.k)
    assert rec >= 0.9, rec


def test_dynamic_batcher_respects_max_batch(base_index, small_dataset):
    srv = _server(base_index, get_preset("baseline", L=16), max_batch=4)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=16, rounds=1)
    assert rep.mean_batch_size <= 4.0
    assert rep.queries == 16


# --- closed-loop edge cases + argument validation (satellites) -------------


def test_closed_loop_more_workers_than_queries(base_index, small_dataset):
    """Clients beyond the query pool wrap around round-robin; every one of
    workers x rounds submissions completes."""
    nq = len(small_dataset.queries)
    srv = _server(base_index, get_preset("baseline", L=16), max_batch=8)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=nq + 8,
                                rounds=1)
    assert rep.queries == nq + 8
    assert len(rep.stats) == nq + 8
    assert rep.query_indices.max() < nq


def test_closed_loop_zero_max_wait(base_index, small_dataset):
    """max_wait_us=0 still batches simultaneous submissions (all clients
    submit at t=0) and completes the full workload."""
    srv = _server(base_index, get_preset("baseline", L=16), max_batch=4,
                  max_wait_us=0.0)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=8, rounds=2)
    assert rep.queries == 16
    assert rep.mean_batch_size <= 4.0
    assert rep.qps > 0


def test_closed_loop_rejects_bad_workers_and_rounds(base_index,
                                                    small_dataset):
    srv = _server(base_index, get_preset("baseline", L=16))
    with pytest.raises(ValueError, match="workers=0"):
        srv.serve_closed_loop(small_dataset.queries, workers=0)
    with pytest.raises(ValueError, match="workers=-3"):
        srv.serve_closed_loop(small_dataset.queries, workers=-3)
    with pytest.raises(ValueError, match="rounds=0"):
        srv.serve_closed_loop(small_dataset.queries, workers=2, rounds=0)


@pytest.mark.fast
@pytest.mark.parametrize("kw,msg", [
    (dict(max_batch=0), "max_batch=0"),
    (dict(max_wait_us=-1.0), "max_wait_us=-1.0"),
    (dict(cache_policy="lru"), "cache_bytes"),
    (dict(cache_bytes=1 << 20), "with cache_policy='none'"),
    (dict(cache_policy="lru", cache_bytes=1 << 20,
          cache_rebalance_every=8), "no partitions to rebalance"),
    (dict(cache_policy="arc", cache_bytes=1 << 20), "cache_policy='arc'"),
    (dict(prefetch=-1), "prefetch=-1"),
    (dict(prefetch=1), "prefetch needs a cache_policy"),
    (dict(slo_p99_us=0.0), "slo_p99_us=0.0"),
    (dict(shards=0), "shards=0"),
    (dict(placement="hash"), "placement='hash'"),
    (dict(placement="contiguous"), "with shards=1 places nothing"),
    (dict(placement_hot_frac=0.0), "placement_hot_frac=0.0"),
])
def test_server_config_rejects_invalid(kw, msg):
    with pytest.raises(ValueError, match=msg):
        ServerConfig(**kw)


# --- stateful cache serving + open loop (tentpole) -------------------------


def _cached_server(idx, cfg, policy="lru", pages=512, prefetch=0,
                   max_batch=8, slo_p99_us=None):
    return AnnServer(idx, cfg, server_cfg=ServerConfig(
        max_batch=max_batch, cache_policy=policy,
        cache_bytes=pages * idx.layout.page_bytes, prefetch=prefetch,
        slo_p99_us=slo_p99_us))


def test_page_trace_matches_visited_bitmap(base_index, small_dataset):
    """The temporally ordered trace and the order-free bitmap are two views
    of the same charges: same page sets, same per-query counts."""
    from repro.core.search_kernel import search_batched
    from repro.io import build_store
    store = build_store(base_index.layout, batched=True)
    cfg = get_preset("baseline", L=32)
    st = search_batched(store, base_index.pq, cfg, small_dataset.queries,
                        medoid=base_index.medoid, collect_visited=True,
                        collect_trace=True, account_kernel_io=False)
    assert st.page_trace.shape[0] == len(small_dataset.queries)
    for b in range(len(st)):
        tr = st.page_trace[b]
        charged = tr[tr >= 0]
        assert len(charged) == int(st.page_reads[b])
        assert (set(charged.tolist())
                == set(np.flatnonzero(st.visited_pages[b]).tolist()))


def test_warm_shared_lru_cache_beats_batched_baseline(base_index,
                                                      small_dataset):
    """Acceptance: a SharedCachePageStore with an LRU policy and a warm
    cache strictly reduces pages_fetched vs. the batch-coalescing baseline
    on the same workload."""
    cfg = get_preset("baseline", L=32)
    workload = dict(workers=16, rounds=1)

    base_srv = _server(base_index, cfg, max_batch=8)
    base_srv.serve_closed_loop(small_dataset.queries, **workload)
    baseline_fetched = base_srv.store.counters.pages_fetched

    cached_srv = _cached_server(base_index, cfg,
                                pages=base_index.layout.num_pages)
    cached_srv.serve_closed_loop(small_dataset.queries, **workload)  # warm-up
    warm0 = cached_srv.store.counters.pages_fetched
    rep = cached_srv.serve_closed_loop(small_dataset.queries, **workload)
    warm_fetched = cached_srv.store.counters.pages_fetched - warm0

    assert 0 <= warm_fetched < baseline_fetched
    assert rep.cache_hit_rate > 0.9
    # the cache must not change what the queries return
    want = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(rep.stats.ids, want.ids[rep.query_indices])


def test_cache_policies_state_persists_across_batches(base_index,
                                                      small_dataset):
    """Within one closed-loop run the shared cache spans batch boundaries:
    with more total queries than max_batch, later batches hit on pages
    fetched by earlier ones, so issued pages undercut the per-batch union
    accounting of the plain batched store."""
    cfg = get_preset("baseline", L=32)
    plain = _server(base_index, cfg, max_batch=4)
    rep_plain = plain.serve_closed_loop(small_dataset.queries, workers=16,
                                        rounds=2)
    srv = _cached_server(base_index, cfg, max_batch=4,
                         pages=base_index.layout.num_pages)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=16, rounds=2)
    assert rep.cache_hit_rate > 0.0
    assert rep.batched_pages_per_query < rep_plain.batched_pages_per_query
    assert srv.store.counters.cache_hits > 0


def test_open_loop_reports_and_determinism(base_index, small_dataset):
    cfg = get_preset("baseline", L=16)
    srv = _cached_server(base_index, cfg, policy="lru", pages=256)
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                              duration_us=10000.0, seed=7)
    assert rep.offered == rep.completed == len(rep.stats)
    assert rep.elapsed_us > 0 and rep.qps > 0
    assert rep.p99_latency_us >= rep.mean_latency_us
    assert 0.0 <= rep.cache_hit_rate <= 1.0
    row = rep.row()
    assert {"rate_qps", "qps", "p99_latency_us",
            "cache_hit_rate"} <= set(row)
    # same seed -> same arrival process -> same report
    srv2 = _cached_server(base_index, cfg, policy="lru", pages=256)
    rep2 = srv2.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                                duration_us=10000.0, seed=7)
    assert rep2.offered == rep.offered
    np.testing.assert_allclose(rep2.mean_latency_us, rep.mean_latency_us)


def test_open_loop_latency_grows_with_offered_rate(base_index,
                                                   small_dataset):
    """Open loop past saturation: a higher offered rate can only deepen the
    backlog, so mean latency is non-decreasing in arrival rate."""
    cfg = get_preset("baseline", L=16)
    lats = []
    for rate in (1000.0, 64000.0):
        srv = _server(base_index, cfg, max_batch=8)
        rep = srv.serve_open_loop(small_dataset.queries, rate_qps=rate,
                                  duration_us=10000.0, seed=3)
        lats.append(rep.mean_latency_us)
    assert lats[1] >= lats[0], lats


def test_open_loop_slo_batcher_dispatches_early(base_index, small_dataset):
    """With a tight SLO the batcher trades batch size for tail latency:
    batches get smaller and p99 must not get worse."""
    cfg = get_preset("baseline", L=16)
    kw = dict(rate_qps=2000.0, duration_us=20000.0, seed=5)
    relaxed = _server(base_index, cfg, max_batch=16, max_wait_us=5000.0)
    rep_rel = relaxed.serve_open_loop(small_dataset.queries, **kw)
    tight = AnnServer(base_index, cfg, server_cfg=ServerConfig(
        max_batch=16, max_wait_us=5000.0, slo_p99_us=1500.0))
    rep_slo = tight.serve_open_loop(small_dataset.queries, **kw)
    assert rep_slo.mean_batch_size <= rep_rel.mean_batch_size
    assert rep_slo.p99_latency_us <= rep_rel.p99_latency_us * 1.001
    assert rep_slo.slo_p99_us == 1500.0


def test_open_loop_prefetch_overlap_cuts_latency(base_index, small_dataset):
    """LAANN-style look-ahead: same device reads, part of their service
    hidden behind compute -> mean latency no worse than the pure cache."""
    cfg = get_preset("baseline", L=16)
    kw = dict(rate_qps=4000.0, duration_us=10000.0, seed=11)
    pure = _cached_server(base_index, cfg, pages=256)
    rep_pure = pure.serve_open_loop(small_dataset.queries, **kw)
    pf = _cached_server(base_index, cfg, pages=256, prefetch=2)
    rep_pf = pf.serve_open_loop(small_dataset.queries, **kw)
    assert rep_pf.overlap_frac > 0.0 == rep_pure.overlap_frac
    assert rep_pf.mean_latency_us <= rep_pure.mean_latency_us * 1.001
    assert rep_pf.offered == rep_pure.offered


@pytest.mark.fast
def test_serving_report_row_carries_overlap_tenant_and_shard_columns():
    """Doc/report satellite: row() used to drop overlap_frac and the whole
    per-tenant dict on the way into print_table."""
    from repro.core import QueryStats
    zi = np.zeros(0, np.int64)
    zf = np.zeros(0, np.float64)
    stats = QueryStats(ids=np.zeros((0, 10), np.int64),
                       dists=np.zeros((0, 10)), hops=zi, page_reads=zf,
                       cache_hits=zf, n_read_records=zf, n_eff=zf,
                       full_evals=zf, pq_evals=zf, mem_hops=zi, mem_evals=zi)
    from repro.serving import ServingReport
    rep = ServingReport(
        workers=2, queries=4, elapsed_us=100.0, qps=1.0,
        mean_latency_us=1.0, p99_latency_us=2.0, mean_service_us=1.0,
        mean_batch_size=2.0, pages_per_query=3.0,
        batched_pages_per_query=2.0, dedup_saved_frac=0.5, stats=stats,
        query_indices=zi, overlap_frac=0.25,
        per_tenant={0: {"completed": 3, "p99_latency_us": 9.0,
                        "cache_hit_rate": 0.5},
                    1: {"completed": 1, "shed": 2}},
        per_shard={0: {"issued": 30, "utilization": 0.4},
                   1: {"issued": 10, "utilization": 0.1}})
    row = rep.row()
    assert row["overlap_frac"] == 0.25
    assert row["t0_completed"] == 3 and row["t0_cache_hit_rate"] == 0.5
    assert row["t1_shed"] == 2
    assert row["shards"] == 2
    assert row["shard_imbalance"] == pytest.approx(30 / 20)
    assert row["max_shard_util"] == 0.4


# --- sharded serving (tentpole) --------------------------------------------


def _sharded_server(idx, cfg, shards, placement="round-robin", policy="none",
                    pages=0, max_batch=8, page_profile=None):
    return AnnServer(idx, cfg, server_cfg=ServerConfig(
        max_batch=max_batch, shards=shards, placement=placement,
        cache_policy=policy,
        cache_bytes=pages * idx.layout.page_bytes), page_profile=page_profile)


def test_sharded_server_results_match_facade(base_index, small_dataset):
    """Sharding only changes WHERE reads are charged, never what a query
    returns: the golden facade contract holds through the sharded store."""
    cfg = get_preset("baseline", L=32)
    srv = _sharded_server(base_index, cfg, shards=4)
    rep = srv.serve_closed_loop(small_dataset.queries, workers=8, rounds=2)
    want = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(rep.stats.ids,
                                  want.ids[rep.query_indices])
    np.testing.assert_array_equal(rep.stats.page_reads,
                                  want.page_reads[rep.query_indices])


def test_sharded_latency_improves_with_shards(base_index, small_dataset):
    """More devices -> each query's pages split across parallel shards ->
    mean service latency strictly improves 1 -> 4 shards, and the per-shard
    report carries the split."""
    cfg = get_preset("baseline", L=32)
    lats, reps = [], {}
    for shards in (1, 2, 4):
        srv = _sharded_server(base_index, cfg, shards=shards)
        rep = srv.serve_closed_loop(small_dataset.queries, workers=8,
                                    rounds=1)
        lats.append(rep.mean_latency_us)
        reps[shards] = rep
    assert lats[0] > lats[1] > lats[2], lats
    assert reps[1].per_shard is None
    per = reps[4].per_shard
    assert set(per) == {0, 1, 2, 3}
    assert sum(r["load_frac"] for r in per.values()) == pytest.approx(1.0)
    row = reps[4].row()
    assert row["shards"] == 4 and row["shard_imbalance"] >= 1.0
    assert "overlap_frac" in row


def test_sharded_open_loop_with_per_shard_caches(base_index, small_dataset):
    """Sharding composes with the stateful cache subsystem: per-shard LRU
    slices of one budget produce hits, per-shard hit rates, and the same
    query results."""
    cfg = get_preset("baseline", L=16)
    srv = _sharded_server(base_index, cfg, shards=4, policy="lru",
                          pages=base_index.layout.num_pages)
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                              duration_us=10000.0, seed=7)
    assert rep.completed == rep.offered
    assert rep.cache_hit_rate > 0.0
    assert rep.per_shard is not None
    assert any(r["hit_rate"] > 0 for r in rep.per_shard.values())
    want = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(rep.stats.ids, want.ids[rep.query_indices])


def test_replicated_placement_balances_skewed_load(base_index,
                                                   small_dataset):
    """A skewed pool (few hot queries dominating) under 4 shards: the
    replicated hot set routes hot pages to the least-loaded device, so the
    issued-read imbalance is no worse than round-robin's and latency does
    not regress."""
    from repro.core.search_kernel import search_batched
    from repro.io import build_store, profile_from_trace
    cfg = get_preset("baseline", L=32)
    pool = np.concatenate([np.tile(small_dataset.queries[:4], (8, 1)),
                           small_dataset.queries])
    store = build_store(base_index.layout, batched=True)
    st = search_batched(store, base_index.pq, cfg, pool,
                        medoid=base_index.medoid,
                        memgraph=base_index.memgraph, collect_trace=True,
                        account_kernel_io=False)
    prof = profile_from_trace(st.page_trace, base_index.layout.num_pages)
    kw = dict(rate_qps=8000.0, duration_us=20000.0, seed=3)
    rr = _sharded_server(base_index, cfg, shards=4).serve_open_loop(
        pool, **kw)
    rep = _sharded_server(base_index, cfg, shards=4, placement="replicated",
                          page_profile=prof).serve_open_loop(pool, **kw)
    assert rep.row()["shard_imbalance"] <= rr.row()["shard_imbalance"]
    assert rep.mean_latency_us <= rr.mean_latency_us * 1.001


def test_open_loop_validates_arguments(base_index, small_dataset):
    srv = _server(base_index, get_preset("baseline", L=16))
    with pytest.raises(ValueError, match="rate_qps=0"):
        srv.serve_open_loop(small_dataset.queries, rate_qps=0,
                            duration_us=1000.0)
    with pytest.raises(ValueError, match="duration_us=-5"):
        srv.serve_open_loop(small_dataset.queries, rate_qps=100.0,
                            duration_us=-5)
