"""Observability: metrics primitives, the span tracer, Chrome trace
export, and the latency-attribution conservation contract on the
serving loops.

The load-bearing claims: (1) histogram p50/p99 agree with the order
statistic ``np.percentile(..., method="higher")`` within the documented
``error_bound``; (2) a traced open-loop run's per-query spans sum back
to the reported latency exactly, and per-shard device spans reproduce
the shard window's busy time; (3) the exported Chrome trace validates
(well-formed, async spans balanced, flows resolve); (4) tracing off is
invisible — identical reports, zero recorded state.
"""
import numpy as np
import pytest

from repro import sanitize
from repro.core import get_preset
from repro.obs import (CONSERVATION_TOL_US, Counter, Gauge, Histogram,
                       MetricsRegistry, Tracer, validate_chrome_trace)
from repro.serving.ann_server import (AnnServer, ServerConfig,
                                      _latency_summary)
from repro.serving.fleet import FleetConfig, FleetServer


# --- metrics ----------------------------------------------------------------


def test_histogram_percentiles_within_documented_bound():
    gen = np.random.default_rng(11)
    vals = np.exp(gen.normal(5.0, 1.5, size=20_000)) + 1.0
    h = Histogram.from_values(vals, name="lat")
    assert h.count == 20_000
    assert np.isclose(h.mean, vals.mean())
    for q in (0.5, 0.9, 0.99):
        # the histogram prices the order statistic at ceil(q * (n-1)) —
        # np.percentile's "higher" method — within sqrt(growth) - 1
        exact = float(np.percentile(vals, q * 100, method="higher"))
        assert abs(h.quantile(q) - exact) / exact <= h.error_bound


def test_histogram_empty_and_rejects_bad_samples():
    h = Histogram(name="empty")
    assert np.isnan(h.quantile(0.99))
    assert h.quantile(0.99, default=0.0) == 0.0
    assert np.isnan(h.mean) and np.isnan(h.min) and np.isnan(h.max)
    with pytest.raises(ValueError):
        h.observe(-1.0)
    with pytest.raises(ValueError):
        h.observe(float("nan"))
    with pytest.raises(ValueError):
        Histogram(growth=1.0)


def test_histogram_merge_and_registry_contracts():
    a = Histogram.from_values([1.0, 2.0, 3.0])
    b = Histogram.from_values([10.0, 20.0])
    a.merge(b)
    assert a.count == 5 and a.max == 20.0 and a.min == 1.0
    reg = MetricsRegistry()
    reg.counter("n").inc(3)
    reg.gauge("depth").set(2.5)
    reg.histogram("lat").observe(7.0)
    assert isinstance(reg.counter("n"), Counter)
    assert isinstance(reg.gauge("depth"), Gauge)
    assert reg.counter("n").value == 3
    with pytest.raises(TypeError):
        reg.gauge("n")            # name already taken by a Counter
    with pytest.raises(ValueError):
        reg.counter("n").inc(-1)  # counters are monotone
    assert reg.names() == ["depth", "lat", "n"]
    assert set(reg.as_dict()) == {"n", "depth", "lat"}


def test_latency_summary_empty_is_finite_and_schema_stable():
    """The zero-admitted report path prices its latency columns off an
    empty histogram: finite 0.0s, never NaN, never np.percentile on []."""
    _, mean, p50, p99 = _latency_summary(np.zeros(0))
    assert (mean, p50, p99) == (0.0, 0.0, 0.0)


# --- tracer -----------------------------------------------------------------


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.span("x", "batch", 0.0, 5.0)
    tr.instant("y", "admission", 1.0)
    assert not tr and len(tr) == 0 and tr.spans == []


# --- traced open loop: conservation + device-time agreement -----------------


@pytest.fixture(scope="module")
def traced_open(base_index, small_dataset):
    cfg = get_preset("baseline", L=16)
    srv = AnnServer(base_index, cfg,
                    server_cfg=ServerConfig(max_batch=8, shards=2))
    tracer = Tracer()
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                              duration_us=20_000.0, seed=7, tracer=tracer)
    return srv, tracer, rep


def test_open_loop_attribution_conserves_latency(traced_open):
    _, tracer, rep = traced_open
    at = rep.attribution
    assert rep.completed > 0 and at is not None
    resid = np.abs(at["queue_us"] + at["service_us"]
                   + at["interference_us"] - at["latency_us"])
    assert float(resid.max()) <= CONSERVATION_TOL_US
    assert float(at["queue_us"].min()) >= 0.0
    assert float(at["interference_us"].min()) >= 0.0
    assert np.isclose(rep.mean_queue_us, at["queue_us"].mean())
    assert np.isclose(rep.mean_service_us, at["service_us"].mean())
    # the same contract holds span-side, per query, inside the trace
    s = tracer.summary()
    assert s.queries == rep.completed
    assert s.max_residual_us <= CONSERVATION_TOL_US
    svc = [sp for sp in tracer.spans if sp.cat == "service"]
    assert len(svc) == rep.completed
    assert np.isclose(sum(sp.dur_us for sp in svc),
                      float(at["service_us"].sum()))


def test_open_loop_device_spans_match_shard_windows(traced_open):
    """Summing the per-shard device spans reproduces the shard windows'
    busy time (issued reads x the model's read unit) — the trace and the
    per_shard utilization column are the same accounting."""
    srv, tracer, rep = traced_open
    rd_us = srv.model.read_service_us(srv.cfg.page_bytes)
    assert rep.per_shard is not None and len(rep.per_shard) == 2
    for s, row in rep.per_shard.items():
        span_sum = sum(sp.dur_us for sp in tracer.spans
                       if sp.cat == "device" and sp.track == f"shard{s}")
        assert np.isclose(span_sum, row["issued"] * rd_us, rtol=1e-9)


def test_open_loop_trace_exports_valid_chrome_json(traced_open):
    _, tracer, rep = traced_open
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    # flows: one s/t/f triple per completed query
    for ph in ("s", "t", "f"):
        assert sum(e["ph"] == ph for e in evs) == rep.completed
    # per-hop markers rode along (collect_trace forced by the tracer)
    assert any(e.get("cat") == "hop" for e in evs)


def test_open_loop_tracing_is_invisible_to_results(base_index,
                                                   small_dataset,
                                                   traced_open):
    _, _, rep = traced_open
    cfg = get_preset("baseline", L=16)
    srv = AnnServer(base_index, cfg,
                    server_cfg=ServerConfig(max_batch=8, shards=2))
    plain = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                                duration_us=20_000.0, seed=7)
    assert plain.completed == rep.completed
    assert np.array_equal(plain.attribution["latency_us"],
                          rep.attribution["latency_us"])
    assert plain.p50_latency_us == rep.p50_latency_us
    assert plain.p99_latency_us == rep.p99_latency_us


def test_sanitizer_checks_attribution_when_armed(traced_open):
    _, _, rep = traced_open
    at = rep.attribution
    prev = sanitize.set_enabled(True)
    try:
        sanitize.check_attribution(at["queue_us"], at["service_us"],
                                   at["interference_us"],
                                   at["latency_us"])
        bad = at["latency_us"].copy()
        bad[0] += 1.0             # one unattributed microsecond
        with pytest.raises(sanitize.SanitizeError):
            sanitize.check_attribution(at["queue_us"], at["service_us"],
                                       at["interference_us"], bad)
    finally:
        sanitize.set_enabled(prev)
    # disarmed: the same broken input is a no-op (zero-cost path)
    sanitize.check_attribution(at["queue_us"], at["service_us"],
                               at["interference_us"], bad)


# --- traced fleet -----------------------------------------------------------


def test_fleet_traced_run_conserves_and_validates(base_index,
                                                  small_dataset):
    cfg = get_preset("baseline", L=16)
    srv = FleetServer(base_index, cfg,
                      server_cfg=ServerConfig(max_batch=8),
                      fleet_cfg=FleetConfig(replica_groups=2))
    tracer = Tracer()
    prev = sanitize.set_enabled(True)   # conservation checked live
    try:
        rep = srv.serve_fleet(small_dataset.queries, rate_qps=6000.0,
                              duration_us=15_000.0, seed=5,
                              tracer=tracer)
    finally:
        sanitize.set_enabled(prev)
    assert rep.completed > 0
    at = rep.attribution
    resid = np.abs(at["queue_us"] + at["service_us"]
                   + at["interference_us"] - at["latency_us"])
    assert float(resid.max()) <= CONSERVATION_TOL_US
    assert validate_chrome_trace(tracer.to_chrome()) == []
    # spans landed on both replica groups' lanes
    assert {sp.pid for sp in tracer.spans if sp.cat == "batch"} == {0, 1}
