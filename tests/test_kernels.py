"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.page_scan import page_scan
from repro.kernels.pq_adc import pq_adc
from repro.kernels.ref import page_scan_ref, pq_adc_ref


@pytest.mark.parametrize("n_pages,n_p,d,w,q", [
    (16, 8, 128, 4, 1),
    (64, 8, 128, 8, 4),
    (32, 16, 256, 6, 8),
    (8, 8, 512, 3, 2),
    (128, 8, 128, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_page_scan_sweep(n_pages, n_p, d, w, q, dtype):
    rng = np.random.default_rng(n_pages + d)
    pages = jnp.asarray(rng.normal(size=(n_pages, n_p, d)), dtype)
    ids = jnp.asarray(rng.integers(0, n_pages, w).astype(np.int32))
    qs = jnp.asarray(rng.normal(size=(q, d)), dtype)
    out = page_scan(pages, ids, qs, interpret=True)
    ref = page_scan_ref(pages, ids, qs)
    tol = 1e-5 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * d)


def test_page_scan_duplicate_and_oob_ids():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.normal(size=(8, 8, 128)).astype(np.float32))
    ids = jnp.asarray(np.array([3, 3, 0, 7], np.int32))
    qs = jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))
    out = page_scan(pages, ids, qs, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6)


@pytest.mark.parametrize("n,m,block", [
    (100, 8, 64), (512, 16, 128), (1000, 16, 512), (4096, 32, 512),
    (7, 16, 8),
])
def test_pq_adc_sweep(n, m, block):
    rng = np.random.default_rng(n + m)
    codes = jnp.asarray(rng.integers(0, 256, (n, m)).astype(np.uint8))
    lut = jnp.asarray((rng.normal(size=(m, 256)) ** 2).astype(np.float32))
    out = pq_adc(codes, lut, block_n=block, interpret=True)
    ref = pq_adc_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_pq_adc_matches_engine_semantics():
    """Kernel ADC == the engine's in-search pq_dist == PQ.adc."""
    from repro.core.pq import train_pq
    rng = np.random.default_rng(3)
    x = rng.normal(size=(512, 64)).astype(np.float32)
    pq = train_pq(x, m=8, sample=512, iters=4)
    q = rng.normal(size=(64,)).astype(np.float32)
    lut = pq.lut(q)
    ids = np.arange(100)
    want = pq.adc(q, ids)
    got = np.asarray(pq_adc(jnp.asarray(pq.codes[ids]), jnp.asarray(lut),
                            block_n=32, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4)
