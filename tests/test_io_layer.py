"""I/O layer (repro/io) + kernel-stats (core/stats) unit tests: PageStore
fetch/counter semantics, cross-query dedup in BatchedPageStore, QueryStats
aggregation equivalence with the old SearchResult plumbing, SearchConfig
validation, and the deduplicated SSDModel rate helpers. Everything here runs
on tiny synthetic layouts — no graph build — so it is all `-m fast`."""
import numpy as np
import pytest

from repro.core import QueryStats, SearchConfig, SearchResult, SSDModel
from repro.core.pages import build_layout
from repro.io import (ArrayPageStore, BatchedPageStore, CachedPageStore,
                      PageStore, build_store)

pytestmark = pytest.mark.fast


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


# --- PageStore fetch / counter semantics -----------------------------------


def test_array_store_fetch_and_counters(tiny_layout):
    store = ArrayPageStore(tiny_layout)
    assert isinstance(store, PageStore)
    out = store.fetch([0, 1, 1])
    assert out["vids"].shape == (3, tiny_layout.n_p)
    np.testing.assert_array_equal(out["vids"][1], out["vids"][2])
    np.testing.assert_allclose(out["vecs"][0], tiny_layout.page_vecs[0])
    # base store charges every requested page (no dedup at this level)
    assert store.counters.pages_requested == 3
    assert store.counters.pages_fetched == 3
    assert store.counters.records_fetched == 3 * tiny_layout.n_p
    store.counters.reset()
    assert store.counters.pages_fetched == 0
    with pytest.raises(IndexError):
        store.fetch([tiny_layout.num_pages])


def test_cached_store_serves_hits_from_memory(tiny_layout):
    inner = ArrayPageStore(tiny_layout)
    n = tiny_layout.vid2page.shape[0]
    cached = np.zeros(n, bool)
    cached[:8] = True
    store = CachedPageStore(inner, cached)
    vids = np.asarray([2, 40, 50])        # vid 2 cached, others not
    pages = tiny_layout.vid2page[vids]
    out = store.fetch(pages, vids=vids)
    assert store.counters.pages_requested == 3
    assert store.counters.cache_hits == 1
    assert store.counters.pages_fetched == 2
    assert inner.counters.pages_fetched == 2   # only misses reach the device
    # the cached record is returned from memory, contents intact
    assert out["cached_vids"].tolist() == [2]
    np.testing.assert_allclose(
        out["cached_vecs"][0],
        tiny_layout.page_vecs[tiny_layout.vid2page[2],
                              tiny_layout.vid2slot[2]])
    # the kernel consumes the same mask the decorator holds
    np.testing.assert_array_equal(store.vertex_cache_mask(), cached)


def test_batched_store_dedups_flat_requests(tiny_layout):
    inner = ArrayPageStore(tiny_layout)
    store = BatchedPageStore(inner)
    out = store.fetch([3, 1, 3, 3, 1])
    assert store.counters.pages_requested == 5
    assert store.counters.pages_fetched == 2      # unique pages only
    assert inner.counters.pages_fetched == 2
    assert store.savings() == 3
    # callers still see one record-set per requested page, in request order
    np.testing.assert_array_equal(out["vids"][0], tiny_layout.page_vids[3])
    np.testing.assert_array_equal(out["vids"][1], tiny_layout.page_vids[1])
    np.testing.assert_array_equal(out["vids"][2], out["vids"][0])


def test_batched_store_forwards_vertex_requests_to_cache(tiny_layout):
    """Vertex-granular fetches can't be page-coalesced; they pass through so
    an inner CachedPageStore still serves its hits."""
    n = tiny_layout.vid2page.shape[0]
    cached = np.zeros(n, bool)
    cached[:4] = True
    mid = CachedPageStore(ArrayPageStore(tiny_layout), cached)
    store = BatchedPageStore(mid)
    vids = np.asarray([1, 30, 30])          # vid 1 cached
    out = store.fetch(tiny_layout.vid2page[vids], vids=vids)
    assert mid.counters.cache_hits == 1
    assert mid.counters.pages_fetched == 2  # uncoalesced pass-through
    assert out["cached_vids"].tolist() == [1]


def test_batched_store_coalesce_accounting_matches_fetch(tiny_layout):
    """coalesce() is the record-free serving-path variant: identical counter
    movement and accounting numbers as fetch_for_queries."""
    visited = np.zeros((2, tiny_layout.num_pages), bool)
    visited[0, [0, 1]] = True
    visited[1, [1, 2]] = True
    a = BatchedPageStore(ArrayPageStore(tiny_layout))
    b = BatchedPageStore(ArrayPageStore(tiny_layout))
    full = a.fetch_for_queries(visited)
    acct = b.coalesce(visited)
    assert (full["requested"], full["issued"]) == \
        (acct["requested"], acct["issued"]) == (4, 3)
    assert a.counters.as_dict() == b.counters.as_dict()


def test_batched_store_cross_query_union(tiny_layout):
    store = BatchedPageStore(ArrayPageStore(tiny_layout))
    P = tiny_layout.num_pages
    visited = np.zeros((3, P), bool)
    visited[0, [0, 1, 2]] = True
    visited[1, [1, 2, 3]] = True           # shares pages 1,2 with query 0
    visited[2, [0, 3]] = True              # shares everything
    out = store.fetch_for_queries(visited)
    assert out["requested"] == 8           # per-query accounting
    assert out["issued"] == 4              # union across the batch
    assert out["issued"] < out["requested"]
    assert store.savings() == 4


def test_build_store_composition(tiny_layout):
    n = tiny_layout.vid2page.shape[0]
    plain = build_store(tiny_layout)
    assert isinstance(plain, ArrayPageStore)
    cached = build_store(tiny_layout, cached_vertices=np.ones(n, bool))
    assert isinstance(cached, CachedPageStore)
    stacked = build_store(tiny_layout, cached_vertices=np.ones(n, bool),
                          batched=True)
    assert isinstance(stacked, BatchedPageStore)
    assert isinstance(stacked.inner, CachedPageStore)
    assert stacked.vertex_cache_mask().all()
    # a mask with no cached vertex composes no cache layer
    assert isinstance(build_store(tiny_layout,
                                  cached_vertices=np.zeros(n, bool)),
                      ArrayPageStore)


# --- QueryStats: aggregation equivalent to the old SearchResult path -------


def _kernel_out(b, seed, with_visited=True):
    rng = np.random.default_rng(seed)
    out = {"ids": rng.integers(0, 100, (b, 10)),
           "dists": rng.random((b, 10)),
           "hops": rng.integers(1, 20, (b,)),
           "page_reads": rng.integers(1, 50, (b,)).astype(np.float32),
           "cache_hits": rng.integers(0, 5, (b,)).astype(np.float32),
           "n_read": rng.integers(1, 200, (b,)).astype(np.float32),
           "n_eff": rng.integers(1, 50, (b,)).astype(np.float32),
           "full_evals": rng.integers(1, 500, (b,)).astype(np.float32),
           "pq_evals": rng.integers(1, 900, (b,)).astype(np.float32),
           "mem_hops": rng.integers(0, 9, (b,)),
           "mem_evals": rng.integers(0, 90, (b,))}
    if with_visited:
        out["visited_pages"] = rng.random((b, 17)) < 0.3
        out["page_trace"] = rng.integers(-1, 17, (b, 6, 4)).astype(np.int32)
    return out


def test_querystats_concat_matches_manual_concatenate():
    """The old engine concatenated raw dicts per batch; QueryStats.concat
    must produce exactly the same arrays."""
    o1, o2 = _kernel_out(5, 1), _kernel_out(3, 2)
    st = QueryStats.concat([QueryStats.from_kernel(o1),
                            QueryStats.from_kernel(o2)])
    assert len(st) == 8
    for field, key in QueryStats._KERNEL_KEYS.items():
        if key not in o1:
            # serving-stamped fields (tenants) never come from the kernel
            assert getattr(st, field) is None
            continue
        want = np.concatenate([o1[key], o2[key]])
        np.testing.assert_array_equal(getattr(st, field), want, err_msg=field)
    assert st.batch_unique_pages() == int(
        np.concatenate([o1["visited_pages"],
                        o2["visited_pages"]]).any(0).sum())


def test_querystats_is_searchresult_and_summary_one_code_path():
    from repro.core import summarize
    assert SearchResult is QueryStats
    st = QueryStats.from_kernel(_kernel_out(6, 3))
    model = SSDModel()
    s1 = st.summary(model, d=32, pq_m=16, page_bytes=4096)
    s2 = summarize(model, st, d=32, pq_m=16, page_bytes=4096)
    assert s1 == s2
    assert s1["u_io"] > 0
    assert s1["qps"] > 0 and s1["mean_latency_us"] > 0


def test_querystats_take_drops_padding():
    st = QueryStats.from_kernel(_kernel_out(8, 4))
    st3 = st.take(3)
    assert len(st3) == 3
    np.testing.assert_array_equal(st3.ids, st.ids[:3])
    np.testing.assert_array_equal(st3.visited_pages, st.visited_pages[:3])


# --- SearchConfig validation -----------------------------------------------


@pytest.mark.parametrize("kw,msg", [
    (dict(k=20, L=16), "k=20 must be <= L=16"),
    (dict(dynamic_width=True, dw_min=64, dw_max=32), "dw_min=64"),
    (dict(cache_frac=-0.1), "cache_frac=-0.1"),
    (dict(cache_frac=1.5), "cache_frac=1.5"),
    (dict(pipeline=True, pipeline_spec=-1), "pipeline_spec=-1"),
])
def test_search_config_rejects_invalid(kw, msg):
    with pytest.raises(ValueError, match=msg):
        SearchConfig(**kw)


def test_search_config_replace_revalidates():
    cfg = SearchConfig()
    with pytest.raises(ValueError):
        cfg.replace(L=4)          # k=10 > L=4
    assert cfg.replace(L=32).L == 32


# --- SSDModel: deduplicated rates + concurrency extension ------------------


def test_rates_helper_consistent_across_page_sizes():
    m = SSDModel()
    for pb in (4096, 8192, 16384):
        iops, bw = m._rates(pb)
        per_read = max(1.0 / iops, pb / bw)
        assert m.page_service_us(pb) == pytest.approx(
            per_read * m.workers * 1e6)
    i4, _ = m._rates(4096)
    i8, _ = m._rates(8192)
    i16, _ = m._rates(16384)
    assert i4 > i8 > i16          # 8K interpolates between 4K and 16K


def test_concurrent_latency_matches_fixed_model_at_worker_depth():
    m = SSDModel()
    kw = dict(hops=np.array([10.0]), pages=np.array([40.0]),
              full_evals=np.array([200.0]), pq_evals=np.array([900.0]),
              mem_evals=np.array([0.0]), d=96, pq_m=16, page_bytes=4096)
    base = m.query_latency_us(**kw)
    np.testing.assert_allclose(m.concurrent_latency_us(m.workers, **kw), base)
    # latency non-decreasing in queue depth; flat region below device knee
    lats = [float(m.concurrent_latency_us(qd, **kw).mean())
            for qd in (1, 2, 8, 48, 96, 192)]
    assert all(b >= a for a, b in zip(lats, lats[1:])), lats
    assert lats[-1] > lats[0]
    # below the device's internal parallelism the latency is flat
    assert lats[0] == lats[1] == lats[2], lats
    assert lats[3] > lats[2]
    # batch-coalescing rebate strictly reduces the I/O term
    full = m.concurrent_latency_us(8, **kw)
    rebated = m.concurrent_latency_us(8, page_dedup=0.5, **kw)
    assert float(rebated.mean()) < float(full.mean())
