"""Type-check gate over the accounting-critical layers (mypy.ini scopes it
to src/repro/io + src/repro/mutation with check_untyped_defs). The
container image doesn't ship mypy, so this skips locally and runs in the
CI lint job, which installs it."""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

pytestmark = pytest.mark.fast


def test_io_and_mutation_layers_typecheck():
    pytest.importorskip("mypy", reason="mypy not installed (CI lint job "
                                       "installs it)")
    res = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file", "mypy.ini"],
        cwd=REPO, capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr
