"""Pipeline parallelism + gradient accumulation + elastic restore tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_grad_accumulation_matches_full_batch():
    from repro.configs import get_smoke_config
    from repro.models import init_params, loss_fn
    from repro.training.accumulate import accumulated_grads

    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size, (4, 32)),
        jnp.int32)}
    (loss_f, _), g_full = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    (loss_a, _), g_acc = accumulated_grads(
        lambda p, b: loss_fn(p, cfg, b), params, batch, n_micro=4)
    np.testing.assert_allclose(float(loss_a), float(loss_f), rtol=1e-5)
    for ga, gf in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gf),
                                   rtol=2e-4, atol=2e-5)


_PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import gpipe

mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("pod",))
S, B, D = 4, 8, 16
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(0, 0.3, (S, D, D)).astype(np.float32))
x = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))

def stage(w, h):
    return jnp.tanh(h @ w)

y_pipe = gpipe(stage, W, x, n_micro=4, axis="pod", mesh=mesh)
y_ref = x
for s in range(S):
    y_ref = stage(W[s], y_ref)
err = float(jnp.abs(y_pipe - y_ref).max())
assert err < 1e-5, err
print("PIPE_OK", err)
"""


def test_gpipe_matches_sequential():
    """4-stage GPipe over a 4-device pod axis == the sequential stack."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _PIPE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PIPE_OK" in r.stdout, r.stdout + r.stderr


def test_elastic_restore_to_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores onto an explicit sharding —
    the elastic-restart reshard path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.training import checkpoint as ck
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(tmp_path, 1, tree)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, step = ck.restore(tmp_path, tree, shardings=sh)
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(16).reshape(4, 4))
    assert restored["w"].sharding == sh["w"]


def test_serve_launcher_runs():
    from repro.launch.serve import main
    done = main(["--arch", "tinyllama-1.1b", "--requests", "3",
                 "--batch-slots", "2", "--prompt-len", "8",
                 "--new-tokens", "4"])
    assert done == 3
