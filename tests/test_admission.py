"""Overload-control subsystem (repro/serving/admission.py + the
serve_open_loop wiring): token-bucket and bounded-queue semantics on
synthetic arrival streams (fast, no index), and the admission edge cases
the ISSUE names — zero-capacity queue, burst arrivals at t=0, all-shed
saturation, degrade-under-pressure — against a real served index."""
import numpy as np
import pytest

from repro.core import get_preset, recall_at_k
from repro.serving import (AdmissionConfig, AdmissionController, AnnServer,
                           ServerConfig)


# --- AdmissionConfig validation (fast) -------------------------------------


@pytest.mark.fast
@pytest.mark.parametrize("kw,msg", [
    (dict(policy="drop-all"), "policy='drop-all'"),
    (dict(queue_cap=-1), "queue_cap=-1"),
    (dict(rate_qps=-2.0), "rate_qps=-2.0"),
    (dict(burst=0), "burst=0"),
    (dict(degrade_levels=()), "must not be empty"),
    (dict(degrade_levels=(1.0, 0.0)), "must all be in"),
    (dict(degrade_levels=(1.0, 1.5)), "must all be in"),
    (dict(degrade_levels=(0.5, 0.25)), r"degrade_levels\[0\]"),
    (dict(degrade_levels=(1.0, 0.25, 0.5)), "non-increasing"),
])
def test_admission_config_rejects_invalid(kw, msg):
    with pytest.raises(ValueError, match=msg):
        AdmissionConfig(**kw)


@pytest.mark.fast
def test_server_config_admission_and_tenant_validation():
    with pytest.raises(ValueError, match="must be an AdmissionConfig"):
        ServerConfig(admission="reject")
    with pytest.raises(ValueError, match="tenants=0"):
        ServerConfig(tenants=0)
    with pytest.raises(ValueError, match="stateful page cache"):
        ServerConfig(tenants=2)
    with pytest.raises(ValueError, match="tenant_shares needs tenants > 1"):
        ServerConfig(tenant_shares=(1.0,))
    with pytest.raises(ValueError, match="cache_rebalance_every=-1"):
        ServerConfig(cache_rebalance_every=-1)
    cfg = ServerConfig(cache_policy="lru", cache_bytes=1 << 20, tenants=2,
                       tenant_shares=(0.7, 0.3),
                       admission=AdmissionConfig(policy="degrade"))
    assert cfg.tenants == 2 and cfg.admission.policy == "degrade"


# --- AdmissionController unit behaviour (fast) -----------------------------


@pytest.mark.fast
def test_token_bucket_burst_at_t0():
    """Burst arrivals at t=0: exactly `burst` tokens exist, nothing has
    refilled yet, so exactly `burst` pass and the rest are rate-shed."""
    ac = AdmissionController(AdmissionConfig(
        policy="reject", queue_cap=100, rate_qps=1000.0, burst=4))
    decisions = [ac.offer(0.0, i) for i in range(16)]
    assert decisions == [True] * 4 + [False] * 12
    assert ac.offered == 16 and ac.admitted == 4
    assert ac.shed_rate == 12 and ac.shed_queue == 0
    assert ac.offered == ac.admitted + ac.shed


@pytest.mark.fast
def test_token_bucket_refills_at_rate():
    """1000 qps refill = one token per 1000 us: a post-burst arrival gets a
    token exactly when the bucket has accrued one."""
    ac = AdmissionController(AdmissionConfig(
        policy="reject", queue_cap=100, rate_qps=1000.0, burst=1))
    assert ac.offer(0.0, 0)            # the initial token
    assert not ac.offer(500.0, 1)      # only half a token accrued
    assert ac.offer(1600.0, 2)         # >= 1 token since the last take
    assert ac.shed == 1


@pytest.mark.fast
def test_zero_capacity_queue_admits_only_into_idle_system():
    """queue_cap=0: no waiting room — an arrival is admitted only when the
    queue is empty AND the executor is idle (the in-service slot)."""
    ac = AdmissionController(AdmissionConfig(policy="reject", queue_cap=0))
    assert ac.offer(0.0, 0, executor_idle=True)
    assert not ac.offer(1.0, 1, executor_idle=True)   # queue occupied
    ac.take_batch(4)                                  # dispatched
    assert not ac.offer(2.0, 2, executor_idle=False)  # executor busy
    assert ac.offer(3.0, 3, executor_idle=True)
    assert ac.offered == 4 and ac.admitted == 2 and ac.shed_queue == 2


@pytest.mark.fast
def test_shed_oldest_drops_from_the_front():
    ac = AdmissionController(AdmissionConfig(policy="shed-oldest",
                                             queue_cap=2))
    for i in range(5):
        ac.offer(float(i), i)
    assert [item for _, item, _ in ac.pending] == [3, 4]
    assert ac.offered == 5 and ac.admitted == 2 and ac.shed == 3
    # zero-capacity shed-oldest with an empty queue sheds the arrival
    ac0 = AdmissionController(AdmissionConfig(policy="shed-oldest",
                                              queue_cap=0))
    assert not ac0.offer(0.0, 0, executor_idle=False)
    assert ac0.shed_queue == 1


@pytest.mark.fast
def test_reject_keeps_oldest_sheds_newest():
    ac = AdmissionController(AdmissionConfig(policy="reject", queue_cap=2))
    for i in range(5):
        ac.offer(float(i), i)
    assert [item for _, item, _ in ac.pending] == [0, 1]
    assert ac.admitted == 2 and ac.shed_queue == 3


@pytest.mark.fast
def test_degrade_admits_everything_and_maps_pressure():
    ac = AdmissionController(AdmissionConfig(
        policy="degrade", queue_cap=4, degrade_levels=(1.0, 0.5, 0.25)))
    for i in range(3):
        ac.offer(float(i), i)
    assert ac.pressure_level() == 0          # below cap
    for i in range(3, 6):
        ac.offer(float(i), i)
    assert ac.pressure_level() == 1          # one cap of backlog
    for i in range(6, 20):
        ac.offer(float(i), i)
    assert ac.pressure_level() == 2          # clamped at the ladder's end
    assert ac.admitted == 20 and ac.shed == 0


@pytest.mark.fast
def test_per_tenant_admission_counters():
    ac = AdmissionController(AdmissionConfig(policy="shed-oldest",
                                             queue_cap=1))
    ac.offer(0.0, 0, tenant=0)
    ac.offer(1.0, 1, tenant=1)     # sheds tenant 0's query (the oldest)
    rows = ac.per_tenant_rows()
    assert rows[0] == {"offered": 1, "admitted": 0, "shed": 1}
    assert rows[1] == {"offered": 1, "admitted": 1, "shed": 0}
    assert ac.offered == sum(r["offered"] for r in rows.values())


# --- served admission edge cases (real index) ------------------------------


def _srv(idx, cfg, admission=None, max_batch=4, **kw):
    return AnnServer(idx, cfg, server_cfg=ServerConfig(
        max_batch=max_batch, admission=admission, **kw))


def test_open_loop_without_admission_unchanged(base_index, small_dataset):
    """ServerConfig.admission=None must reproduce the PR 2 open loop
    exactly: everything admitted, nothing shed or degraded."""
    cfg = get_preset("baseline", L=16)
    rep = _srv(base_index, cfg).serve_open_loop(
        small_dataset.queries, rate_qps=4000.0, duration_us=10000.0, seed=7)
    assert rep.admitted == rep.offered == rep.completed
    assert rep.shed == 0 and rep.degraded == 0
    assert rep.offered_qps > 0 and rep.per_tenant is None
    assert len(rep.query_indices) == rep.completed


def test_all_shed_saturation_reports_cleanly(base_index, small_dataset):
    """A token bucket with a starved refill sheds every arrival: the report
    must stay consistent (no NaNs, no kernel execution implied)."""
    cfg = get_preset("baseline", L=16)
    srv = _srv(base_index, cfg, AdmissionConfig(
        policy="reject", queue_cap=8, rate_qps=0.001, burst=1))
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=8000.0,
                              duration_us=20000.0, seed=3)
    assert rep.offered > 1
    assert rep.admitted <= 1           # at most the single initial token
    assert rep.shed >= rep.offered - 1
    assert rep.offered == rep.admitted + rep.shed
    assert rep.completed == rep.admitted == len(rep.stats)
    assert np.isfinite(rep.p99_latency_us)


def test_shed_oldest_bounds_p99_under_overload(base_index, small_dataset):
    """Acceptance shape: at far-past-saturation offered load, the bounded
    queue keeps p99-of-admitted orders below the uncontrolled open loop,
    and the shed count absorbs the overload."""
    cfg = get_preset("baseline", L=16)
    kw = dict(rate_qps=64000.0, duration_us=10000.0, seed=7)
    rep_none = _srv(base_index, cfg).serve_open_loop(
        small_dataset.queries, **kw)
    rep_shed = _srv(base_index, cfg, AdmissionConfig(
        policy="shed-oldest", queue_cap=8)).serve_open_loop(
        small_dataset.queries, **kw)
    assert rep_shed.shed > 0
    assert rep_shed.offered == rep_none.offered     # same arrival process
    assert rep_shed.p99_latency_us < rep_none.p99_latency_us
    # queue bound => wait is capped by ~queue_cap batches of service
    assert rep_shed.p99_latency_us < rep_none.p99_latency_us / 2


def test_degrade_sheds_nothing_and_shrinks_the_beam(base_index,
                                                    small_dataset):
    """Degrade serves everyone: no drops, degraded queries read fewer pages
    (smaller beam), p99 lands under the uncontrolled loop, and recall
    stays sane (the floor is L=k)."""
    cfg = get_preset("baseline", L=32)
    kw = dict(rate_qps=64000.0, duration_us=10000.0, seed=7)
    rep_none = _srv(base_index, cfg).serve_open_loop(
        small_dataset.queries, **kw)
    srv = _srv(base_index, cfg, AdmissionConfig(
        policy="degrade", queue_cap=8, degrade_levels=(1.0, 0.5, 0.25)))
    rep = srv.serve_open_loop(small_dataset.queries, **kw)
    assert rep.shed == 0 and rep.completed == rep.offered
    assert rep.degraded > 0
    assert rep.pages_per_query < rep_none.pages_per_query
    assert rep.p99_latency_us < rep_none.p99_latency_us
    rec = recall_at_k(rep.stats.ids, small_dataset.gt[rep.query_indices],
                      cfg.k)
    assert rec > 0.5, rec


def test_burst_at_t0_served_through_explicit_arrivals(base_index,
                                                      small_dataset):
    """Deterministic burst: 24 arrivals at t=0 against a 2-deep bounded
    queue — the first batch fills straight from the burst, the bounded
    queue sheds the overflow, and every admitted query completes."""
    cfg = get_preset("baseline", L=16)
    srv = _srv(base_index, cfg, AdmissionConfig(policy="reject",
                                                queue_cap=2), max_batch=4)
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=1000.0,
                              duration_us=1000.0,
                              arrivals=np.zeros(24))
    assert rep.offered == 24
    # the idle-system bypass admits the first arrival, which then occupies
    # the 2-deep queue until dispatch, so exactly one more fits
    assert rep.admitted == 2 and rep.shed == 22
    assert rep.completed == 2 == len(rep.stats)
    assert rep.mean_batch_size <= 4.0
    with pytest.raises(ValueError, match="non-negative and sorted"):
        srv.serve_open_loop(small_dataset.queries, rate_qps=1000.0,
                            duration_us=1000.0,
                            arrivals=np.asarray([5.0, 1.0]))


def test_multi_tenant_partitioned_serving(base_index, small_dataset):
    """Two tenants on a partitioned LRU: the report carries per-tenant
    admission + latency + hit-rate rows and partition capacities."""
    cfg = get_preset("baseline", L=16)
    tenants = (np.arange(len(small_dataset.queries)) % 2).astype(np.int64)
    srv = AnnServer(base_index, cfg, server_cfg=ServerConfig(
        max_batch=4, cache_policy="lru",
        cache_bytes=128 * base_index.layout.page_bytes, tenants=2))
    rep = srv.serve_open_loop(small_dataset.queries, rate_qps=4000.0,
                              duration_us=20000.0, seed=5, tenants=tenants)
    assert set(rep.per_tenant) == {0, 1}
    for t in (0, 1):
        row = rep.per_tenant[t]
        assert row["offered"] == row["admitted"] == row["completed"] > 0
        assert 0.0 <= row["cache_hit_rate"] <= 1.0
        assert row["cache_pages"] == 64
    # tenant ids out of range for the partition count must be rejected
    with pytest.raises(ValueError, match="out of range"):
        srv.serve_open_loop(small_dataset.queries, rate_qps=1000.0,
                            duration_us=1000.0,
                            tenants=np.full(len(small_dataset.queries), 7))


def test_closed_loop_carries_tenant_accounting(base_index, small_dataset):
    cfg = get_preset("baseline", L=16)
    tenants = (np.arange(len(small_dataset.queries)) % 2).astype(np.int64)
    srv = AnnServer(base_index, cfg, server_cfg=ServerConfig(
        max_batch=4, cache_policy="lru",
        cache_bytes=128 * base_index.layout.page_bytes, tenants=2))
    rep = srv.serve_closed_loop(small_dataset.queries, workers=8, rounds=2,
                                tenants=tenants)
    assert set(rep.per_tenant) == {0, 1}
    assert sum(r["completed"] for r in rep.per_tenant.values()) == 16
    assert rep.stats.tenants is not None and len(rep.stats.tenants) == 16
