"""Documentation stays healthy: every relative link in the top-level and
docs/ markdown resolves to a real file (the same check the CI docs job
runs via tools/check_links.py), and the link checker itself catches
breakage."""
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_links  # noqa: E402

pytestmark = pytest.mark.fast

DOC_TARGETS = ["README.md", "ARCHITECTURE.md", "docs"]


def test_repo_markdown_has_no_broken_relative_links():
    files = list(check_links.iter_md_files(
        [str(REPO / t) for t in DOC_TARGETS]))
    assert files, "no markdown files found — did the layout move?"
    # the rule catalog must stay inside the checked set (ISSUE 9)
    assert any(f.name == "contracts.md" for f in files)
    broken = [b for md in files for b in check_links.check_file(md)]
    assert not broken, "\n".join(broken)


def test_checker_flags_broken_and_accepts_valid(tmp_path):
    good = tmp_path / "target.md"
    good.write_text("# here\n")
    md = tmp_path / "doc.md"
    md.write_text(
        "[ok](target.md) [ok#frag](target.md#frag) "
        "[url](https://example.com/x.md) [anchor](#local)\n"
        "[missing](nope.md)\n")
    broken = check_links.check_file(md)
    assert len(broken) == 1 and "nope.md" in broken[0]
    assert "doc.md:2" in broken[0]


def test_checker_rejects_non_markdown_argument(tmp_path):
    with pytest.raises(SystemExit, match="not a markdown"):
        list(check_links.iter_md_files([str(tmp_path / "x.py")]))
