"""Golden facade test (refactor acceptance): `DiskIndex.search` must produce
identical ids / page_reads / hops / dists to the pre-refactor monolithic
engine. tests/golden/facade_golden.npz was captured from the seed engine
(commit 8d132d2) on the fixed-seed conftest dataset + graph, for four search
configs covering the static kernel variants (page_search / dynamic_width /
pipeline code paths)."""
from pathlib import Path

import numpy as np
import pytest

from repro.core import get_preset

GOLDEN = Path(__file__).parent / "golden" / "facade_golden.npz"
PRESETS = ("baseline", "pagesearch", "dynamicwidth", "pipeline")


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


@pytest.mark.parametrize("preset", PRESETS)
def test_facade_identical_to_pre_refactor_engine(preset, golden, base_index,
                                                 small_dataset, small_graph):
    _, med, _ = small_graph
    assert med == int(golden["medoid"]), \
        "fixture graph drifted from the golden capture"
    cfg = get_preset(preset, L=48)
    res = base_index.search(small_dataset.queries, cfg)
    np.testing.assert_array_equal(res.ids, golden[f"{preset}_ids"])
    np.testing.assert_array_equal(res.page_reads,
                                  golden[f"{preset}_page_reads"])
    np.testing.assert_array_equal(res.hops, golden[f"{preset}_hops"])
    np.testing.assert_array_equal(res.cache_hits,
                                  golden[f"{preset}_cache_hits"])
    np.testing.assert_allclose(res.dists, golden[f"{preset}_dists"],
                               rtol=1e-6)
