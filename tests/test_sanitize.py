"""REPRO_SANITIZE runtime sanitizer: armed, it trips on injected
monotonicity / write-conservation / admission-conservation violations at
the exact boundary; disarmed (the default), the hooks cost nothing and
let legacy downward resets through. Real store stacks run clean under it
(the whole fast tier is re-run with REPRO_SANITIZE=1 in CI)."""
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro import sanitize
from repro.io import ArrayPageStore, BatchedPageStore, CachedPageStore
from repro.core.pages import build_layout
from repro.io.page_store import StoreCounters, book_writes

pytestmark = pytest.mark.fast


@pytest.fixture()
def armed():
    prev = sanitize.set_enabled(True)
    yield
    sanitize.set_enabled(prev)


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(3)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


def test_monotonicity_trip_on_counter_decrement(armed):
    c = StoreCounters()
    c.pages_fetched = 5
    with pytest.raises(sanitize.SanitizeError, match="moved backward"):
        c.pages_fetched -= 1
    with pytest.raises(sanitize.SanitizeError, match="negative"):
        c.cache_hits = -2


def test_write_conservation_trip(armed):
    c = StoreCounters()
    book_writes(c, 3, "journal")          # legitimate booking: clean
    # corrupt one side of the invariant behind the sanitizer's back — the
    # next booking boundary must catch it
    object.__setattr__(c, "journal_writes", 0)
    with pytest.raises(sanitize.SanitizeError,
                       match="write conservation broken"):
        book_writes(c, 1, "data")


def test_reset_is_exempt_and_disabled_mode_is_silent(armed):
    c = StoreCounters()
    c.pages_fetched = 5
    c.reset()                              # downward, but sanctioned
    assert c.pages_fetched == 0
    sanitize.set_enabled(False)
    c.pages_fetched = 5
    c.pages_fetched -= 1                   # disarmed: legacy behaviour
    assert c.pages_fetched == 4


def test_admission_conservation_trip(armed):
    ok = SimpleNamespace(offered=10, admitted=7, shed=3, completed=7)
    sanitize.check_open_report(ok)
    lost = SimpleNamespace(offered=10, admitted=7, shed=2, completed=7)
    with pytest.raises(sanitize.SanitizeError,
                       match="admission conservation broken"):
        sanitize.check_open_report(lost)
    vanished = SimpleNamespace(offered=10, admitted=7, shed=3, completed=6)
    with pytest.raises(sanitize.SanitizeError, match="vanished"):
        sanitize.check_open_report(vanished)


def test_real_store_stack_runs_clean_under_sanitizer(armed, tiny_layout):
    store = BatchedPageStore(
        CachedPageStore(ArrayPageStore(tiny_layout),
                        np.zeros(tiny_layout.vid2page.shape[0], bool)))
    vids = np.asarray([2, 40, 50, 2])
    store.fetch(tiny_layout.vid2page[vids], vids=vids)
    store.charge([0, 1])
    store.note_write([0], kind="data")
    store.note_write(kind="journal", count=2)
    store.note_write(kind="snapshot", count=1)
    for c in (store.counters, store.inner.counters,
              store.inner.inner.counters):
        d = c.as_dict()
        assert (d["pages_written"]
                == d["data_writes"] + d["journal_writes"]
                + d["snapshot_writes"])
    store.counters.reset()


def test_env_var_arms_the_sanitizer():
    env = dict(os.environ, REPRO_SANITIZE="1",
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    code = ("from repro import sanitize; assert sanitize.enabled(); "
            "from repro.io.page_store import StoreCounters\n"
            "c = StoreCounters(); c.pages_fetched = 1\n"
            "try:\n"
            "    c.pages_fetched = 0\n"
            "except sanitize.SanitizeError:\n"
            "    print('TRIPPED')\n")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert "TRIPPED" in out.stdout
