"""Disk-engine behaviour: recall targets, I/O accounting invariants, and the
paper's single-factor findings at small scale."""
import numpy as np
import pytest

from repro.core import (SSDModel, build_index, get_preset, overlap_ratio,
                        recall_at_k, summarize)


def _search(idx, ds, preset, **over):
    cfg = get_preset(preset, **over)
    # page_shuffle/AiS change the layout — need their own index
    res = idx.search(ds.queries, cfg)
    return cfg, res


def test_baseline_recall(base_index, small_dataset):
    cfg, res = _search(base_index, small_dataset, "baseline", L=64)
    rec = recall_at_k(res.ids, small_dataset.gt, 10)
    assert rec >= 0.9, rec


def test_recall_monotonic_in_L(base_index, small_dataset):
    recs = []
    for L in (16, 32, 64):
        _, res = _search(base_index, small_dataset, "baseline", L=L)
        recs.append(recall_at_k(res.ids, small_dataset.gt, 10))
    assert recs[0] <= recs[-1] + 0.02, recs


def test_pages_grow_with_L(base_index, small_dataset):
    pages = []
    for L in (16, 64):
        _, res = _search(base_index, small_dataset, "baseline", L=L)
        pages.append(res.page_reads.mean())
    assert pages[0] < pages[1]


def test_cache_reduces_charged_pages(small_dataset, small_graph):
    from repro.core import build_index
    G, med, _ = small_graph
    idx = build_index(small_dataset, get_preset("cache", cache_frac=0.05),
                      graph=G, medoid_id=med)
    _, res_c = _search(idx, small_dataset, "cache", cache_frac=0.05)
    _, res_b = _search(idx, small_dataset, "baseline")
    assert res_c.cache_hits.sum() > 0
    assert res_c.page_reads.mean() < res_b.page_reads.mean()


def test_pagesearch_does_not_increase_pages(base_index, small_dataset):
    _, res_b = _search(base_index, small_dataset, "baseline")
    _, res_p = _search(base_index, small_dataset, "pagesearch")
    assert res_p.page_reads.mean() <= res_b.page_reads.mean() * 1.05
    # in-page scoring doesn't change the fetch volume, only the pool
    # (the engine evaluates fetched records either way; traversal shifts
    # slightly as in-page candidates enter the pool)
    assert abs(res_p.full_evals.sum() / res_b.full_evals.sum() - 1) < 0.05


def test_dynamicwidth_reduces_io(base_index, small_dataset):
    _, res_b = _search(base_index, small_dataset, "baseline")
    _, res_d = _search(base_index, small_dataset, "dynamicwidth")
    rec_b = recall_at_k(res_b.ids, small_dataset.gt, 10)
    rec_d = recall_at_k(res_d.ids, small_dataset.gt, 10)
    assert res_d.page_reads.mean() < res_b.page_reads.mean()
    assert rec_d >= rec_b - 0.08  # small accuracy cost allowed (paper §6.1)


def test_pipeline_speculation_adds_io(base_index, small_dataset):
    """Finding 5: speculative reads increase I/O operations."""
    _, res_b = _search(base_index, small_dataset, "baseline")
    _, res_p = _search(base_index, small_dataset, "pipeline")
    assert res_p.page_reads.mean() >= res_b.page_reads.mean()
    assert res_p.n_eff.sum() / res_p.n_read_records.sum() <= \
        res_b.n_eff.sum() / res_b.n_read_records.sum() + 1e-6


def test_pageshuffle_raises_overlap_ratio(small_dataset, small_graph):
    G, med, _ = small_graph
    idx_seq = build_index(small_dataset, get_preset("baseline"),
                          graph=G, medoid_id=med)
    idx_shuf = build_index(small_dataset, get_preset("pageshuffle"),
                           graph=G, medoid_id=med)
    or_seq = overlap_ratio(idx_seq.layout, G)
    or_shuf = overlap_ratio(idx_shuf.layout, G)
    assert or_shuf > or_seq * 2, (or_seq, or_shuf)


def test_memgraph_shortens_paths(small_dataset, small_graph):
    G, med, _ = small_graph
    idx = build_index(small_dataset,
                      get_preset("memgraph", memgraph_frac=0.05),
                      graph=G, medoid_id=med)
    _, res_m = _search(idx, small_dataset, "memgraph", memgraph_frac=0.05)
    _, res_b = _search(idx, small_dataset, "baseline")
    assert res_m.hops.mean() < res_b.hops.mean()
    assert res_m.page_reads.mean() < res_b.page_reads.mean()


def test_results_are_exact_distance_sorted(base_index, small_dataset):
    _, res = _search(base_index, small_dataset, "baseline")
    d = res.dists
    assert np.all(np.diff(d, axis=1) >= -1e-4)


def test_io_complexity_model_eq1(base_index, small_dataset, small_graph):
    """Eq. 1: page reads scale with R*H/(OR*n_p) — check the H correlation
    by sweeping L (H grows with L, OR/n_p fixed)."""
    G, _, _ = small_graph
    hops, pages = [], []
    for L in (16, 32, 64):
        _, res = _search(base_index, small_dataset, "baseline", L=L)
        hops.append(res.hops.mean())
        pages.append(res.page_reads.mean())
    ratio = [p / h for p, h in zip(pages, hops)]
    # pages/hops should be roughly constant (model: pages ∝ H)
    assert max(ratio) / min(ratio) < 1.6, ratio


def test_device_model_io_bound(base_index, small_dataset):
    cfg, res = _search(base_index, small_dataset, "baseline")
    s = summarize(SSDModel(), res, d=small_dataset.d, pq_m=cfg.pq_m,
                  page_bytes=cfg.page_bytes)
    assert 0.5 < s["io_fraction"] <= 1.0   # I/O dominates (paper Fig. 2)
    assert s["qps"] > 0 and s["mean_latency_us"] > 0
