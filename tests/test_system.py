"""End-to-end behaviour: the paper's headline claim at test scale —
OctopusANN (C5) beats the DiskANN-style baseline on I/O and modeled QPS at
matched accuracy — plus the serving integration path."""
import numpy as np
import pytest

from repro.core import (SSDModel, build_index, get_preset, recall_at_k,
                        summarize)


@pytest.fixture(scope="module")
def octopus_index(small_dataset, small_graph):
    G, med, _ = small_graph
    return build_index(small_dataset, get_preset("octopusann",
                                                 memgraph_frac=0.05),
                       graph=G, medoid_id=med)


def test_octopus_beats_baseline(small_dataset, base_index, octopus_index):
    model = SSDModel()
    cfg_b = get_preset("baseline")
    cfg_o = get_preset("octopusann", memgraph_frac=0.05)
    res_b = base_index.search(small_dataset.queries, cfg_b)
    res_o = octopus_index.search(small_dataset.queries, cfg_o)
    rec_b = recall_at_k(res_b.ids, small_dataset.gt, 10)
    rec_o = recall_at_k(res_o.ids, small_dataset.gt, 10)
    s_b = summarize(model, res_b, d=small_dataset.d, pq_m=16, page_bytes=4096)
    s_o = summarize(model, res_o, d=small_dataset.d, pq_m=16, page_bytes=4096)
    assert rec_o >= rec_b - 0.05
    assert s_o["mean_pages_per_query"] < s_b["mean_pages_per_query"]
    assert s_o["qps"] > s_b["qps"]


def test_rag_serving_integration(small_dataset, octopus_index):
    """ANN retrieval feeding a decode loop — the framework's serving path."""
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving.engine import LMServer

    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    server = LMServer(params, cfg, max_len=128)

    res = octopus_index.search(small_dataset.queries[:2])
    assert (res.ids[:, 0] >= 0).all()
    # retrieved ids become context token prefixes (toy RAG contract)
    prompts = (res.ids[:, :8] % cfg.vocab_size).astype(np.int32)
    out = server.generate(prompts, new_tokens=4)
    assert out.shape == (2, 4)
    assert ((0 <= out) & (out < cfg.padded_vocab)).all()
