"""Counter-conservation property test over every store stack.

A sharded store is only trustworthy if the counter rollup it aggregates is
conserved at every layer, so this walks EVERY build_store composition —
`none` / `static-vertex` / `batched` / `lru` / `2q` / partitioned / sharded
(plain and cached) — through a fixed workload on its own serving path and
asserts, at each decorator:

  1. pages_requested == cache_hits + pages_fetched   (coalescing layers
     additionally bank the dedup: requested - fetched - hits == savings)
  2. the decorator's pages_fetched equals the inner store's movement
     (every read this layer charged reached the device it decorates)

Both previously FAILED for SharedCachePageStore.replay_batch, which booked
issued reads only in its own counters — the bugfix this test pins down.
All `-m fast` (tiny synthetic layouts, no graph build)."""
import numpy as np
import pytest

from repro.core.pages import build_layout
from repro.io import (BatchedPageStore, PrefetchingPageStore,
                      SharedCachePageStore, ShardedPageStore, build_store)
from repro.mutation import MutablePageStore

pytestmark = pytest.mark.fast


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


def _mask(layout):
    n = layout.vid2page.shape[0]
    m = np.zeros(n, bool)
    m[:8] = True
    return m


STACKS = {
    "none": lambda lay: build_store(lay),
    "static-vertex": lambda lay: build_store(
        lay, cached_vertices=_mask(lay), cache_policy="static-vertex"),
    "batched": lambda lay: build_store(lay, batched=True),
    "lru": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes),
    "2q": lambda lay: build_store(
        lay, batched=True, cache_policy="2q",
        cache_bytes=8 * lay.page_bytes),
    "lru-prefetch": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=16 * lay.page_bytes, prefetch=1),
    "partitioned": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes, tenants=2),
    "sharded": lambda lay: build_store(lay, batched=True, shards=3),
    "sharded-cached": lambda lay: build_store(
        lay, batched=True, shards=3, cache_policy="lru",
        cache_bytes=9 * lay.page_bytes),
    # streaming updates: the MutablePageStore wrapper must keep mirroring
    # the stack it decorates on every read path (writes book at its layer)
    "mutable": lambda lay: build_store(lay, batched=True, mutable=True),
    "mutable-lru": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes, mutable=True),
    "mutable-sharded": lambda lay: build_store(
        lay, batched=True, shards=3, cache_policy="lru",
        cache_bytes=9 * lay.page_bytes, mutable=True),
}


def _trace(B, num_pages, seed=7):
    """(B, 4, 3) trace with deliberate within- and cross-query reuse."""
    rng = np.random.default_rng(seed)
    t = rng.integers(0, min(num_pages, 12), (B, 4, 3)).astype(np.int32)
    t[rng.random(t.shape) < 0.2] = -1
    return t


def _drive(store, layout):
    """Run the store's own serving path(s) on a fixed workload."""
    trace = _trace(3, layout.num_pages)
    if hasattr(store, "replay_batch"):
        tenants = ([0, 1, 0] if getattr(getattr(store, "cache", None),
                                        "tenant_aware", False) else None)
        store.replay_batch(trace, tenants=tenants)
        store.replay_batch(trace, tenants=tenants)   # warm pass: hits move
    if hasattr(store, "coalesce"):
        vis = np.zeros((3, layout.num_pages), bool)
        vis[0, [0, 1, 2]] = True
        vis[1, [1, 2, 3]] = True
        vis[2, [0, 3, 4]] = True
        store.coalesce(vis)
    # the record-returning paths move the same books
    store.fetch([0, 1, 1, 2])
    if isinstance(store, MutablePageStore):
        # rewrite path: invalidation + write booking + the charged re-read
        store.invalidate([0, 1])
        store.note_write([0, 1])
        store.fetch([0, 1])
    if not hasattr(store, "shard_counters"):
        # vertex-granular fetches pass through the shard layer into the
        # roll-up only (static-vertex territory), which would skew the
        # per-shard == roll-up audit below — drive them elsewhere
        # (hasattr sees through the mutable wrapper's delegation)
        vids = np.asarray([2, 9, 40])
        store.fetch(layout.vid2page[vids], vids=vids)


def _layers(store):
    out = [store]
    while hasattr(out[-1], "inner"):
        out.append(out[-1].inner)
    return out


@pytest.mark.parametrize("name", sorted(STACKS))
def test_conservation_at_every_layer(name, tiny_layout):
    store = STACKS[name](tiny_layout)
    _drive(store, tiny_layout)
    layers = _layers(store)
    assert len(layers) >= 1
    for layer, inner in zip(layers, layers[1:] + [None]):
        c = layer.counters
        label = f"{name}:{type(layer).__name__}"
        if isinstance(layer, MutablePageStore):
            # the mutable wrapper mirrors EVERY read-path field of the
            # stack it decorates; writes are its own ledger
            for f in ("pages_requested", "pages_fetched", "cache_hits",
                      "records_fetched"):
                assert getattr(c, f) == getattr(inner.counters, f), \
                    (label, f)
            assert c.pages_written == 2, label
            assert inner.counters.pages_written == 0, label
            continue
        if isinstance(layer, (BatchedPageStore, ShardedPageStore)):
            # coalescing layers bank their cross-query dedup as savings,
            # not hits (ShardedPageStore's union path included); hits and
            # savings are disjoint and together close the books
            assert c.pages_requested >= c.cache_hits + c.pages_fetched, label
            assert layer.savings() == \
                c.pages_requested - c.pages_fetched, label
        elif isinstance(layer, PrefetchingPageStore):
            # look-ahead charges reads BEFORE their demand access arrives:
            # fetched = demand misses + prefetches, and each prefetched
            # page later hits, so requested <= hits + fetched
            assert c.pages_requested <= c.cache_hits + c.pages_fetched, label
            assert (c.pages_requested
                    == c.cache_hits + c.pages_fetched
                    - layer.prefetch_issued), label
        else:
            assert c.pages_requested == c.cache_hits + c.pages_fetched, label
        if inner is not None:
            # every read this layer charged reached the store it decorates
            assert c.pages_fetched == inner.counters.pages_fetched, label
        if isinstance(layer, ShardedPageStore):
            # the roll-up equals the per-shard sum, field by field
            for f in ("pages_requested", "pages_fetched", "cache_hits",
                      "records_fetched"):
                assert getattr(c, f) == sum(
                    getattr(sc, f) for sc in layer.shard_counters), (label, f)


def test_replay_charges_reach_the_bottom(tiny_layout):
    """Regression for the headline bugfix: under a stateful policy the
    base ArrayPageStore used to stay at ZERO while the top of the stack
    reported device reads — audits disagreed across the stack."""
    store = STACKS["lru"](tiny_layout)
    trace = _trace(3, tiny_layout.num_pages)
    acct = store.replay_batch(trace)
    assert acct["issued"] > 0
    assert isinstance(store, SharedCachePageStore)
    base = store.inner.inner
    assert base.counters.pages_fetched == acct["issued"]
    assert base.counters.records_fetched == acct["issued"] * tiny_layout.n_p
