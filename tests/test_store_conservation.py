"""Counter-conservation property test over every store stack.

A sharded store is only trustworthy if the counter rollup it aggregates is
conserved at every layer, so this walks EVERY build_store composition —
`none` / `static-vertex` / `batched` / `lru` / `2q` / partitioned / sharded
(plain and cached) — through a fixed workload on its own serving path and
asserts, at each decorator:

  1. pages_requested == cache_hits + pages_fetched   (coalescing layers
     additionally bank the dedup: requested - fetched - hits == savings)
  2. the decorator's pages_fetched equals the inner store's movement
     (every read this layer charged reached the device it decorates)
  3. pages_written == data_writes + journal_writes + snapshot_writes, and
     the write totals roll 1:1 to the BOTTOM of every stack (incl. the
     sharded per-shard sum) — the write half of the spine the durability
     layer (repro/mutation/journal.py) bills journal commits on

Both read invariants previously FAILED for
SharedCachePageStore.replay_batch, which booked issued reads only in its
own counters — the bugfix this test pins down. All `-m fast` (tiny
synthetic layouts, no graph build) except the recovery-replay spine test,
which builds one tiny real index."""
import numpy as np
import pytest

from repro.core.pages import build_layout
from repro.io import (BatchedPageStore, PrefetchingPageStore,
                      SharedCachePageStore, ShardedPageStore, build_store)
from repro.mutation import MutablePageStore

pytestmark = pytest.mark.fast

WRITE_FIELDS = ("data_writes", "journal_writes", "snapshot_writes")


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


def _mask(layout):
    n = layout.vid2page.shape[0]
    m = np.zeros(n, bool)
    m[:8] = True
    return m


STACKS = {
    "none": lambda lay: build_store(lay),
    "static-vertex": lambda lay: build_store(
        lay, cached_vertices=_mask(lay), cache_policy="static-vertex"),
    "batched": lambda lay: build_store(lay, batched=True),
    "lru": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes),
    "2q": lambda lay: build_store(
        lay, batched=True, cache_policy="2q",
        cache_bytes=8 * lay.page_bytes),
    "lru-prefetch": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=16 * lay.page_bytes, prefetch=1),
    "partitioned": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes, tenants=2),
    "sharded": lambda lay: build_store(lay, batched=True, shards=3),
    "sharded-cached": lambda lay: build_store(
        lay, batched=True, shards=3, cache_policy="lru",
        cache_bytes=9 * lay.page_bytes),
    # streaming updates: the MutablePageStore wrapper must keep mirroring
    # the stack it decorates on every read path (writes book at its layer)
    "mutable": lambda lay: build_store(lay, batched=True, mutable=True),
    "mutable-lru": lambda lay: build_store(
        lay, batched=True, cache_policy="lru",
        cache_bytes=8 * lay.page_bytes, mutable=True),
    "mutable-sharded": lambda lay: build_store(
        lay, batched=True, shards=3, cache_policy="lru",
        cache_bytes=9 * lay.page_bytes, mutable=True),
}


def _trace(B, num_pages, seed=7):
    """(B, 4, 3) trace with deliberate within- and cross-query reuse."""
    rng = np.random.default_rng(seed)
    t = rng.integers(0, min(num_pages, 12), (B, 4, 3)).astype(np.int32)
    t[rng.random(t.shape) < 0.2] = -1
    return t


def _drive(store, layout):
    """Run the store's own serving path(s) on a fixed workload."""
    trace = _trace(3, layout.num_pages)
    if hasattr(store, "replay_batch"):
        tenants = ([0, 1, 0] if getattr(getattr(store, "cache", None),
                                        "tenant_aware", False) else None)
        store.replay_batch(trace, tenants=tenants)
        store.replay_batch(trace, tenants=tenants)   # warm pass: hits move
    if hasattr(store, "coalesce"):
        vis = np.zeros((3, layout.num_pages), bool)
        vis[0, [0, 1, 2]] = True
        vis[1, [1, 2, 3]] = True
        vis[2, [0, 3, 4]] = True
        store.coalesce(vis)
    # the record-returning paths move the same books
    store.fetch([0, 1, 1, 2])
    if isinstance(store, MutablePageStore):
        # rewrite path: invalidation + write booking + the charged re-read,
        # plus the durability layer's count-only sequential traffic
        store.invalidate([0, 1])
        store.note_write([0, 1])
        store.note_write(kind="journal", count=3)
        store.note_write(kind="snapshot", count=2)
        store.fetch([0, 1])
    if not hasattr(store, "shard_counters"):
        # vertex-granular fetches pass through the shard layer into the
        # roll-up only (static-vertex territory), which would skew the
        # per-shard == roll-up audit below — drive them elsewhere
        # (hasattr sees through the mutable wrapper's delegation)
        vids = np.asarray([2, 9, 40])
        store.fetch(layout.vid2page[vids], vids=vids)


def _layers(store):
    out = [store]
    while hasattr(out[-1], "inner"):
        out.append(out[-1].inner)
    return out


@pytest.mark.parametrize("name", sorted(STACKS))
def test_conservation_at_every_layer(name, tiny_layout):
    store = STACKS[name](tiny_layout)
    _drive(store, tiny_layout)
    layers = _layers(store)
    assert len(layers) >= 1
    for layer, inner in zip(layers, layers[1:] + [None]):
        c = layer.counters
        label = f"{name}:{type(layer).__name__}"
        # write conservation at EVERY layer: total == sum of kinds, and the
        # booking forwarded 1:1 to the layer below (all zeros on stacks the
        # workload never writes to — the invariant still holds)
        assert c.pages_written == sum(
            getattr(c, f) for f in WRITE_FIELDS), label
        if inner is not None:
            for f in WRITE_FIELDS + ("pages_written",):
                assert getattr(c, f) == getattr(inner.counters, f), \
                    (label, f)
        if isinstance(layer, MutablePageStore):
            # the mutable wrapper mirrors EVERY read-path field of the
            # stack it decorates
            for f in ("pages_requested", "pages_fetched", "cache_hits",
                      "records_fetched"):
                assert getattr(c, f) == getattr(inner.counters, f), \
                    (label, f)
            assert c.data_writes == 2, label
            assert c.journal_writes == 3, label
            assert c.snapshot_writes == 2, label
            assert c.pages_written == 7, label
            continue
        if isinstance(layer, (BatchedPageStore, ShardedPageStore)):
            # coalescing layers bank their cross-query dedup as savings,
            # not hits (ShardedPageStore's union path included); hits and
            # savings are disjoint and together close the books
            assert c.pages_requested >= c.cache_hits + c.pages_fetched, label
            assert layer.savings() == \
                c.pages_requested - c.pages_fetched, label
        elif isinstance(layer, PrefetchingPageStore):
            # look-ahead charges reads BEFORE their demand access arrives:
            # fetched = demand misses + prefetches, and each prefetched
            # page later hits, so requested <= hits + fetched
            assert c.pages_requested <= c.cache_hits + c.pages_fetched, label
            assert (c.pages_requested
                    == c.cache_hits + c.pages_fetched
                    - layer.prefetch_issued), label
        else:
            assert c.pages_requested == c.cache_hits + c.pages_fetched, label
        if inner is not None:
            # every read this layer charged reached the store it decorates
            assert c.pages_fetched == inner.counters.pages_fetched, label
        if isinstance(layer, ShardedPageStore):
            # the roll-up equals the per-shard sum, field by field —
            # including the write ledger (data writes land on placement
            # homes, journal/snapshot streams on shard 0)
            for f in ("pages_requested", "pages_fetched", "cache_hits",
                      "records_fetched", "pages_written") + WRITE_FIELDS:
                assert getattr(c, f) == sum(
                    getattr(sc, f) for sc in layer.shard_counters), (label, f)


def test_replay_charges_reach_the_bottom(tiny_layout):
    """Regression for the headline bugfix: under a stateful policy the
    base ArrayPageStore used to stay at ZERO while the top of the stack
    reported device reads — audits disagreed across the stack."""
    store = STACKS["lru"](tiny_layout)
    trace = _trace(3, tiny_layout.num_pages)
    acct = store.replay_batch(trace)
    assert acct["issued"] > 0
    assert isinstance(store, SharedCachePageStore)
    base = store.inner.inner
    assert base.counters.pages_fetched == acct["issued"]
    assert base.counters.records_fetched == acct["issued"] * tiny_layout.n_p


def test_journaled_stack_conserves_writes(tiny_layout):
    """A store-owned journal makes data writes two-phase: the intent
    record's journal pages AND the data pages both land on the write spine
    at every layer, and the journal's own page count agrees with the
    booked journal_writes."""
    from repro.mutation import JournalConfig, MutationJournal
    j = MutationJournal(JournalConfig(group_commit=1,
                                      page_bytes=tiny_layout.page_bytes))
    store = build_store(tiny_layout, batched=True, cache_policy="lru",
                        cache_bytes=8 * tiny_layout.page_bytes,
                        mutable=True, journal=j)
    store.note_write([0, 1, 2])
    store.note_write([4])
    for layer in _layers(store):
        c = layer.counters
        label = type(layer).__name__
        assert c.data_writes == 4, label
        assert c.journal_writes == j.pages_written > 0, label
        assert c.pages_written == c.data_writes + c.journal_writes, label
    # the intent records survive in the log, naming the written pages
    intents = [p for _, k, p in j.replay() if k == "intent"]
    assert intents == [[0, 1, 2], [4]]


@pytest.fixture(scope="module")
def tiny_index():
    from repro.core import build_index, get_preset, make_dataset
    from repro.core.vamana import build_vamana
    ds = make_dataset("deep-like", n=128, nq=4, seed=3)
    G, med, _ = build_vamana(ds.vectors, R=4, L=8, batch=64, seed=3)
    return build_index(ds, get_preset("baseline"), graph=G, medoid_id=med)


def test_recovery_replay_charges_reads_on_spine(tiny_index):
    """recover(attach=[store]) replays the journal's flushes over the
    attached stack: the redo reads go down the `charge` spine and the redo
    writes down the write spine, conserved at every layer — recovery I/O
    is never free."""
    from repro.mutation import (JournalConfig, MutableIndex,
                                MutationConfig, MutationJournal, recover)
    mcfg = MutationConfig(flush_threshold=4, growth_chunk=32, insert_L=8)
    j = MutationJournal(JournalConfig(group_commit=2))
    live = MutableIndex(tiny_index, mcfg, journal=j)
    rng = np.random.default_rng(5)
    for i in range(6):
        live.insert(rng.normal(size=live.d).astype(np.float32))
    live.delete(3)
    live.flush()

    store = build_store(live.layout, batched=True, mutable=True)
    recovered = recover(tiny_index, j, mcfg, attach=[store])
    assert recovered.ops_applied == live.ops_applied
    assert recovered.last_recovery_us > 0
    layers = _layers(store)
    top = store.counters
    # the replayed flush charged its read-modify-write reads and booked
    # its page writes on the attached spine, conserved to the bottom
    assert top.pages_written > 0
    assert top.pages_written == top.data_writes
    for layer in layers:
        c = layer.counters
        label = type(layer).__name__
        assert c.pages_written == top.pages_written, label
        assert c.pages_fetched == top.pages_fetched, label
