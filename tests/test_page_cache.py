"""Stateful page-cache subsystem (repro/io/page_cache) unit tests: policy
semantics and hit-rate ordering on synthetic revisit-heavy traces, shared
cache persistence across batches, look-ahead prefetch accounting, the grown
build_store surface, the BatchedPageStore counter-mirroring fix, and the
device model's prefetch-overlap rebate. Everything runs on tiny synthetic
layouts/traces — no graph build — so it is all `-m fast`."""
import numpy as np
import pytest

from repro.core import SSDModel
from repro.core.pages import build_layout
from repro.io import (DYNAMIC_POLICIES, ArrayPageStore, BatchedPageStore,
                      CachedPageStore, FIFOPageCache, LRUPageCache,
                      PageStore, PartitionedPageCache, PrefetchingPageStore,
                      SharedCachePageStore, TwoQPageCache, build_store,
                      make_cache)

pytestmark = pytest.mark.fast


@pytest.fixture()
def tiny_layout():
    rng = np.random.default_rng(0)
    n, d, R = 64, 8, 4
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, (n, R)).astype(np.int32)
    return build_layout(vectors, graph, page_bytes=256)


def _hit_rate(cache, seq) -> float:
    return sum(cache.access(p) for p in seq) / len(seq)


def _trace(*hop_rows, width=None):
    """Build a (1, H, W) page_trace from per-hop page lists, -1 padded."""
    w = width or max(len(r) for r in hop_rows)
    t = np.full((1, len(hop_rows), w), -1, np.int32)
    for h, row in enumerate(hop_rows):
        t[0, h, :len(row)] = row
    return t


# --- replacement-policy semantics ------------------------------------------


def test_policy_capacity_and_make_cache_validation():
    with pytest.raises(ValueError, match="capacity_pages=0"):
        LRUPageCache(0)
    with pytest.raises(ValueError, match="unknown cache policy"):
        make_cache("arc", 4096, 4096)
    with pytest.raises(ValueError, match="holds no"):
        make_cache("lru", 100, 4096)
    assert isinstance(make_cache("2q", 10 * 4096, 4096), TwoQPageCache)
    assert make_cache("fifo", 10 * 4096, 4096).capacity == 10


def test_lru_renews_residency_fifo_does_not():
    seq = [0, 1, 0, 2, 0, 3, 0, 4]   # page 0 re-touched before each insert
    lru, fifo = LRUPageCache(2), FIFOPageCache(2)
    assert _hit_rate(lru, seq) == pytest.approx(3 / 8)   # every 0-revisit hits
    assert _hit_rate(fifo, seq) < 3 / 8                  # 0 ages out anyway
    assert 0 in lru and len(lru) == 2


def test_hit_rate_ordering_recency_heavy_trace():
    """One hot page interleaved with one-touch fillers: recency wins —
    LRU >= 2Q > FIFO."""
    seq, f = [], 100
    for _ in range(200):
        seq.extend((0, f))
        f += 1
    rates = {c.name: _hit_rate(c(4), seq)
             for c in (LRUPageCache, FIFOPageCache, TwoQPageCache)}
    assert rates["lru"] >= rates["2q"] > rates["fifo"], rates
    assert rates["lru"] > 0.45


def test_hit_rate_ordering_scan_heavy_trace():
    """A small revisited hot set buried in a one-touch scan: the scan
    flushes LRU and FIFO completely, while 2Q's probation queue keeps the
    scan out of the protected set — the classic 2Q win."""
    seq, f = [], 1000
    for i in range(600):
        seq.append(i % 4)                 # hot set of 4
        seq.extend(range(f, f + 3))       # 3 one-touch scan pages
        f += 3
    rates = {c.name: _hit_rate(c(8), seq)
             for c in (LRUPageCache, FIFOPageCache, TwoQPageCache)}
    assert rates["2q"] > rates["lru"] == rates["fifo"] == 0.0, rates
    assert rates["2q"] > 0.2


def test_2q_reset_and_membership():
    c = TwoQPageCache(8)
    for p in (1, 2, 3, 1, 1):
        c.access(p)
    assert 1 in c and len(c) >= 2
    c.reset()
    assert len(c) == 0 and 1 not in c


# --- SharedCachePageStore: trace replay + cross-batch persistence ----------


def test_replay_accounting_and_counters(tiny_layout):
    store = SharedCachePageStore(ArrayPageStore(tiny_layout),
                                 LRUPageCache(8))
    assert isinstance(store, PageStore)
    acct = store.replay_batch(_trace([0, 1], [1, 2], [0]))
    # hop order: 0,1 miss; 1 hits (resident), 2 misses; 0 hits
    assert acct == {"requested": 5, "issued": 3, "hits": 2,
                    "per_query_issued": acct["per_query_issued"],
                    "prefetch_issued": 0, "overlap_frac": 0.0,
                    "hit_rate": 2 / 5,
                    "per_tenant": {0: {"requested": 5, "hits": 2,
                                       "issued": 3, "hit_rate": 2 / 5}}}
    np.testing.assert_array_equal(acct["per_query_issued"], [3.0])
    assert store.tenant_hit_rates() == {0: 2 / 5}
    c = store.counters
    assert (c.pages_requested, c.pages_fetched, c.cache_hits) == (5, 3, 2)
    assert c.records_fetched == 3 * tiny_layout.n_p
    assert store.hit_rate() == pytest.approx(2 / 5)


def test_shared_cache_persists_across_batches(tiny_layout):
    """The decisive difference from BatchedPageStore: pages fetched by one
    batch serve the next batch from memory."""
    store = SharedCachePageStore(ArrayPageStore(tiny_layout),
                                 LRUPageCache(16))
    first = store.replay_batch(_trace([0, 1, 2], [3, 4]))
    assert first["hits"] == 0
    second = store.replay_batch(_trace([2, 3], [0, 5]))
    assert second["hits"] == 3          # 2, 3, 0 warmed by batch one
    assert second["issued"] == 1        # only page 5 reaches the device
    # a batch-local coalescer must charge all 4 distinct pages of batch two
    batched = BatchedPageStore(ArrayPageStore(tiny_layout))
    vis = np.zeros((1, tiny_layout.num_pages), bool)
    vis[0, [2, 3, 0, 5]] = True
    assert batched.coalesce(vis)["issued"] == 4 > second["issued"]


def test_warm_lru_replay_beats_batch_union(tiny_layout):
    """Acceptance shape (unit scale): with a warm cache the same trace
    replays with strictly fewer device reads than the cross-query union."""
    trace = np.stack([
        _trace([0, 1], [2, 3])[0],
        _trace([1, 2], [4])[0]])
    shared = SharedCachePageStore(ArrayPageStore(tiny_layout),
                                  LRUPageCache(32))
    shared.replay_batch(trace)                    # cold pass warms the cache
    warm = shared.replay_batch(trace)
    union = BatchedPageStore(ArrayPageStore(tiny_layout))
    vis = np.zeros((2, tiny_layout.num_pages), bool)
    vis[0, [0, 1, 2, 3]] = True
    vis[1, [1, 2, 4]] = True
    issued_union = union.coalesce(vis)["issued"]
    assert warm["issued"] == 0 < issued_union
    assert warm["hit_rate"] == 1.0


def test_replay_rejects_malformed_trace(tiny_layout):
    store = SharedCachePageStore(ArrayPageStore(tiny_layout), LRUPageCache(4))
    with pytest.raises(ValueError, match="page_trace must be"):
        store.replay_batch(np.zeros((2, 5), np.int32))


def test_shared_cache_fetch_path_hits_and_forwards(tiny_layout):
    inner = ArrayPageStore(tiny_layout)
    store = SharedCachePageStore(inner, LRUPageCache(8))
    out = store.fetch([0, 1, 0])
    np.testing.assert_array_equal(out["vids"][0], tiny_layout.page_vids[0])
    np.testing.assert_array_equal(out["vids"][2], out["vids"][0])
    assert store.counters.cache_hits == 1       # the repeated 0
    assert store.counters.pages_fetched == 2
    assert inner.counters.pages_fetched == 2    # misses reach the device
    out2 = store.fetch([1])                     # warmed by the first fetch
    assert store.counters.cache_hits == 2
    assert inner.counters.pages_fetched == 2
    np.testing.assert_allclose(out2["vecs"][0], tiny_layout.page_vecs[1])


# --- PrefetchingPageStore: look-ahead + overlap accounting -----------------


def test_prefetch_overlap_accounting(tiny_layout):
    store = PrefetchingPageStore(ArrayPageStore(tiny_layout),
                                 LRUPageCache(32), lookahead=1)
    acct = store.replay_batch(_trace([0, 1], [2, 3], [4]))
    # hop 0: prefetch {2,3}; hop 1 accesses hit; hop 1: prefetch {4}; hits
    assert acct["prefetch_issued"] == 3
    assert acct["issued"] == 5                  # same device reads in total
    assert acct["hits"] == 3                    # ...but 3 arrive early
    assert acct["overlap_frac"] == pytest.approx(3 / 5)
    assert store.prefetch_issued == 3


def test_prefetch_same_total_io_as_pure_cache(tiny_layout):
    """Look-ahead hides latency; it must not change the number of device
    reads when the cache is big enough to hold the prefetched pages."""
    trace = _trace([0, 1], [2, 3], [0, 4], [5])
    pure = SharedCachePageStore(ArrayPageStore(tiny_layout),
                                LRUPageCache(32))
    pf = PrefetchingPageStore(ArrayPageStore(tiny_layout),
                              LRUPageCache(32), lookahead=2)
    a, b = pure.replay_batch(trace), pf.replay_batch(trace)
    assert a["issued"] == b["issued"] == 6
    assert b["overlap_frac"] > a["overlap_frac"] == 0.0


def test_prefetching_store_requires_lookahead():
    with pytest.raises(ValueError, match="lookahead=0"):
        PrefetchingPageStore(None, LRUPageCache(4), lookahead=0)
    with pytest.raises(ValueError, match="lookahead=-1"):
        SharedCachePageStore(None, LRUPageCache(4), lookahead=-1)


# --- build_store surface ---------------------------------------------------


def test_build_store_cache_policy_surface(tiny_layout):
    lru = build_store(tiny_layout, batched=True, cache_policy="lru",
                      cache_bytes=8 * tiny_layout.page_bytes)
    assert isinstance(lru, SharedCachePageStore)
    assert not isinstance(lru, PrefetchingPageStore)
    assert isinstance(lru.inner, BatchedPageStore)
    assert isinstance(lru.cache, LRUPageCache) and lru.cache.capacity == 8

    pf = build_store(tiny_layout, cache_policy="2q",
                     cache_bytes=8 * tiny_layout.page_bytes, prefetch=2)
    assert isinstance(pf, PrefetchingPageStore) and pf.lookahead == 2
    assert isinstance(pf.cache, TwoQPageCache)

    n = tiny_layout.vid2page.shape[0]
    sv = build_store(tiny_layout, cached_vertices=np.ones(n, bool),
                     cache_policy="static-vertex")
    assert isinstance(sv, CachedPageStore)
    assert set(DYNAMIC_POLICIES) == {"lru", "fifo", "2q"}


def test_build_store_surface_validation(tiny_layout):
    with pytest.raises(ValueError, match="unknown cache_policy"):
        build_store(tiny_layout, cache_policy="arc")
    with pytest.raises(ValueError, match="static-vertex"):
        build_store(tiny_layout, cache_policy="static-vertex")
    with pytest.raises(ValueError, match="prefetch=1"):
        build_store(tiny_layout, prefetch=1)
    with pytest.raises(ValueError, match="holds no"):
        build_store(tiny_layout, cache_policy="lru", cache_bytes=0)


# --- bugfix: replay_batch forwards the misses' charge to the inner store ---


def test_replay_batch_charges_inner_store(tiny_layout):
    """Regression: replay booked issued reads only in its own counters, so
    ArrayPageStore/BatchedPageStore stayed at zero under stateful policies
    and cross-stack rollups disagreed with the top of the stack."""
    inner = BatchedPageStore(ArrayPageStore(tiny_layout))
    store = SharedCachePageStore(inner, LRUPageCache(4))
    acct = store.replay_batch(_trace([0, 1], [2, 3], [0, 1]))
    assert acct["issued"] == 4 and acct["hits"] == 2
    # conservation: every layer saw exactly the issued reads
    assert store.counters.pages_fetched == 4
    assert inner.counters.pages_fetched == 4
    assert inner.inner.counters.pages_fetched == 4
    assert inner.inner.counters.records_fetched == 4 * tiny_layout.n_p


def test_replay_eviction_remiss_is_charged_twice_downstream(tiny_layout):
    """A page evicted and missed again IS two device reads; the coalescing
    inner store must not dedup the genuine re-read."""
    inner = BatchedPageStore(ArrayPageStore(tiny_layout))
    store = SharedCachePageStore(inner, LRUPageCache(2))
    acct = store.replay_batch(_trace([0, 1], [2], [0]))   # 2 evicts 0; 0 again
    assert acct["issued"] == 4                            # page 0 twice
    assert inner.counters.pages_fetched == 4
    assert inner.inner.counters.pages_fetched == 4


# --- bugfix: look-ahead admits without demand accounting -------------------


def test_admit_does_not_move_partition_demand_stats():
    c = PartitionedPageCache(8, 2, rebalance_every=4)
    c.admit(0, 0)
    c.admit(1, 1)
    assert c.t_accesses == [0, 0] and c.t_hits == [0, 0]
    assert c._since == 0 and all(len(sh) == 0 for sh in c._shadow)
    # the pages ARE resident (that is admit's whole job)
    assert c.access(0, 0) and c.access(1, 1)
    assert c.t_accesses == [1, 1] and c.t_hits == [1, 1]


def test_prefetch_rebalance_decisions_match_pure_cache(tiny_layout):
    """Bugfix acceptance: look-ahead used the demand access(page, tenant)
    path, inflating t_accesses/t_hits and the shadow-gain window — with the
    non-demand admit path, rebalance decisions (capacity moves, rebalance
    count, demand access totals) are identical with and without prefetch on
    a fixed trace."""
    def batch():
        # query 0 / tenant 0: multi-hop over a resident working set (the
        # prefetchable traffic); query 1 / tenant 1: single-hop cycling a
        # set larger than its partition (the gain-accruing traffic that
        # look-ahead cannot touch)
        t0 = _trace([0, 1], [2, 3], [4, 5])[0]
        return t0

    def run(store):
        cyc = 0
        for _ in range(10):
            t0 = batch()
            t1 = np.full_like(t0, -1)
            t1[0, :2] = [6 + cyc % 10, 6 + (cyc + 1) % 10]
            cyc += 2
            store.replay_batch(np.stack([t0, t1]), tenants=[0, 1])
        return store.cache

    mk = lambda: PartitionedPageCache(16, 2, rebalance_every=20,
                                      rebalance_step=2)
    pure = run(SharedCachePageStore(ArrayPageStore(tiny_layout), mk()))
    pf = run(PrefetchingPageStore(ArrayPageStore(tiny_layout), mk(),
                                  lookahead=1))
    # demand accounting is prefetch-blind: same accesses, same windows
    assert pf.t_accesses == pure.t_accesses
    assert pf.rebalances == pure.rebalances > 0
    assert pf.capacities() == pure.capacities()
    # the rebalance moved capacity toward the gaining tenant in both
    assert pure.capacities()[1] > 8


# --- bugfix: make_cache names the byte budget in the tenant-floor error ----


def test_make_cache_tenant_floor_error_names_bytes(tiny_layout):
    with pytest.raises(ValueError, match=r"cache_bytes=4096 is only 1 "
                                         r"page\(s\) of 4096 bytes"):
        make_cache("lru", 4096, 4096, tenants=3)
    with pytest.raises(ValueError, match="need cache_bytes >= 12288"):
        make_cache("lru", 4096, 4096, tenants=3)
    # build_store surfaces the same byte-level message
    with pytest.raises(ValueError, match="1-page floor"):
        build_store(tiny_layout, cache_policy="lru",
                    cache_bytes=2 * tiny_layout.page_bytes, tenants=3)
    # the floor passes exactly at tenants * page_bytes
    c = make_cache("lru", 3 * 4096, 4096, tenants=3)
    assert c.capacities() == [1, 1, 1]


# --- satellite: BatchedPageStore mirrors the full counter movement ---------


def test_batched_store_mirrors_hits_and_records(tiny_layout):
    """Regression: the vids pass-through mirrored only pages_fetched, so
    savings() and rollups disagreed with the inner cache store."""
    n = tiny_layout.vid2page.shape[0]
    cached = np.zeros(n, bool)
    cached[:4] = True
    mid = CachedPageStore(ArrayPageStore(tiny_layout), cached)
    store = BatchedPageStore(mid)
    vids = np.asarray([1, 30, 30])          # vid 1 cached, 30s are misses
    store.fetch(tiny_layout.vid2page[vids], vids=vids)
    assert store.counters.cache_hits == mid.counters.cache_hits == 1
    assert store.counters.pages_fetched == mid.counters.pages_fetched == 2
    assert store.counters.records_fetched \
        == mid.counters.records_fetched == 2 * tiny_layout.n_p
    assert store.savings() == 1             # the hit really was saved I/O


def test_cached_store_counts_records_on_page_requests(tiny_layout):
    n = tiny_layout.vid2page.shape[0]
    store = CachedPageStore(ArrayPageStore(tiny_layout),
                            np.zeros(n, bool))
    store.fetch([0, 1])
    assert store.counters.records_fetched == 2 * tiny_layout.n_p
    assert store.counters.records_fetched \
        == store.inner.counters.records_fetched


# --- device model: prefetch-overlap rebate ---------------------------------


def test_prefetch_overlap_rebate_monotone_and_bounded():
    m = SSDModel()
    kw = dict(hops=np.array([10.0]), pages=np.array([40.0]),
              full_evals=np.array([200.0]), pq_evals=np.array([900.0]),
              mem_evals=np.array([0.0]), d=96, pq_m=16, page_bytes=4096)
    base = float(m.concurrent_latency_us(8, **kw)[0])
    lats = [float(m.concurrent_latency_us(8, prefetch_overlap=f, **kw)[0])
            for f in (0.0, 0.25, 0.5, 1.0)]
    assert lats[0] == pytest.approx(base)            # rebate off == before
    assert all(a >= b for a, b in zip(lats, lats[1:])), lats
    assert lats[-1] < lats[0]
    # hidden I/O is capped by the compute actually available
    comp = float(m._compute_us(kw["full_evals"], kw["pq_evals"],
                               kw["mem_evals"], kw["d"], kw["pq_m"])[0])
    assert base - lats[-1] <= comp + 1e-9


# --- PartitionedPageCache: multi-tenant partitioning -----------------------


def test_partitioned_single_tenant_degenerates_to_base_policy():
    """Acceptance: with one tenant the partition gets the whole budget and
    every access routes straight through — the hit/miss sequence is
    bit-identical to the bare policy, for every policy."""
    rng = np.random.default_rng(3)
    seq = rng.integers(0, 48, 2000)
    for cls in (LRUPageCache, FIFOPageCache, TwoQPageCache):
        base = cls(12)
        part = PartitionedPageCache(12, 1, policy=cls.name)
        for p in seq:
            assert base.access(int(p)) == part.access(int(p)), cls.name
        assert len(base) == len(part)


def test_partitioned_share_allocation_and_validation():
    c = PartitionedPageCache(10, 3, shares=[0.5, 0.3, 0.2])
    assert c.capacities() == [5, 3, 2]
    # 1-page floor even for a vanishing share
    c = PartitionedPageCache(8, 2, shares=[0.999, 0.001])
    assert c.capacities() == [7, 1]
    assert sum(PartitionedPageCache(7, 3).capacities()) == 7
    with pytest.raises(ValueError, match="tenants=0"):
        PartitionedPageCache(8, 0)
    with pytest.raises(ValueError, match="1-page floor"):
        PartitionedPageCache(2, 3)
    with pytest.raises(ValueError, match="3 entries for 2 tenants"):
        PartitionedPageCache(8, 2, shares=[1, 1, 1])
    with pytest.raises(ValueError, match="must all be positive"):
        PartitionedPageCache(8, 2, shares=[1.0, 0.0])
    with pytest.raises(ValueError, match="unknown partition policy"):
        PartitionedPageCache(8, 2, policy="arc")


def test_partitioned_isolates_noisy_neighbor():
    """The partition IS the isolation: a tenant-1 scan that would flush a
    shared LRU cannot touch tenant 0's resident hot set."""
    shared = LRUPageCache(8)
    part = PartitionedPageCache(8, 2)          # 4 pages each
    for p in range(4):                         # tenant 0's hot set
        shared.access(p)
        part.access(p, 0)
    for p in range(100, 180):                  # tenant 1's one-touch scan
        shared.access(p)
        part.access(p, 1)
    assert all(p not in shared for p in range(4))      # flushed
    hits_shared = sum(shared.access(p) for p in range(4))
    hits_part = sum(part.access(p, 0) for p in range(4))
    assert hits_shared == 0 and hits_part == 4
    assert part.tenant_hit_rates()[1] == 0.0   # the scan never re-used


def test_partitioned_rebalance_moves_capacity_to_utility():
    """Utility rebalance: a tenant whose misses the doubled-capacity shadow
    would convert takes pages from a tenant with no marginal gain; the
    total budget is conserved and the donor keeps its 1-page floor."""
    c = PartitionedPageCache(16, 2, shares=[3, 1], rebalance_every=40,
                             rebalance_step=2)
    for i in range(4000):
        c.access(i % 6, 0)     # hot set of 6 in 12 pages: zero marginal gain
        c.access(i % 8, 1)     # cycle of 8 in 4 pages: every miss convertible
    assert c.rebalances > 0
    assert c.capacities()[1] >= 8, c.capacities()
    assert sum(c.capacities()) == 16
    # the donor was never squeezed below its own working set
    assert c.tenant_hit_rates()[0] > 0.9
    assert c.tenant_hit_rates()[1] > 0.5


def test_partitioned_static_shares_do_not_move():
    c = PartitionedPageCache(16, 2, shares=[3, 1])      # rebalance off
    for i in range(2000):
        c.access(i % 6, 0)
        c.access(i % 8, 1)
    assert c.capacities() == [12, 4] and c.rebalances == 0


def test_policy_resize_evicts_in_policy_order():
    lru = LRUPageCache(4)
    for p in (0, 1, 2, 3):
        lru.access(p)
    lru.access(0)               # renew 0: LRU order is now 1,2,3,0
    lru.resize(2)
    assert 3 in lru and 0 in lru and 1 not in lru and 2 not in lru
    fifo = FIFOPageCache(4)
    for p in (0, 1, 2, 3):
        fifo.access(p)
    fifo.access(0)              # FIFO: renewal does not matter
    fifo.resize(2)
    assert 2 in fifo and 3 in fifo and 0 not in fifo
    q = TwoQPageCache(8)
    for p in range(6):
        q.access(p)
    q.resize(4)
    assert len(q) <= 4
    with pytest.raises(ValueError, match="capacity_pages=0"):
        lru.resize(0)
    with pytest.raises(NotImplementedError):
        PartitionedPageCache(8, 2).resize(16)


def test_replay_batch_routes_tenants_to_partitions(tiny_layout):
    """Two queries on different tenants: each warms only its own partition,
    and the per-tenant accounting splits exactly."""
    cache = PartitionedPageCache(8, 2)
    store = SharedCachePageStore(ArrayPageStore(tiny_layout), cache)
    trace = np.stack([_trace([0, 1], [2])[0], _trace([0, 1], [3])[0]])
    acct = store.replay_batch(trace, tenants=[0, 1])
    # no sharing across partitions: tenant 1 re-misses pages 0 and 1
    assert acct["hits"] == 0 and acct["issued"] == 6
    assert acct["per_tenant"][0] == {"requested": 3, "hits": 0, "issued": 3,
                                     "hit_rate": 0.0}
    assert acct["per_tenant"][1]["issued"] == 3
    # second replay: each tenant hits its own warmed partition
    acct2 = store.replay_batch(trace, tenants=[0, 1])
    assert acct2["hits"] == 6 and acct2["issued"] == 0
    assert store.tenant_hit_rates() == {0: 0.5, 1: 0.5}
    assert cache.tenant_hit_rates() == [0.5, 0.5]
    with pytest.raises(ValueError, match="2 entries for a 1-query"):
        store.replay_batch(_trace([0]), tenants=[0, 1])
    with pytest.raises(ValueError, match=">= 0"):
        store.replay_batch(_trace([0]), tenants=[-1])
    with pytest.raises(ValueError, match="out of range"):
        store.replay_batch(_trace([0]), tenants=[5])


def test_build_store_tenant_surface(tiny_layout):
    st = build_store(tiny_layout, batched=True, cache_policy="2q",
                     cache_bytes=8 * tiny_layout.page_bytes, tenants=2,
                     tenant_shares=(0.75, 0.25), rebalance_every=64)
    assert isinstance(st.cache, PartitionedPageCache)
    assert st.cache.policy == "2q"
    assert st.cache.capacities() == [6, 2]
    assert st.cache.rebalance_every == 64
    one = build_store(tiny_layout, cache_policy="lru",
                      cache_bytes=8 * tiny_layout.page_bytes, tenants=1)
    assert isinstance(one.cache, LRUPageCache)   # no partition wrapper
    with pytest.raises(ValueError, match="tenants=0"):
        build_store(tiny_layout, cache_policy="lru",
                    cache_bytes=8 * tiny_layout.page_bytes, tenants=0)
    with pytest.raises(ValueError, match="stateful page cache"):
        build_store(tiny_layout, tenants=2)
