"""Sharding-rule validation for every (arch x mesh) without compiling the
production mesh (that's the dry-run's job): each PartitionSpec axis must
divide its dimension, MoE specs must agree between GSPMD rules and the
shard_map body, and the smoke configs must run under a real (1-device) mesh
through the pjit path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config, get_shape
from repro.models import abstract_params, input_specs, loss_fn, init_params
from repro.parallel.api import ParallelContext
from repro.parallel import sharding as sh


class FakeMesh:
    """Shape-only mesh stand-in (no devices needed for rule validation)."""

    def __init__(self, shape: dict):
        self.shape = shape

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


def _check_tree(ctx, specs, shapes):
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_a = jax.tree.leaves(shapes)
    assert len(flat_s) == len(flat_a)
    for spec, leaf in zip(flat_s, flat_a):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= ctx.mesh.shape[a]
            assert dim % size == 0, (spec, leaf.shape, dim, size)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                    else {"data": 16, "model": 16})
    ctx = ParallelContext(mesh)  # type: ignore[arg-type]
    ap = abstract_params(cfg)
    specs = sh.param_pspecs(ctx, cfg, ap)
    _check_tree(ctx, specs, ap)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_input_and_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    ctx = ParallelContext(mesh)  # type: ignore[arg-type]
    specs = input_specs(cfg, shape)
    pspecs = sh.batch_pspecs(ctx, cfg, specs)
    _check_tree(ctx, pspecs, specs)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "qwen2-moe-a2.7b",
                                  "jamba-v0.1-52b"])
def test_moe_expert_padding_divides_ep(arch):
    cfg = get_config(arch)
    assert cfg.moe.padded_experts % 16 == 0


def test_moe_shardmap_matches_local(small_dataset=None):
    """EP shard_map MoE == single-device MoE on a real 1x2 mesh."""
    if jax.device_count() < 1:
        pytest.skip("no devices")
    from repro.models import moe as MOE
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    # pad experts so EP=2 divides when we fake a model axis of 1
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_local, aux = MOE.apply_moe(p, x, cfg, parallel=None)
    assert np.isfinite(np.asarray(y_local)).all()
    assert float(aux) > 0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b"])
def test_smoke_config_under_real_mesh(arch):
    """pjit path end-to-end on the 1-device mesh (constraints exercised)."""
    cfg = get_smoke_config(arch)
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    ctx = ParallelContext(mesh)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    loss, _ = jax.jit(lambda p, b: loss_fn(p, cfg, b, parallel=ctx))(
        params, batch)
    assert np.isfinite(float(loss))


def test_kimi_pod_fsdp_rule():
    cfg = get_config("kimi-k2-1t-a32b")
    ctx = ParallelContext(FakeMesh({"pod": 2, "data": 16, "model": 16}))  # type: ignore
    w = ctx.moe_weight_axes(cfg)
    assert w == {"d_ff": "data", "d_model": "pod"}
    small = get_config("qwen2-moe-a2.7b")
    w2 = ctx.moe_weight_axes(small)
    assert w2["d_model"] is None  # only the 1T-class shards over pod
