"""Property-based tests (hypothesis, optional) for the engine's invariants +
unit tests for PQ / layouts / Vamana pruning. When hypothesis is not
installed the property tests skip and the rest of the module still runs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.searchutils import INF, SENTINEL, dedup_merge_topL

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False


if HAS_HYPOTHESIS:
    @st.composite
    def id_key_flag_arrays(draw):
        n = draw(st.integers(2, 80))
        ids = draw(st.lists(st.integers(0, 20), min_size=n, max_size=n))
        # XLA flushes subnormals to zero; keep keys in the normal f32 range
        keys = draw(st.lists(
            st.floats(9.999999974752427e-07, 1e6, allow_nan=False, width=32),
            min_size=n, max_size=n))
        flags = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        L = draw(st.integers(1, n))
        return ids, keys, flags, L

    @given(id_key_flag_arrays())
    @settings(max_examples=60, deadline=None)
    def test_dedup_merge_properties(data):
        ids, keys, flags, L = data
        i, k, f = dedup_merge_topL(
            jnp.asarray(ids, jnp.int32),
            jnp.asarray(keys, jnp.float32)[:, None],
            jnp.asarray(flags, bool)[:, None], L)
        i, k, f = np.asarray(i), np.asarray(k[:, 0]), np.asarray(f[:, 0])
        real = i[i < int(SENTINEL)]
        # unique ids
        assert len(set(real.tolist())) == len(real)
        # sorted by key
        kk = k[: len(real)]
        assert np.all(np.diff(kk) >= -1e-6)
        # min-key and OR-flag per id (exact reference)
        want = {}
        for id_, key_, fl in zip(ids, keys, flags):
            if id_ not in want:
                want[id_] = [key_, fl]
            else:
                want[id_][0] = min(want[id_][0], key_)
                want[id_][1] = want[id_][1] or fl
        for idx, id_ in enumerate(real.tolist()):
            np.testing.assert_allclose(k[idx], want[id_][0], rtol=1e-6)
            assert f[idx] == want[id_][1]
        # top-L: kept keys <= smallest dropped key
        if len(want) > L:
            dropped = sorted(v[0] for v in want.values())[L:]
            assert kk[-1] <= dropped[0] + 1e-6

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_quantize_roundtrip_bounded(seed):
        from repro.training.compression import dequantize, quantize
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(0, rng.uniform(1e-5, 10), (64,)),
                        jnp.float32)
        q, s = quantize(g)
        err = np.abs(np.asarray(dequantize(q, s) - g))
        assert err.max() <= float(s) / 2 + 1e-9  # half-ulp of the int8 grid
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_dedup_merge_properties():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_quantize_roundtrip_bounded():
        pass


def test_error_feedback_unbiased():
    from repro.training.compression import ef_compress_tree, init_error_state
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)}
    e = init_error_state(g)
    total_sent = np.zeros(256)
    steps = 50
    for _ in range(steps):
        sent, e = ef_compress_tree(g, e)
        total_sent += np.asarray(sent["w"])
    # long-run average of transmitted grads converges to the true grad
    np.testing.assert_allclose(total_sent / steps, np.asarray(g["w"]),
                               atol=2e-2)


def test_pq_error_decreases_with_m():
    from repro.core.pq import train_pq
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2048, 64)).astype(np.float32)
    q = rng.normal(size=(64,)).astype(np.float32)
    true = ((x - q) ** 2).sum(1)
    errs = []
    for m in (4, 16):
        pq = train_pq(x, m=m, sample=2048, iters=6)
        approx = pq.adc(q, np.arange(len(x)))
        errs.append(np.abs(approx - true).mean())
    assert errs[1] < errs[0]


def test_layout_roundtrip(small_dataset, small_graph):
    from repro.core.pages import build_layout
    G, _, _ = small_graph
    lay = build_layout(small_dataset.vectors, G)
    n = small_dataset.n
    vids = np.arange(n)
    back = lay.page_vids[lay.vid2page[vids], lay.vid2slot[vids]]
    np.testing.assert_array_equal(back, vids)
    # record contents match source
    np.testing.assert_allclose(
        lay.page_vecs[lay.vid2page[:50], lay.vid2slot[:50]],
        small_dataset.vectors[:50], rtol=1e-6)
    np.testing.assert_array_equal(
        lay.page_nbrs[lay.vid2page[:50], lay.vid2slot[:50]], G[:50])


def test_shuffle_perm_is_permutation(small_dataset, small_graph):
    from repro.core.page_shuffle import shuffle_order
    G, med, _ = small_graph
    out = shuffle_order(G, med, n_p=7)
    perm = out["perm"]
    assert sorted(perm.tolist()) == list(range(small_dataset.n))


def test_robust_prune_degree_and_self(small_dataset):
    from repro.core.vamana import _robust_prune_batch
    from repro.core.searchutils import SENTINEL
    x = jnp.asarray(small_dataset.vectors[:256])
    ids = jnp.arange(8, dtype=jnp.int32)
    cand = jnp.tile(jnp.arange(64, dtype=jnp.int32)[None], (8, 1))
    cd = jnp.asarray(np.linalg.norm(
        small_dataset.vectors[:64][None] - small_dataset.vectors[:8][:, None],
        axis=-1) ** 2)
    out = np.asarray(_robust_prune_batch(x, ids, cand, cd, R=16, alpha=1.2))
    for i in range(8):
        row = out[i][out[i] >= 0]
        assert i not in row.tolist()               # no self edge
        assert len(set(row.tolist())) == len(row)  # unique
        assert len(row) <= 16


def test_aisaq_layout_tradeoff(small_dataset, small_graph):
    """AiS: bigger records -> fewer records/page -> more disk, ~zero memory."""
    from repro.core import build_index, get_preset
    G, med, _ = small_graph
    idx_b = build_index(small_dataset, get_preset("baseline"),
                        graph=G, medoid_id=med)
    idx_a = build_index(small_dataset, get_preset("aisaq"),
                        graph=G, medoid_id=med)
    assert idx_a.layout.n_p <= idx_b.layout.n_p
    assert idx_a.layout.disk_bytes >= idx_b.layout.disk_bytes
    assert idx_a.memory_bytes() < idx_b.memory_bytes()
