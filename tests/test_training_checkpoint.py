"""TRAINING-side durability and determinism: `repro.training.checkpoint`
roundtrip/prune, the data pipeline's die-and-resume, the optimizer, and
the straggler monitor.

(Previously named test_fault_tolerance.py, which made `pytest -k fault`
select training tests while the SERVING-side fault story lives in
tests/test_durability.py — the crash/recover sweep over the mutable
index's write-ahead journal.)"""
import json
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_checkpoint_roundtrip(tmp_path):
    from repro.training import checkpoint as ck
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(tmp_path, 5, tree)
    ck.save(tmp_path, 10, jax.tree.map(lambda x: x * 2, tree))
    assert ck.latest_step(tmp_path) == 10
    restored, step = ck.restore(tmp_path, tree)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(10) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_prune_keeps_k(tmp_path):
    from repro.training import checkpoint as ck
    tree = {"a": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, tree, keep=2)
    assert ck.latest_step(tmp_path) == 5
    restored, step = ck.restore(tmp_path, tree, step=4)
    assert step == 4
    with pytest.raises(FileNotFoundError):
        ck.restore(tmp_path, tree, step=1)


def test_data_pipeline_deterministic_and_host_disjoint():
    from repro.data.pipeline import DataConfig, TokenPipeline
    a = TokenPipeline(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    b = TokenPipeline(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    np.testing.assert_array_equal(a.batch(7)["tokens"], b.batch(7)["tokens"])
    h0 = TokenPipeline(DataConfig(vocab_size=100, seq_len=16, global_batch=8,
                                  num_hosts=2, host_index=0))
    h1 = TokenPipeline(DataConfig(vocab_size=100, seq_len=16, global_batch=8,
                                  num_hosts=2, host_index=1))
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])
    assert h0.batch(0)["tokens"].shape == (4, 16)


def test_train_die_and_resume_reproduces_trajectory(tmp_path):
    """End-to-end restart drill: a run killed at step 15 and resumed must
    land on the same final loss as an uninterrupted run."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "tinyllama-1.1b", "--smoke", "--steps", "24", "--batch", "2",
            "--seq", "32", "--ckpt-every", "8", "--log-every", "100"]
    m_all = tmp_path / "all.json"
    subprocess.run(base + ["--metrics-out", str(m_all)], env=env, check=True,
                   capture_output=True)
    ck = tmp_path / "ck"
    r = subprocess.run(base + ["--ckpt-dir", str(ck), "--die-at", "15"],
                       env=env, capture_output=True)
    assert r.returncode == 42  # simulated failure
    m_res = tmp_path / "res.json"
    subprocess.run(base + ["--ckpt-dir", str(ck), "--resume",
                           "--metrics-out", str(m_res)], env=env, check=True,
                   capture_output=True)
    full = json.load(open(m_all))["losses"]
    res = json.load(open(m_res))
    assert res["start"] == 8
    np.testing.assert_allclose(res["losses"][-1], full[-1], rtol=1e-4)


def test_adamw_converges_quadratic():
    from repro.training import optim
    opt = optim.OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.init_state(params, opt)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = optim.apply_updates(params, g, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_factored_second_moment_tracks_full():
    from repro.training import optim
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    pf = {"w": jnp.zeros((32, 48))}
    opt_full = optim.OptimizerConfig(lr=0.01, weight_decay=0.0,
                                     factored=False, total_steps=100)
    opt_fac = optim.OptimizerConfig(lr=0.01, weight_decay=0.0, factored=True,
                                    min_factored_size=1, total_steps=100)
    sf = optim.init_state(pf, opt_full)
    sa = optim.init_state(pf, opt_fac)
    assert "vr" in sa["mu"]["w"] and "v" in sf["mu"]["w"]
    p1, p2 = pf, pf
    for _ in range(20):
        p1, sf, _ = optim.apply_updates(p1, {"w": g}, sf, opt_full)
        p2, sa, _ = optim.apply_updates(p2, {"w": g}, sa, opt_fac)
    # the rank-1 second moment is an approximation (that's the point —
    # O(n+m) state); against a random dense gradient adafactor-style
    # reconstruction correlates ~0.8 with full AdamW and must agree in sign
    u1 = np.asarray(p1["w"]).ravel()
    u2 = np.asarray(p2["w"]).ravel()
    corr = np.corrcoef(u1, u2)[0, 1]
    assert corr > 0.75, corr
    assert (np.sign(u1) == np.sign(u2)).mean() > 0.95


def test_straggler_monitor_flags():
    from repro.launch.train import StragglerMonitor
    mon = StragglerMonitor(factor=3.0, warmup=3)
    for _ in range(10):
        mon.record(0.01)
    mon.record(0.2)
    assert mon.flagged == 1
