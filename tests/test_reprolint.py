"""reprolint catches seeded contract violations and passes compliant code.

Per rule (R001–R007): at least one true-positive fixture the rule must
flag and one clean fixture it must not; plus suppression handling, CLI
exit codes, JSON output, and the live-tree-is-clean gate the CI lint job
relies on."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.reprolint import all_rules, lint_source  # noqa: E402

pytestmark = pytest.mark.fast


def findings(src, path, rules=None):
    return lint_source(textwrap.dedent(src), path, rules=rules)


def rule_ids(src, path, rules=None):
    return {f.rule_id for f in findings(src, path, rules=rules)}


# ---------------------------------------------------------------------------
# R001 conservation-spine


R001_BAD = """
    class LeakyStore:
        def __init__(self, inner):
            self.inner = inner
            self.counters = None

        def fetch(self, page_ids, vids=None):
            return {"vids": [], "vecs": [], "nbrs": []}

        def charge(self, page_ids):
            self.counters.pages_fetched += len(page_ids)

        def note_write(self, page_ids=None, kind="data", count=None):
            pass
"""

R001_GOOD = """
    class SpineStore:
        def __init__(self, inner):
            self.inner = inner
            self.counters = None

        def fetch(self, page_ids, vids=None):
            return fetch_mirroring_inner(self.counters, self.inner,
                                         page_ids, vids)

        def charge(self, page_ids):
            book_charged_reads(self.counters, len(page_ids), 4)
            charge_inner_reads(self.inner, page_ids)

        def note_write(self, page_ids=None, kind="data", count=None):
            note_inner_writes(self.inner, page_ids, kind, count)

    class DelegatingStore:
        def __init__(self, inner):
            self.inner = inner

        def fetch(self, page_ids, vids=None):
            return self._mirrored("fetch", page_ids, vids=vids)

        def charge(self, page_ids):
            self.inner.charge(page_ids)

    class BaseStore:                      # no self.inner: nothing to forward
        def fetch(self, page_ids, vids=None):
            return {}
"""


def test_r001_flags_every_nonforwarding_method():
    found = findings(R001_BAD, "src/repro/io/x.py", rules=["R001"])
    assert len(found) == 3
    assert {"fetch", "charge", "note_write"} == {
        f.message.split()[0].split(".")[1] for f in found}


def test_r001_accepts_forwarding_and_baseline_stores():
    assert rule_ids(R001_GOOD, "src/repro/io/x.py", rules=["R001"]) == set()


# ---------------------------------------------------------------------------
# R002 journal-before-apply


R002_BAD = """
    class Idx:
        def _journal_append(self, kind, payload, sync=False):
            pass

        def insert(self, vec):
            self.delta.insert(7, vec)                 # apply before journal
            self._journal_append("insert", vec)

        def delete(self, vid):
            self.deleted.add(vid)                     # never journals
"""

R002_GOOD = """
    class Idx:
        def _journal_append(self, kind, payload, sync=False):
            pass

        def insert(self, vec):
            vec = list(vec)                           # pure prep is fine
            self._journal_append("insert", vec)
            self.delta.insert(7, vec)

        def delete(self, vid):
            vid = int(vid)
            self._journal_append("delete", vid)
            self.deleted.add(vid)

        def flush(self):
            self._journal_append("flush", None, sync=True)
            self.dirty_pages.clear()

        def compact(self, max_pages=None):
            budget = max_pages or 4
            self._journal_append("compact", budget, sync=True)
            self.free_pages.extend([1, 2])

    class NotJournaled:                 # no _journal_append: out of scope
        def insert(self, vec):
            self.delta.insert(7, vec)
"""


def test_r002_flags_apply_before_journal_and_missing_journal():
    found = findings(R002_BAD, "src/repro/mutation/x.py", rules=["R002"])
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "before the journal append" in msgs   # insert
    assert "never calls" in msgs                 # delete


def test_r002_accepts_journal_first_methods():
    assert rule_ids(R002_GOOD, "src/repro/mutation/x.py",
                    rules=["R002"]) == set()


# ---------------------------------------------------------------------------
# R003 clock discipline


R003_BAD = """
    class Tracker:
        def charge(self, model, n):
            self.busy_us += 12.5                    # raw float: unpriced
            self.exec_free = n * 3.0
"""

R003_GOOD = """
    class Tracker:
        def charge(self, model, n, win):
            self.busy_us += n * model.read_service_us(4096)
            self.exec_free = model.concurrent_latency_us(n, 1)
            self.total_us = self.busy_us + win.bg_io_us   # re-aggregation
            self.busy_us = 0.0                            # zero reset
            self.measured_step_us = 1.25                  # measured channel
"""


def test_r003_flags_raw_clock_writes_outside_serving():
    found = findings(R003_BAD, "src/repro/io/x.py", rules=["R003"])
    assert len(found) == 2


def test_r003_accepts_model_billed_and_serving_code():
    assert rule_ids(R003_GOOD, "src/repro/io/x.py", rules=["R003"]) == set()
    # the same raw writes are the serving layer's own business
    assert rule_ids(R003_BAD, "src/repro/serving/x.py",
                    rules=["R003"]) == set()


# ---------------------------------------------------------------------------
# R004 kernel purity


R004_BAD = """
    import jax

    @jax.jit
    def step(x):
        t0 = time.perf_counter()
        noise = np.random.default_rng()
        jitter = random.random()
        host = x.item()
        return float(x) + t0 + jitter

    def _scan_kernel(ref, out):
        out[0] = ref[0] * random.random()

    fused = pl.pallas_call(_scan_kernel, grid=(1,))
"""

R004_GOOD = """
    import functools
    import time

    import jax

    @functools.partial(jax.jit, static_argnames=("k",))
    def step(x, k):
        return x * k

    def measure_step_us(store, queries):      # host-side harness: untraced
        t0 = time.perf_counter()
        rng = np.random.default_rng(17)
        return time.perf_counter() - t0
"""


def test_r004_flags_impurity_in_traced_and_pallas_regions():
    found = findings(R004_BAD, "src/repro/kernels/x.py", rules=["R004"])
    msgs = " | ".join(f.message for f in found)
    assert "wall clock" in msgs
    assert "host RNG" in msgs
    assert ".item()" in msgs
    assert "float()" in msgs
    assert any("_scan_kernel" in f.message for f in found)  # pallas body


def test_r004_accepts_pure_kernels_and_host_harness():
    assert rule_ids(R004_GOOD, "src/repro/kernels/x.py",
                    rules=["R004"]) == set()
    # same impure source outside the kernel dirs is out of scope
    assert rule_ids(R004_BAD, "src/repro/io/x.py", rules=["R004"]) == set()


# ---------------------------------------------------------------------------
# R005 report-schema stability


R005_BAD = """
    class Report:
        def row(self):
            row = {"qps": 1.0}
            for t, stats in self.per_tenant.items():     # unordered iter
                row[f"t{t}_p99"] = stats
            key = self.pick()
            row[key] = 0.0                               # dynamic key
            return row
"""

R005_GOOD = """
    class Report:
        def row(self):
            row = {"qps": 1.0, "p99_latency_us": 2.0}
            for t, stats in sorted(self.per_tenant.items()):
                for k in ("mean", "p99"):
                    row[f"t{t}_{k}"] = stats[k]
            row.update(_tenant_columns(self.per_tenant))
            return row

    def _tenant_columns(per_tenant):
        return {f"t{t}_hit": v for t, v in sorted(per_tenant.items())}
"""


def test_r005_flags_unordered_and_dynamic_keys():
    found = findings(R005_BAD, "src/repro/serving/x.py", rules=["R005"])
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "isn't pinned" in msgs
    assert "dynamic column key" in msgs


def test_r005_accepts_constant_and_sorted_fstring_keys():
    assert rule_ids(R005_GOOD, "src/repro/serving/x.py",
                    rules=["R005"]) == set()


# ---------------------------------------------------------------------------
# R006 seeded RNG


R006_BAD = """
    import random

    import numpy as np

    def bench():
        gen = np.random.default_rng()          # unseeded
        np.random.seed(0)                      # legacy global
        xs = np.random.rand(8)
        pick = random.choice([1, 2, 3])        # stdlib global
        return gen, xs, pick
"""

R006_GOOD = """
    import numpy as np

    def bench(seed=17):
        gen = np.random.default_rng(seed)
        sub = np.random.default_rng(gen.integers(2**31))
        jkey = jax.random.PRNGKey(seed)        # jax.random is not random.*
        local = gen.random(8)                  # Generator method, not global
        return gen, sub, jkey, local
"""


def test_r006_flags_unseeded_and_global_rngs():
    found = findings(R006_BAD, "benchmarks/x.py", rules=["R006"])
    assert len(found) == 4


def test_r006_accepts_seeded_generators_and_ignores_src():
    assert rule_ids(R006_GOOD, "tests/x.py", rules=["R006"]) == set()
    # src/ RNG construction is governed by its own seeding conventions
    assert rule_ids(R006_BAD, "src/repro/core/x.py", rules=["R006"]) == set()


# ---------------------------------------------------------------------------
# R007 span clock discipline


R007_BAD = """
    def emit(tracer, t_us):
        # a fabricated duration: no clock value, no *_service_us pricing
        tracer.span("device", "device", t0_us=t_us, dur_us=123.4)
"""

R007_GOOD = """
    def emit(tracer, model, t_us, issued, page_bytes):
        rd_us = model.read_service_us(page_bytes)
        tracer.span("device", "device", t0_us=t_us, dur_us=issued * rd_us)
        tracer.span("idle", "device", t0_us=t_us, dur_us=0.0)
        tracer.instant("mark", "admission", t_us=float(t_us))
"""


def test_r007_flags_unpriced_span_durations_in_obs():
    found = findings(R007_BAD, "src/repro/obs/x.py", rules=["R007"])
    assert len(found) == 1
    assert "dur_us" in found[0].message


def test_r007_accepts_billed_values_and_only_governs_obs():
    assert rule_ids(R007_GOOD, "src/repro/obs/x.py", rules=["R007"]) == set()
    # outside src/repro/obs/ the serving loops own the billing contract
    assert rule_ids(R007_BAD, "src/repro/serving/x.py",
                    rules=["R007"]) == set()


# ---------------------------------------------------------------------------
# suppressions


def test_line_suppression_silences_one_line_only():
    src = """
    class Idx:
        def _journal_append(self, kind, payload):
            pass

        def insert(self, vec):    # reprolint: disable=R002
            self.delta.insert(7, vec)

        def delete(self, vid):
            self.deleted.add(vid)
    """
    found = findings(src, "src/repro/mutation/x.py", rules=["R002"])
    assert len(found) == 1 and "delete" in found[0].message


def test_file_suppression_and_multi_rule_disable():
    body = """
    # reprolint: disable-file=R006
    import numpy as np
    gen = np.random.default_rng()
    """
    assert rule_ids(body, "tests/x.py") == set()
    line = """
    import numpy as np
    gen = np.random.default_rng()   # reprolint: disable=R001,R006
    """
    assert rule_ids(line, "tests/x.py") == set()


def test_syntax_error_is_reported_not_crashed():
    found = lint_source("def broken(:\n", "src/x.py")
    assert len(found) == 1 and found[0].rule_id == "E000"


# ---------------------------------------------------------------------------
# CLI


def run_cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *argv],
        cwd=cwd, capture_output=True, text=True)


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "tests"
    bad.mkdir()
    (bad / "bench.py").write_text(
        "import numpy as np\ngen = np.random.default_rng()\n")
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")

    ok = run_cli(str(clean))
    assert ok.returncode == 0 and "clean" in ok.stdout

    dirty = run_cli("--format", "json", str(bad))
    assert dirty.returncode == 1
    doc = json.loads(dirty.stdout)
    assert doc["total"] == 1 and doc["counts"] == {"R006": 1}
    assert doc["findings"][0]["rule"] == "R006"

    usage = run_cli()
    assert usage.returncode == 2

    unknown = run_cli("--rules", "R999", str(clean))
    assert unknown.returncode == 2 and "unknown rule" in unknown.stderr


def test_cli_lists_all_seven_rules():
    out = run_cli("--list-rules")
    assert out.returncode == 0
    for rid in ("R001", "R002", "R003", "R004", "R005", "R006", "R007"):
        assert rid in out.stdout
    assert set(all_rules()) == {"R001", "R002", "R003", "R004", "R005",
                                "R006", "R007"}


# ---------------------------------------------------------------------------
# the gate CI enforces: the live tree is clean


def test_live_tree_is_clean():
    res = run_cli("src", "tests", "benchmarks")
    assert res.returncode == 0, res.stdout + res.stderr
