"""Per-architecture smoke tests (reduced same-family configs): one forward +
one train step on CPU asserting shapes and finiteness, plus the strongest
cache-correctness check we have: single-token decode must reproduce
teacher-forced prefill logits for EVERY family (attention KV caches, RWKV6
state, Mamba conv+ssm state, whisper cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, init_params, loss_fn, prefill_step)

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.frontend == "audio_stub":
        b["frames"] = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.num_frames, cfg.d_model)), jnp.float32)
    if cfg.rope_variant == "mrope":
        b["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return b


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            params = init_params(cfg, jax.random.PRNGKey(0),
                                 dtype=jnp.float32)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = _batch(cfg)
    loss, metrics = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch, arch_state):
    """logits(prefill(t_0..t_s)) == logits(decode after prefill(t_0..t_{s-1}))."""
    cfg, params = arch_state(arch)
    batch = _batch(cfg)
    toks = batch["tokens"]

    full = dict(batch)
    logits_full, _ = prefill_step(params, cfg, full)

    s_half = S // 2
    part = dict(batch)
    part["tokens"] = toks[:, :s_half]
    if "mrope_positions" in part:
        part["mrope_positions"] = part["mrope_positions"][:, :, :s_half]
    logits_h, cache = prefill_step(params, cfg, part)
    # grow cache to length S by padding decode slots
    from repro.models import init_cache
    big = init_cache(cfg, B, S)
    cache = jax.tree.map(
        lambda d, c: (c if d.shape == c.shape
                      else d.at[tuple(slice(0, m) for m in c.shape)].set(
                          c.astype(d.dtype))), big, cache)
    lg = logits_h
    # decode convention: mrope positions are RELATIVE (forward adds cur_index)
    mp = (jnp.zeros((3, B, 1), jnp.int32)
          if cfg.rope_variant == "mrope" else None)
    for i in range(s_half, S):
        lg, cache = decode_step(params, cfg, toks[:, i:i + 1], cache,
                                jnp.int32(i), mrope_positions=mp)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_spec(arch):
    """The full config files carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    spec = {
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)
    if arch == "kimi-k2-1t-a32b":
        assert cfg.moe.num_experts == 384 and cfg.moe.top_k == 8
        assert cfg.param_count() > 1e12
    if arch == "qwen2-moe-a2.7b":
        assert cfg.moe.num_experts == 60 and cfg.moe.top_k == 4
        assert cfg.moe.num_shared_experts == 4
    if arch == "jamba-v0.1-52b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
        attn_layers = [i for i in range(32) if cfg.is_attn_layer(i)]
        assert len(attn_layers) == 4  # 1:7 interleave
