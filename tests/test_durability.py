"""Crash-point sweep over the durability layer (PR 8).

The headline acceptance: kill the mutable index at ANY numbered I/O
boundary (journal sync or data-page write) mid-way through a scripted
insert/delete/flush/compact trace, `recover()` from the base snapshot plus
the journal's committed prefix, resume the script from
`MutableIndex.ops_applied`, and the final state is BIT-IDENTICAL to a run
that never crashed — search ids and dists, tombstone set, free list,
dirty set, and `overlap_ratio` all agree exactly.

Tiers: the full every-boundary sweep is `-m slow`; the fast default tier
samples a handful of boundaries (first, quartiles, the penultimate, the
last). Alongside the sweep: torn-tail discard (truncated and bit-flipped
last record), double-recovery idempotence, snapshot-seeded recovery, the
golden-facade contract on a durable zero-mutation index, and the
serve-level rng-cursor resume (a recovered `serve_open_loop` window is
row-identical to the same-seed uninterrupted one — satellite of the PR 7
fleet determinism test)."""
import dataclasses

import numpy as np
import pytest

from repro.core import build_index, get_preset, make_dataset
from repro.core.vamana import build_vamana
from repro.mutation import (CrashError, CrashPoint, JournalConfig,
                            MutableIndex, MutationConfig, MutationJournal,
                            MutationMix, recover)

GC = 4   # group-commit batch of the sweep's journal (buffer loss is part
#          of what the sweep must survive: buffered ops get re-applied)


def _script(d, n_ops=40, seed=17):
    """Deterministic op trace exercising every record kind, including
    no-op deletes (journaled and replayed as the same no-op) and flushes
    of a part-full delta."""
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.50:
            ops.append(("insert", rng.normal(size=d).astype(np.float32)))
        elif r < 0.75:
            ops.append(("delete", int(rng.integers(300))))
        elif r < 0.90:
            ops.append(("flush",))
        else:
            ops.append(("compact", 4))
    return ops


def _apply(idx, script, start=0):
    for op in script[start:]:
        if op[0] == "insert":
            idx.insert(op[1])
        elif op[0] == "delete":
            idx.delete(op[1])
        elif op[0] == "flush":
            idx.flush()
        else:
            idx.compact(op[1])


def _state(idx, queries):
    st = idx.search(queries)
    return {"ids": np.asarray(st.ids).copy(),
            "dists": np.asarray(st.dists).copy(),
            "tombstones": set(idx.pending_tombstones),
            "free": list(idx.free_pages),
            "dirty": set(idx.dirty_pages),
            "n_disk": idx.n_disk, "next_vid": idx.next_vid,
            "delta": len(idx.delta), "ops": idx.ops_applied,
            "overlap": idx.overlap_ratio()}


def _assert_identical(got, ref):
    assert np.array_equal(got["ids"], ref["ids"])
    assert np.array_equal(got["dists"], ref["dists"])   # bit-identical
    for key in ("tombstones", "free", "dirty", "n_disk", "next_vid",
                "delta", "ops", "overlap"):
        assert got[key] == ref[key], key


@dataclasses.dataclass
class Harness:
    base: object
    mcfg: MutationConfig
    script: list
    queries: np.ndarray
    ref: dict          # final state of the uninterrupted (journal-free) run
    boundaries: int    # killable I/O boundaries in the durable run


@pytest.fixture(scope="module")
def dur():
    ds = make_dataset("deep-like", n=256, nq=8, seed=11)
    G, med, _ = build_vamana(ds.vectors, R=8, L=16, batch=128, seed=11)
    base = build_index(ds, get_preset("baseline"), graph=G, medoid_id=med)
    mcfg = MutationConfig(flush_threshold=8, growth_chunk=64, insert_L=8)
    script = _script(base.layout.page_vecs.shape[-1])
    plain = MutableIndex(base, mcfg)
    _apply(plain, script)
    ref = _state(plain, ds.queries)
    # counting pass: kill_at=None numbers the boundaries without firing —
    # and doubles as the journaling-is-inert check (same bits as plain)
    cp = CrashPoint()
    durable = MutableIndex(base, mcfg,
                           journal=MutationJournal(JournalConfig(GC)),
                           crash=cp)
    _apply(durable, script)
    _assert_identical(_state(durable, ds.queries), ref)
    assert cp.boundaries > len(script) // 4
    return Harness(base, mcfg, script, ds.queries, ref, cp.boundaries)


def _kill_recover_resume(dur, k):
    """Kill the durable run at boundary k, recover, resume, return the
    final state (the harness the sweep tiers share)."""
    j = MutationJournal(JournalConfig(GC))
    idx = MutableIndex(dur.base, dur.mcfg, journal=j,
                       crash=CrashPoint(kill_at=k))
    with pytest.raises(CrashError):
        _apply(idx, dur.script)
    rec = recover(dur.base, j, dur.mcfg)
    assert rec.ops_applied <= len(dur.script)
    assert rec.last_recovery_us > 0
    _apply(rec, dur.script, rec.ops_applied)
    return _state(rec, dur.queries)


def _sample(boundaries):
    picks = {1, boundaries // 4, boundaries // 2, 3 * boundaries // 4,
             boundaries - 1, boundaries}
    return sorted(p for p in picks if p >= 1)


def test_crash_recover_resume_sampled(dur):
    """Fast tier: first/quartile/last boundaries."""
    for k in _sample(dur.boundaries):
        _assert_identical(_kill_recover_resume(dur, k), dur.ref)


@pytest.mark.slow
def test_crash_recover_resume_every_boundary(dur):
    """The full sweep: EVERY journal sync and data-page write is a kill
    point, and every one of them recovers to the same bits."""
    for k in range(1, dur.boundaries + 1):
        _assert_identical(_kill_recover_resume(dur, k), dur.ref)


# -- torn tails ---------------------------------------------------------------


def _torn_tail_case(dur, mangle):
    """Common harness: journal 12 ops with per-op sync, mangle the last
    durable record, recover (tail discarded by framing/checksum), resume
    the dropped op, land on the uninterrupted prefix state."""
    prefix = dur.script[:12]
    j = MutationJournal(JournalConfig(group_commit=1))
    idx = MutableIndex(dur.base, dur.mcfg, journal=j)
    _apply(idx, prefix)
    assert len(j.replay()) == len(prefix) and j.torn_records == 0
    mangle(j)
    rec = recover(dur.base, j, dur.mcfg)
    assert j.torn_records == 1           # exactly the mangled tail dropped
    assert rec.ops_applied == len(prefix) - 1
    _apply(rec, prefix, rec.ops_applied)
    plain = MutableIndex(dur.base, dur.mcfg)
    _apply(plain, prefix)
    _assert_identical(_state(rec, dur.queries), _state(plain, dur.queries))


def test_torn_tail_truncated_record_is_discarded(dur):
    _torn_tail_case(dur, lambda j: j.tear_tail(3))


def test_torn_tail_corrupted_record_is_discarded(dur):
    """A bit flip in the last record's body fails its crc32 — same
    discard path as a short write."""
    _torn_tail_case(dur, lambda j: j.corrupt_tail())


def test_double_recovery_is_idempotent(dur):
    """The journal is only read and the base never mutated: recovering
    twice from the same remains yields bit-identical indexes."""
    k = max(1, dur.boundaries // 2)
    j = MutationJournal(JournalConfig(GC))
    idx = MutableIndex(dur.base, dur.mcfg, journal=j,
                       crash=CrashPoint(kill_at=k))
    with pytest.raises(CrashError):
        _apply(idx, dur.script)
    rec_a = recover(dur.base, j, dur.mcfg)
    rec_b = recover(dur.base, j, dur.mcfg)
    assert rec_a.ops_applied == rec_b.ops_applied
    _assert_identical(_state(rec_a, dur.queries),
                      _state(rec_b, dur.queries))


def test_snapshot_seeds_recovery_and_truncates_journal(dur):
    """snapshot() supersedes the log: recovery restores the checkpoint and
    replays only the ops journaled after it, landing on the same bits as
    the uninterrupted run (modulo the group-commit buffer, re-applied on
    resume)."""
    j = MutationJournal(JournalConfig(GC))
    idx = MutableIndex(dur.base, dur.mcfg, journal=j)
    _apply(idx, dur.script[:20])
    snap = idx.snapshot()
    assert j.log_bytes == 0              # the checkpoint truncated the log
    assert snap["ops_applied"] == 20
    _apply(idx, dur.script, 20)
    _assert_identical(_state(idx, dur.queries), dur.ref)
    rec = recover(dur.base, j, dur.mcfg, snapshot=snap)
    assert rec.ops_applied >= 20
    _apply(rec, dur.script, rec.ops_applied)
    _assert_identical(_state(rec, dur.queries), dur.ref)
    # the snapshot dict survived both restores unmutated: reusable
    rec2 = recover(dur.base, j, dur.mcfg, snapshot=snap)
    _apply(rec2, dur.script, rec2.ops_applied)
    _assert_identical(_state(rec2, dur.queries), dur.ref)


def test_durable_zero_mutation_facade_stays_golden(dur):
    """The golden facade contract survives the durability layer: a
    journal-equipped wrapper with zero mutations returns the same bits as
    DiskIndex.search."""
    idx = MutableIndex(dur.base, dur.mcfg, journal=MutationJournal())
    a = dur.base.search(dur.queries)
    b = idx.search(dur.queries)
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)
    assert np.array_equal(a.page_reads, b.page_reads)
    assert np.array_equal(a.hops, b.hops)


# -- serve-level rng-cursor resume (mirrors PR 7's fleet determinism) ---------


def test_recovered_rng_resumes_arrival_stream(base_index, small_dataset):
    """`recover()` restores the mutation rng cursor: a crashed streaming
    run resumed via `serve_open_loop(rng=recovered_rng())` reproduces the
    exact arrival/victim stream — and therefore the exact report row —
    of the same-seed uninterrupted run. `recovery_us` is the one extra
    (report-only) column the resumed row carries."""
    from repro.serving import AnnServer, ServerConfig

    pool = small_dataset.vectors[:128].astype(np.float32)
    mix = MutationMix(insert_frac=0.3, delete_frac=0.2,
                      compaction="threshold", threshold=0.05, max_pages=8)
    mcfg = MutationConfig(flush_threshold=16, insert_L=8)
    kw = dict(rate_qps=4000.0, duration_us=30000.0, mutation_mix=mix,
              insert_pool=pool)

    def windows(idx):
        srv = AnnServer(idx, server_cfg=ServerConfig(max_batch=8))
        w1 = srv.serve_open_loop(small_dataset.queries, seed=3,
                                 **kw).row()
        w2 = srv.serve_open_loop(small_dataset.queries,
                                 rng=idx.recovered_rng(), **kw).row()
        return w1, w2

    # A: both windows uninterrupted (the rng cursor journaled after each)
    j_a = MutationJournal(JournalConfig(group_commit=4))
    idx_a = MutableIndex(base_index, mcfg, journal=j_a)
    a1, a2 = windows(idx_a)

    # B: window 1 same seed, then "crash" (drop the live index), recover,
    # resume window 2 from the journaled cursor
    j_b = MutationJournal(JournalConfig(group_commit=4))
    idx_b = MutableIndex(base_index, mcfg, journal=j_b)
    srv_b = AnnServer(idx_b, server_cfg=ServerConfig(max_batch=8))
    b1 = srv_b.serve_open_loop(small_dataset.queries, seed=3, **kw).row()
    rec = recover(base_index, j_b, mcfg)
    srv_r = AnnServer(rec, server_cfg=ServerConfig(max_batch=8))
    b2 = srv_r.serve_open_loop(small_dataset.queries,
                               rng=rec.recovered_rng(), **kw).row()

    assert a1 == b1
    assert a1["journal_writes"] > 0
    assert "recovery_us" not in a2
    assert b2.pop("recovery_us") > 0     # priced exactly once, report-only
    assert a2 == b2
