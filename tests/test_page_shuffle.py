"""Unit coverage for core/page_shuffle.py — the greedy packer the build
pipeline AND the mutation subsystem's localized compaction share.

All `-m fast` (pure numpy/python, no graph build, no kernel)."""
import numpy as np
import pytest

from repro.core.page_shuffle import (bfs_order, greedy_pack, shuffle_order,
                                     undirected_adjacency)

pytestmark = pytest.mark.fast


def _random_graph(n, R, seed=0):
    rng = np.random.default_rng(seed)
    G = rng.integers(0, n, (n, R)).astype(np.int32)
    G[G == np.arange(n)[:, None]] = -1          # no self loops, some padding
    return G


def test_perm_is_a_permutation():
    """Every vertex appears exactly once in the packed order — the property
    build_layout relies on (a dropped or duplicated vid silently corrupts
    vid2page)."""
    G = _random_graph(97, 6)                    # not a multiple of n_p
    perm = shuffle_order(G, medoid=0, n_p=8)["perm"]
    assert perm.shape == (97,)
    assert np.array_equal(np.sort(perm), np.arange(97))


def test_multi_component_bfs_covers_every_vertex():
    """A disconnected graph must still pack every component: the BFS
    fallback restarts from the smallest unvisited id when the frontier
    drains."""
    n = 24
    G = np.full((n, 2), -1, np.int32)
    # two rings that never reference each other, plus 4 fully isolated ids
    for i in range(10):
        G[i, 0] = (i + 1) % 10
    for i in range(10, 20):
        G[i, 0] = 10 + ((i - 10 + 1) % 10)
    perm = shuffle_order(G, medoid=0, n_p=4)["perm"]
    assert np.array_equal(np.sort(perm), np.arange(n))
    order = bfs_order(undirected_adjacency(G), 0)
    assert sorted(order) == list(range(n))
    # the first component is exhausted before the fallback jumps across
    assert set(order[:10]) == set(range(10))


def test_deterministic_under_fixed_inputs():
    """Two runs with the same (graph, medoid, n_p, seed) must agree bit for
    bit — the build cache and the golden facade both depend on it."""
    G = _random_graph(64, 4, seed=3)
    a = shuffle_order(G, medoid=5, n_p=4, seed=0)["perm"]
    b = shuffle_order(G, medoid=5, n_p=4, seed=0)["perm"]
    assert np.array_equal(a, b)


def test_greedy_pack_groups_neighbors():
    """A graph of two 4-cliques packs each clique onto one page (n_p=4):
    the greedy scorer must prefer the vertex with the most edges into the
    open page."""
    n = 8
    G = np.full((n, 3), -1, np.int32)
    for base in (0, 4):
        for i in range(4):
            G[base + i] = [base + j for j in range(4) if j != i]
    adj = undirected_adjacency(G)
    perm = greedy_pack(adj, bfs_order(adj, 0), n_p=4)
    pages = [set(perm[:4].tolist()), set(perm[4:].tolist())]
    assert {0, 1, 2, 3} in pages and {4, 5, 6, 7} in pages


def test_shuffle_reports_costs():
    G = _random_graph(32, 4)
    out = shuffle_order(G, medoid=0, n_p=4)
    assert out["stats"]["shuffle_s"] >= 0.0
    assert out["stats"]["approx_peak_bytes"] > 0
