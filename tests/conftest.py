import os
import sys

# Smoke tests and benches must see ONE device — the 512-device override is
# exclusively the dry-run's (set inside repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def small_dataset():
    from repro.core import make_dataset
    return make_dataset("deep-like", n=2048, nq=64, seed=1)


@pytest.fixture(scope="session")
def small_graph(small_dataset):
    from repro.core.vamana import build_vamana
    G, med, stats = build_vamana(small_dataset.vectors, R=16, L=32,
                                 batch=512, seed=1)
    return G, med, stats


@pytest.fixture(scope="session")
def base_index(small_dataset, small_graph):
    from repro.core import build_index, get_preset
    G, med, _ = small_graph
    return build_index(small_dataset, get_preset("baseline"),
                       graph=G, medoid_id=med)
