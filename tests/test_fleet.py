"""Fleet layer (repro/serving/fleet.py): replica groups over the sharded
store — config validation, least-work routing + goodput scaling, result
fidelity, hot-page migration, hysteresis autoscaling, per-replica
admission budgets, one-seed reproducibility of a full streaming fleet
run, and the FleetReport row-schema stability contract.

Config validation is `-m fast`; everything else serves real queries over
the session-scoped `base_index` fixture in virtual time."""
import numpy as np
import pytest

from repro.core import get_preset
from repro.mutation import MutableIndex, MutationConfig, MutationMix
from repro.serving import (AutoscaleConfig, FleetConfig, FleetServer,
                           MigrationConfig, ServerConfig)

L = 32


def _fleet(idx, groups=2, shards=2, migration=None, autoscale=None,
           budget=0.0, routing="least-work", cache_pages=64, **scfg_kw):
    scfg = ServerConfig(
        max_batch=8, shards=shards, cache_policy="lru",
        cache_bytes=cache_pages * idx.layout.page_bytes, prefetch=1,
        **scfg_kw)
    return FleetServer(idx, get_preset("baseline", L=L), server_cfg=scfg,
                       fleet_cfg=FleetConfig(
                           replica_groups=groups, routing=routing,
                           replica_budget_qps=budget, migration=migration,
                           autoscale=autoscale))


# --- config validation (fast) ------------------------------------------------


@pytest.mark.fast
@pytest.mark.parametrize("kw,msg", [
    (dict(replica_groups=0), "replica_groups=0"),
    (dict(routing="random"), "routing='random'"),
    (dict(replica_budget_qps=-1.0), "replica_budget_qps=-1.0"),
    (dict(migration=3), "must be a MigrationConfig"),
    (dict(autoscale="yes"), "must be an AutoscaleConfig"),
    (dict(replica_groups=9, autoscale=AutoscaleConfig(max_groups=4)),
     "above"),
])
def test_fleet_config_rejects_invalid(kw, msg):
    with pytest.raises(ValueError, match=msg):
        FleetConfig(**kw)


@pytest.mark.fast
def test_migration_autoscale_config_validation():
    with pytest.raises(ValueError, match="every_us=0"):
        MigrationConfig(every_us=0)
    with pytest.raises(ValueError, match="hot_frac=1.5"):
        MigrationConfig(hot_frac=1.5)
    with pytest.raises(ValueError, match="max_moves=0"):
        MigrationConfig(max_moves=0)
    with pytest.raises(ValueError, match="min_reads=0"):
        MigrationConfig(min_reads=0)
    with pytest.raises(ValueError, match="check_every_us=0"):
        AutoscaleConfig(check_every_us=0)
    with pytest.raises(ValueError, match="hysteresis band"):
        AutoscaleConfig(util_low=0.8, util_high=0.5)
    with pytest.raises(ValueError, match="min_groups=0"):
        AutoscaleConfig(min_groups=0)
    with pytest.raises(ValueError, match="max_groups=1 < min_groups=2"):
        AutoscaleConfig(min_groups=2, max_groups=1)


# --- serving behaviour -------------------------------------------------------


def test_fleet_results_match_facade(base_index, small_dataset):
    """Routing across replica groups must not change per-query results:
    the fleet returns exactly what DiskIndex.search returns (the groups
    share the same bytes; only I/O accounting is per-group)."""
    srv = _fleet(base_index, groups=3)
    rep = srv.serve_fleet(small_dataset.queries, rate_qps=100_000,
                          duration_us=4_000, seed=2)
    want = base_index.search(small_dataset.queries,
                             get_preset("baseline", L=L))
    np.testing.assert_array_equal(rep.stats.ids,
                                  want.ids[rep.query_indices])
    # every group served something under least-work routing at this load
    assert all(r["completed"] > 0 for r in rep.per_replica.values())


def test_fleet_goodput_scales_with_groups(base_index, small_dataset):
    """Acceptance: saturation goodput rises monotonically with the group
    count at fixed shards — more copies, more concurrent devices."""
    qps = []
    for g in (1, 2, 4):
        srv = _fleet(base_index, groups=g)
        rep = srv.serve_fleet(small_dataset.queries, rate_qps=300_000,
                              duration_us=2_000, seed=2)
        qps.append(rep.qps)
        # (group x shard) device cells all reported
        assert len(rep.per_shard) == g * 2
    assert qps[0] < qps[1] < qps[2], qps


def test_fleet_least_work_beats_round_robin_tail(base_index,
                                                 small_dataset):
    """Least-outstanding-work routing never loses to blind rotation on
    p99 at saturation (it fills the idlest group's queue first)."""
    reps = {}
    for routing in ("least-work", "round-robin"):
        srv = _fleet(base_index, groups=2, routing=routing)
        reps[routing] = srv.serve_fleet(
            small_dataset.queries, rate_qps=100_000, duration_us=2_000,
            seed=2)
    assert (reps["least-work"].p99_latency_us
            <= reps["round-robin"].p99_latency_us * 1.01)


def test_migration_moves_pages_not_results(base_index, small_dataset):
    """Online hot-page migration: the rebalancer promotes pages read in
    the serving window, bills real copy I/O, and never changes search
    results (same seed, migration on vs off -> identical ids)."""
    mig = MigrationConfig(every_us=400.0, hot_frac=0.2, max_moves=32)
    on = _fleet(base_index, groups=2, migration=mig).serve_fleet(
        small_dataset.queries, rate_qps=50_000, duration_us=4_000, seed=4)
    off = _fleet(base_index, groups=2).serve_fleet(
        small_dataset.queries, rate_qps=50_000, duration_us=4_000, seed=4)
    assert on.migrations >= 1 and on.promoted_pages > 0
    # each promoted page: one home read, one copy written per other shard
    assert on.mig_pages_written == on.mig_pages_read * (2 - 1)
    assert on.mig_io_us > 0.0
    np.testing.assert_array_equal(on.stats.ids, off.stats.ids)


def test_migration_hot_set_lives_on_stores(base_index, small_dataset):
    mig = MigrationConfig(every_us=400.0, hot_frac=0.2, max_moves=32)
    srv = _fleet(base_index, groups=2, migration=mig)
    srv.serve_fleet(small_dataset.queries, rate_qps=50_000,
                    duration_us=4_000, seed=4)
    assert all(r.store.placement.replicated.any() for r in srv.replicas)


def test_autoscale_adds_on_ramp_drains_after(base_index, small_dataset):
    """Hysteresis: a dense burst then a sparse tail — the fleet must add
    groups under the burst and drain-before-drop in the tail, never
    below min_groups."""
    arrivals = np.concatenate([
        np.linspace(0.0, 3_000.0, 400),          # ~133k qps burst
        np.linspace(3_100.0, 30_000.0, 30)])     # ~1k qps tail
    asc = AutoscaleConfig(check_every_us=500.0, util_high=0.6,
                          util_low=0.2, min_groups=1, max_groups=4)
    srv = _fleet(base_index, groups=1, autoscale=asc)
    rep = srv.serve_fleet(small_dataset.queries, rate_qps=10_000,
                          duration_us=30_000.0, seed=2,
                          arrivals=arrivals)
    assert rep.groups_added >= 1, rep.timeline
    assert rep.groups_dropped >= 1, rep.timeline
    assert rep.groups_final >= asc.min_groups
    events = [s[3] for s in rep.timeline]
    assert "add" in events and "drain" in events
    # drain-before-drop: a drained group still completed its work — no
    # query vanished
    assert rep.completed == rep.admitted


def test_replica_budget_sheds(base_index, small_dataset):
    srv = _fleet(base_index, groups=2, budget=5_000.0)
    rep = srv.serve_fleet(small_dataset.queries, rate_qps=100_000,
                          duration_us=3_000, seed=2)
    assert rep.shed_budget > 0
    assert rep.shed >= rep.shed_budget
    assert rep.offered == rep.completed + rep.shed
    unbudgeted = _fleet(base_index, groups=2).serve_fleet(
        small_dataset.queries, rate_qps=100_000, duration_us=3_000,
        seed=2)
    assert unbudgeted.shed_budget == 0 and unbudgeted.shed == 0


def test_one_seed_reproduces_streaming_fleet_run(base_index,
                                                 small_dataset):
    """Satellite: ONE seed drives arrivals + mutation kinds + delete
    victims across the whole fleet — two runs at the same seed are
    row-identical (and the seed is stamped); a different seed diverges."""
    mix = MutationMix(insert_frac=0.1, delete_frac=0.05,
                      compaction="threshold", threshold=0.2, max_pages=8)
    pool = small_dataset.vectors[:64]

    def run(seed):
        mi = MutableIndex(base_index, MutationConfig(
            flush_threshold=16, growth_chunk=128, insert_L=16,
            compaction_pages=8))
        srv = _fleet(mi, groups=2)
        return srv.serve_fleet(small_dataset.queries, rate_qps=30_000,
                               duration_us=3_000, seed=seed,
                               mutation_mix=mix, insert_pool=pool)

    a, b, c = run(9), run(9), run(10)
    assert a.row() == b.row()
    assert a.seed == 9
    assert a.inserts > 0 and a.row() != c.row()


def test_mutations_invalidate_every_group(base_index, small_dataset):
    """A flush rewrites pages in every group's copy: all replica stores
    are attached to the shared MutableIndex, and background I/O lands on
    every group's clock (bg_io_us sums the per-group device time)."""
    mi = MutableIndex(base_index, MutationConfig(
        flush_threshold=8, growth_chunk=128, insert_L=16,
        compaction_pages=8))
    srv = _fleet(mi, groups=2)
    rep = srv.serve_fleet(
        small_dataset.queries, rate_qps=30_000, duration_us=3_000,
        seed=1, mutation_mix=MutationMix(insert_frac=0.3),
        insert_pool=small_dataset.vectors[:64])
    assert rep.flushes >= 1
    assert rep.bg_io_us > 0.0
    versions = [r.store.page_version.max() for r in srv.replicas]
    assert all(v > 0 for v in versions)          # every copy invalidated


# --- FleetReport row schema (satellite: stability under replica groups) ------


EXPECTED_BASE_COLS = [
    "rate_qps", "offered", "offered_qps", "qps", "admitted", "shed",
    "degraded", "mean_latency_us", "p50_latency_us", "p99_latency_us",
    "mean_queue_us", "mean_service_us", "mean_interference_us",
    "mean_batch", "pages_per_query", "issued_pages_per_query",
    "cache_hit_rate", "overlap_frac", "slo_violation_frac", "seed",
    "shards", "shard_imbalance", "max_shard_util", "groups",
    "groups_final", "groups_added", "groups_dropped", "migrations",
    "promoted_pages", "mig_pages_written", "shed_budget"]


def test_fleet_row_schema_stable_under_groups(base_index, small_dataset):
    """The row() contract downstream tables key on: fixed column names in
    a fixed order, with exactly one r<N>_completed/r<N>_util pair added
    per replica group — growing the fleet appends columns, never renames
    or reorders the shared prefix."""
    def cols(groups):
        rep = _fleet(base_index, groups=groups).serve_fleet(
            small_dataset.queries, rate_qps=30_000, duration_us=2_000,
            seed=2)
        return list(rep.row().keys())

    c2 = cols(2)
    assert c2 == EXPECTED_BASE_COLS + ["r0_completed", "r0_util",
                                       "r1_completed", "r1_util"]
    c3 = cols(3)
    assert c3[:len(EXPECTED_BASE_COLS)] == EXPECTED_BASE_COLS
    assert c3 == EXPECTED_BASE_COLS + ["r0_completed", "r0_util",
                                       "r1_completed", "r1_util",
                                       "r2_completed", "r2_util"]


def test_fleet_row_tenant_columns_keep_their_slot(base_index,
                                                  small_dataset):
    """With tenants on, the t<N>_* triplets slot between `seed` and the
    shard columns — same names, same position, regardless of how many
    replica groups serve them."""
    tenant_of = np.arange(len(small_dataset.queries)) % 2

    def cols(groups):
        rep = _fleet(base_index, groups=groups,
                     tenants=2).serve_fleet(
            small_dataset.queries, rate_qps=30_000, duration_us=2_000,
            seed=2, tenants=tenant_of)
        return list(rep.row().keys())

    c2, c3 = cols(2), cols(3)
    at = EXPECTED_BASE_COLS.index("seed") + 1
    tenant_cols = ["t0_completed", "t0_shed", "t0_p99_latency_us",
                   "t1_completed", "t1_shed", "t1_p99_latency_us"]
    assert c2[at:at + len(tenant_cols)] == tenant_cols
    assert c3[at:at + len(tenant_cols)] == tenant_cols
    # groups only ever APPEND r<N>_* columns at the tail
    assert c3[:len(c2)] == c2
    assert c3[len(c2):] == ["r2_completed", "r2_util"]
