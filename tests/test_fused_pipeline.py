"""Fused pipelined kernel: equivalence, pad guards, compile-count bounds,
and the facade contract that `pipeline="fused"` changes ONLY the clock.

Four claims pinned here:
  1. fused_page_rank == page_scan_ref + per-page one-hot ADC (the fused
     body computes exactly what the two kernels it absorbs computed);
  2. pq_adc's pad tail is +inf-guarded inside the kernel (regression: a
     length with n % block_n != 0 used to leave garbage in the padded
     rows, visible to any bucketed caller that keeps the full buffer);
  3. the ops-layer shape bucketing bounds recompiles: a whole width ladder
     through the bucketed wrappers adds at most one compiled variant per
     power-of-two bucket (jit cache-size deltas, not timing);
  4. DiskIndex.search with pipeline="fused" is bit-identical to
     pipeline=True — the fused kernel is a measurement surface, never a
     result path — and carries measured_step_us next to the modeled time.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as ops
from repro.kernels.fused_search import fused_page_rank, page_adc
from repro.kernels.pq_adc import pq_adc
from repro.kernels.ref import fused_page_rank_ref, pq_adc_ref


def _rand_case(rng, n_pages, n_p, d, m, w, q, dtype):
    pages = jnp.asarray(rng.normal(size=(n_pages, n_p, d)), dtype)
    codes = jnp.asarray(rng.integers(0, 256, (n_pages, n_p, m))
                        .astype(np.uint8))
    ids = jnp.asarray(rng.integers(0, n_pages, w).astype(np.int32))
    qs = jnp.asarray(rng.normal(size=(q, d)), dtype)
    lut = jnp.asarray((rng.normal(size=(q, m, 256)) ** 2).astype(np.float32))
    return pages, codes, ids, qs, lut


# -- 1. fused kernel == reference composition -------------------------------


@pytest.mark.parametrize("n_pages,n_p,d,m,w,q", [
    (16, 8, 128, 16, 4, 1),
    (64, 8, 128, 16, 8, 4),
    (32, 16, 256, 8, 6, 8),
    (8, 8, 128, 4, 3, 2),      # odd width (pad tail in the bucketed wrapper)
    (128, 8, 128, 16, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_ref(n_pages, n_p, d, m, w, q, dtype):
    rng = np.random.default_rng(n_pages + d + w)
    pages, codes, ids, qs, lut = _rand_case(rng, n_pages, n_p, d, m, w, q,
                                            dtype)
    exact, adc = fused_page_rank(pages, codes, ids, qs, lut, interpret=True)
    exact_ref, adc_ref = fused_page_rank_ref(pages, codes, ids, qs, lut)
    tol = 1e-5 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(exact), np.asarray(exact_ref),
                               rtol=tol, atol=tol * d)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(adc_ref),
                               rtol=1e-4, atol=1e-3)


def test_fused_matches_split_kernels():
    """The fused grid and the two separate grids it replaces agree on the
    same schedule (duplicate ids included — a page staged twice scores
    identically both times)."""
    rng = np.random.default_rng(7)
    pages, codes, _, qs, lut = _rand_case(rng, 32, 8, 128, 16, 6, 8,
                                          jnp.float32)
    ids = jnp.asarray(np.array([3, 3, 0, 31, 7, 3], np.int32))
    exact_f, adc_f = fused_page_rank(pages, codes, ids, qs, lut,
                                     interpret=True)
    from repro.kernels.page_scan import page_scan
    exact_s = page_scan(pages, ids, qs, interpret=True)
    adc_s = page_adc(codes, ids, lut, interpret=True)
    np.testing.assert_allclose(np.asarray(exact_f), np.asarray(exact_s),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(adc_f), np.asarray(adc_s),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(adc_f[0]), np.asarray(adc_f[1]),
                               rtol=1e-6)


def test_fused_bucketed_wrapper_slices_pad():
    """ops.fused_page_rank pads the schedule to its bucket and must slice
    the padded steps back off."""
    rng = np.random.default_rng(11)
    pages, codes, ids, qs, lut = _rand_case(rng, 16, 8, 128, 8, 5, 4,
                                            jnp.float32)
    exact, adc = ops.fused_page_rank(pages, codes, ids, qs, lut)
    assert exact.shape == (5, 8, 4) and adc.shape == (5, 8, 4)
    exact_ref, adc_ref = fused_page_rank_ref(pages, codes, ids, qs, lut)
    np.testing.assert_allclose(np.asarray(exact), np.asarray(exact_ref),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(adc_ref),
                               rtol=1e-4, atol=1e-3)


# -- 2. pq_adc pad-tail guard -----------------------------------------------


@pytest.mark.parametrize("n,block", [(100, 64), (513, 512), (7, 8), (65, 64)])
def test_pq_adc_pad_tail_is_inf(n, block):
    """n % block_n != 0: the kernel itself guards the padded rows to +inf
    (regression — the tail used to hold garbage LUT sums, hidden only by
    the caller's slice)."""
    rng = np.random.default_rng(n)
    codes = jnp.asarray(rng.integers(0, 256, (n, 16)).astype(np.uint8))
    lut = jnp.asarray((rng.normal(size=(16, 256)) ** 2).astype(np.float32))
    out = np.asarray(pq_adc(codes, lut, block_n=block, interpret=True,
                            keep_pad=True))
    assert out.shape[0] % block == 0 and out.shape[0] >= n
    np.testing.assert_allclose(out[:n], np.asarray(pq_adc_ref(codes, lut)),
                               rtol=1e-5)
    assert np.all(np.isinf(out[n:])), "padded rows must be +inf-guarded"
    assert np.all(out[n:] > 0)


def test_pq_adc_bucketed_wrapper():
    """The ops-layer bucketed pq_adc returns exactly n rows and matches the
    oracle even when n lands mid-bucket."""
    rng = np.random.default_rng(5)
    for n in (100, 513, 700, 1025):
        codes = jnp.asarray(rng.integers(0, 256, (n, 8)).astype(np.uint8))
        lut = jnp.asarray((rng.normal(size=(8, 256)) ** 2).astype(np.float32))
        out = np.asarray(ops.pq_adc(codes, lut, block_n=256))
        assert out.shape[0] == n
        np.testing.assert_allclose(out, np.asarray(pq_adc_ref(codes, lut)),
                                   rtol=1e-5)


# -- 3. bucketing bounds compiles -------------------------------------------


def test_bucket_size_ladder():
    assert [ops.bucket_size(n) for n in (1, 3, 4, 5, 8, 9, 16, 17)] == \
        [4, 4, 4, 8, 8, 16, 16, 32]
    with pytest.raises(ValueError):
        ops.bucket_size(0)


def test_width_ladder_bounded_compiles():
    """A whole width ladder through the bucketed wrappers compiles at most
    one variant per power-of-two bucket (the DynamicWidth/degrade case that
    motivated the bucketing)."""
    from repro.kernels.page_scan import page_scan as raw_scan
    rng = np.random.default_rng(2)
    pages = jnp.asarray(rng.normal(size=(32, 8, 128)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, (32, 8, 8)).astype(np.uint8))
    qs = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    lut = jnp.asarray((rng.normal(size=(4, 8, 256)) ** 2).astype(np.float32))
    widths = list(range(1, 17))            # ladder spans buckets {4, 8, 16}
    before_scan = raw_scan._cache_size()
    before_fused = fused_page_rank._cache_size()
    for w in widths:
        ids = jnp.asarray(rng.integers(0, 32, w).astype(np.int32))
        ops.page_scan(pages, ids, qs)
        ops.fused_page_rank(pages, codes, ids, qs, lut)
    buckets = {ops.bucket_size(w) for w in widths}
    assert raw_scan._cache_size() - before_scan <= len(buckets)
    assert fused_page_rank._cache_size() - before_fused <= len(buckets)


def test_pq_adc_length_ladder_bounded_compiles():
    """Lengths sharing a bucket share a compile: nvalid is traced, so only
    the padded shape keys the jit cache."""
    rng = np.random.default_rng(3)
    lut = jnp.asarray((rng.normal(size=(8, 256)) ** 2).astype(np.float32))
    before = pq_adc._cache_size()
    lengths = [129, 150, 200, 255, 256]    # all bucket to 256 at block_n=64
    for n in lengths:
        codes = jnp.asarray(rng.integers(0, 256, (n, 8)).astype(np.uint8))
        ops.pq_adc(codes, lut, block_n=64)
    assert pq_adc._cache_size() - before <= 1


# -- 4. facade contract: fused changes only the clock -----------------------


def test_facade_fused_bit_identical(base_index, small_dataset):
    from repro.core import get_preset
    cfg = get_preset("pipeline", L=32)
    q = small_dataset.queries[:16]
    a = base_index.search(q, cfg)
    b = base_index.search(q, cfg.replace(pipeline="fused"))
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.dists, b.dists)
    np.testing.assert_array_equal(a.page_reads, b.page_reads)
    np.testing.assert_array_equal(a.hops, b.hops)
    assert a.measured_step_us is None
    assert b.measured_step_us is not None and len(b.measured_step_us) == 16
    assert np.all(b.measured_step_us >= 0)
    assert b.measured_step_us[b.page_reads > 0].min() > 0


def test_fused_stats_survive_concat_and_take(base_index, small_dataset):
    """measured_step_us rides the QueryStats lifecycle (batch concat, the
    serving layer's take) like every other kernel column."""
    from repro.core import get_preset
    cfg = get_preset("pipeline", L=32, pipeline="fused")
    q = small_dataset.queries[:12]
    st = base_index.search(q, cfg, batch=5)    # 3 batches -> concat path
    assert st.measured_step_us.shape == (12,)
    assert st.take(7).measured_step_us.shape == (7,)
