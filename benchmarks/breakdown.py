"""Paper Fig. 22: OctopusANN cumulative optimization breakdown (QPS and
pages/query as techniques stack up baseline -> +MemGraph -> +PS&PSe -> +DW)."""
from __future__ import annotations

from repro.core import get_preset

from benchmarks import common

STACK = [
    ("baseline", {}),
    ("+memgraph", {"memgraph_frac": 0.01}),
    ("+ps+pse", {"memgraph_frac": 0.01, "page_shuffle": True,
                 "page_search": True}),
    ("+dw(=octopus)", {"memgraph_frac": 0.01, "page_shuffle": True,
                       "page_search": True, "dynamic_width": True}),
]


def main(dataset="sift-like", L=48):
    rows = []
    prev_qps = None
    for name, over in STACK:
        r = common.run(dataset, "baseline", L, **over)
        r["stage"] = name
        r["qps_gain"] = (round(r["qps"] / prev_qps - 1, 3)
                         if prev_qps else 0.0)
        prev_qps = r["qps"]
        rows.append(r)
    common.print_table(rows, cols=["stage", "recall@10", "qps", "qps_gain",
                                   "pages_per_query", "hops"])
    return rows


if __name__ == "__main__":
    main()
