"""Paper Figs. 19-21: OctopusANN vs Starling vs PipeANN vs DiskANN at matched
Recall@10 = 90% and 95%."""
from __future__ import annotations

from benchmarks import common

SYSTEMS = ("diskann", "starling", "pipeann", "octopusann")


def main(datasets=("sift-like", "deep-like", "spacev-like", "gist-like"),
         targets=(0.90, 0.95)):
    rows = []
    for ds in datasets:
        over = {"page_bytes": 16384} if ds == "gist-like" else {}
        for target in targets:
            qps = {}
            for sysname in SYSTEMS:
                q, at = common.qps_at_recall(ds, sysname, target, **over)
                qps[sysname] = q
                rows.append({"dataset": ds, "target_recall": target,
                             "system": sysname, "qps_at_recall": round(q, 1),
                             "pages_per_query": at["pages_per_query"] if at else "",
                             })
            if qps["diskann"] > 0:
                print(f"# {ds} @R{int(target*100)}: octopus/diskann = "
                      f"{qps['octopusann']/max(qps['diskann'],1e-9):.2f}x, "
                      f"octopus/starling = "
                      f"{qps['octopusann']/max(qps['starling'],1e-9):.2f}x")
    common.print_table(rows)
    return rows


if __name__ == "__main__":
    main()
