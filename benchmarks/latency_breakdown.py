"""Paper Fig. 2: I/O vs compute share of query latency per dataset."""
from __future__ import annotations

from benchmarks import common


def main(datasets=("sift-like", "deep-like", "spacev-like", "gist-like"),
         L=48):
    rows = []
    for ds in datasets:
        over = {"page_bytes": 16384} if ds == "gist-like" else {}
        r = common.run(ds, "baseline", L, **over)
        rows.append({"dataset": ds, "io_fraction": r["io_fraction"],
                     "compute_fraction": round(1 - r["io_fraction"], 3),
                     "mean_latency_us": r["mean_latency_us"]})
    common.print_table(rows)
    ios = [r["io_fraction"] for r in rows]
    print(f"# I/O dominates: {min(ios):.2f}..{max(ios):.2f} "
          "(paper reports 0.70-0.90 at 100M scale)")
    return rows


if __name__ == "__main__":
    main()
