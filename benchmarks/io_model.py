"""Eq. 1 validation: measured page reads vs the model R*H/(OR(G)*n_p)."""
from __future__ import annotations

import numpy as np

from repro.core import get_preset, overlap_ratio

from benchmarks import common


def main(dataset="sift-like", Ls=(16, 24, 32, 48, 64, 96)):
    ds = common.dataset(dataset)
    G, _, _ = common.graph(dataset)
    rbar = float((G >= 0).sum(1).mean())
    rows, xs, ys = [], [], []
    for preset in ("baseline", "pageshuffle"):
        idx = common.index(dataset, preset)
        og = overlap_ratio(idx.layout, G)
        n_p = idx.layout.n_p
        for L in Ls:
            cfg = get_preset(preset, L=L)
            res = idx.search(ds.queries, cfg)
            h = float(res.hops.mean())
            model = rbar * h / (max(og, 1.0 / n_p) * n_p)
            measured = float(res.page_reads.mean())
            xs.append(model)
            ys.append(measured)
            rows.append({"preset": preset, "L": L, "OR": round(og, 4),
                         "n_p": n_p, "hops": round(h, 1),
                         "model_pages": round(model, 1),
                         "measured_pages": round(measured, 1)})
    corr = float(np.corrcoef(xs, ys)[0, 1])
    common.print_table(rows)
    print(f"# Eq.1 model-vs-measured correlation r={corr:.3f}")
    return corr


if __name__ == "__main__":
    main()
