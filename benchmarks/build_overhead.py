"""Paper Table 6 / Finding 6: index construction overhead (build time, peak
memory, disk and memory footprint) — PageShuffle is the expensive one."""
from __future__ import annotations

from benchmarks import common


def main(datasets=("sift-like", "deep-like")):
    rows = []
    for ds in datasets:
        for preset in ("baseline", "memgraph", "starling"):
            idx = common.index(ds, preset)
            st = idx.build_stats
            rows.append({
                "dataset": ds, "preset": preset,
                "graph_build_s": round(st.get("graph_build_s", 0), 1),
                "shuffle_s": round(st.get("shuffle_s", 0), 2),
                "shuffle_peak_mb": round(
                    st.get("approx_peak_bytes", 0) / 2**20, 1),
                "memgraph_build_s": round(st.get("memgraph_build_s", 0), 2),
                "disk_mb": round(st.get("disk_bytes", 0) / 2**20, 1),
                "memory_mb": round(st.get("memory_bytes", 0) / 2**20, 2),
                "overlap_ratio": round(st.get("overlap_ratio", 0), 4),
            })
    common.print_table(rows)
    return rows


if __name__ == "__main__":
    main()
