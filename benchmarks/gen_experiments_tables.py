"""Regenerate the §Dry-run / §Roofline markdown tables in EXPERIMENTS.md from
the dry-run artifacts. Usage:
    PYTHONPATH=src python -m benchmarks.gen_experiments_tables [--tag opt]
Prints markdown to stdout (EXPERIMENTS.md embeds the output)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.roofline import ART, analyze, load


def dryrun_table(mesh_tag, tag=""):
    rows = load(mesh_tag, tag)
    out = ["| arch | shape | ok | compile_s | HLO flops/dev | coll GiB/dev | "
           "args GiB | temp GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |")
            continue
        coll = sum(v for k, v in r["collectives"].items()
                   if not k.endswith("count"))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{r['flops']:.2e} | {coll/2**30:.2f} | "
            f"{r['memory']['argument_bytes']/2**30:.1f} | "
            f"{r['memory']['temp_bytes']/2**30:.1f} |")
    return "\n".join(out)


def roofline_table(mesh_tag, tag=""):
    rows = analyze(mesh_tag, tag)
    out = ["| arch | shape | compute s | memory s (model) | memory s "
           "(HLO ub) | collective s | dominant | MODEL/HLO flops | roofline "
           "fraction |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']} | "
            f"{r['memory_s_model']} | {r['memory_s_hlo_ub']} | "
            f"{r['collective_s']} | {r['dominant']} | {r['useful_ratio']} | "
            f"{r['roofline_fraction']} |")
    return "\n".join(out)


def compare_table(mesh_tag="single"):
    """Baseline vs optimized roofline fractions per cell."""
    base = {(r["arch"], r["shape"]): r for r in analyze(mesh_tag, "")
            if "error" not in r}
    opt = {(r["arch"], r["shape"]): r for r in analyze(mesh_tag, "opt")
           if "error" not in r}
    out = ["| arch | shape | coll s before | coll s after | fraction before "
           "| fraction after | gain |",
           "|---|---|---|---|---|---|---|"]
    for k in sorted(base):
        b = base[k]
        o = opt.get(k)
        if not o:
            continue
        fb, fo = float(b["roofline_fraction"]), float(o["roofline_fraction"])
        out.append(
            f"| {k[0]} | {k[1]} | {b['collective_s']} | {o['collective_s']} "
            f"| {fb:.3f} | {fo:.3f} | {fo/max(fb,1e-9):.1f}x |")
    return "\n".join(out)


if __name__ == "__main__":
    tag = "opt" if "--tag" in sys.argv and "opt" in sys.argv else ""
    for mesh in ("single", "multi"):
        print(f"\n### Dry-run ({mesh}-pod{', ' + tag if tag else ''})\n")
        print(dryrun_table(mesh, tag))
        print(f"\n### Roofline ({mesh}-pod{', ' + tag if tag else ''})\n")
        print(roofline_table(mesh, tag))
    if (ART / "dryrun_opt").exists():
        print("\n### Baseline vs optimized (single-pod)\n")
        print(compare_table("single"))
