"""Paper Figs. 16-18 + Table 7: combination study C1..C5 (+ references)."""
from __future__ import annotations

from benchmarks import common

COMBOS = ("baseline", "memgraph", "dynamicwidth",
          "C1", "C2", "C3", "C4", "C5")
LS = (16, 24, 32, 48, 64, 96)


def main(datasets=("sift-like", "deep-like", "spacev-like", "gist-like"),
         Ls=LS):
    rows = []
    for ds in datasets:
        over_ds = {"page_bytes": 16384} if ds == "gist-like" else {}
        for p in COMBOS:
            for L in Ls:
                rows.append(common.run(ds, p, L, **over_ds))
    common.print_table(rows)
    l_ref = sorted(Ls)[len(Ls) // 2]
    for ds in datasets:
        at = {r["preset"]: r for r in rows
              if r["dataset"] == ds and r["L"] == l_ref}
        print(f"# {ds} L={l_ref} qps: base={at['baseline']['qps']} "
              f"C1={at['C1']['qps']} C2={at['C2']['qps']} "
              f"C3={at['C3']['qps']} C5={at['C5']['qps']}")
    return rows


if __name__ == "__main__":
    main()
