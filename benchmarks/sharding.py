"""Sharded-store sweep: shard count x placement x arrival rate.

The open-loop sweep (benchmarks/open_loop.py) finds WHERE one device
saturates; past that point the only way to keep pushing the throughput
frontier is more devices. This sweep drives `AnnServer` over the sharded
PageStore (repro/io/sharded_store.py) and shows

  1. saturation goodput scaling with shard count (1/2/4/8) under the
     balanced round-robin placement — the acceptance criterion is that it
     increases monotonically from 1 to 4 shards,
  2. an open-loop rate sweep per (shards, placement) cell, reporting
     qps / p99 / shard_imbalance / max_shard_util per row,
  3. a SKEWED workload (a few hot queries dominating the pool) at a fixed
     shard count, where the `replicated` hot-set placement (top pages of a
     `page_trace` profile replicated on every device, routed least-loaded)
     beats `round-robin`'s fixed page homes on latency, with `contiguous`
     as the deliberate worst case (the hot range pins one device).

How to read the output: `shard_imbalance` is max/mean issued reads across
shards (1.0 = perfectly balanced placement — lower is better);
`max_shard_util` is the hottest device's busy fraction. At equal offered
load a lower imbalance means the max-over-shards device time — and so p99 —
drops; at saturation it means higher goodput.

Env knobs (dataset sizing in benchmarks/common.py):
  REPRO_SH_DURATION   arrival window in us of virtual time (default 20000)
  REPRO_SH_SHARDS     comma-separated shard counts (default 1,2,4,8)
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro.core import get_preset, recall_at_k
from repro.core.search_kernel import search_batched
from repro.io import build_store, profile_from_trace
from repro.serving import AnnServer, ServerConfig

DURATION_US = float(os.environ.get("REPRO_SH_DURATION", 20000.0))
SHARDS = tuple(int(s) for s in os.environ.get(
    "REPRO_SH_SHARDS", "1,2,4,8").split(","))
SYSTEM = "starling"
L = 32


def _server(idx, cfg, shards: int, placement: str = "round-robin",
            page_profile=None, max_batch: int = 16):
    return AnnServer(idx, cfg, common.MODEL, ServerConfig(
        max_batch=max_batch, shards=shards, placement=placement),
        page_profile=page_profile)


def page_profile(idx, cfg, queries) -> np.ndarray:
    """Per-page access counts from one profiling pass over `queries` —
    what the replicated placement ranks its hot set by."""
    store = build_store(idx.layout, batched=True)
    st = search_batched(store, idx.pq, cfg, queries, medoid=idx.medoid,
                        memgraph=idx.memgraph, collect_trace=True,
                        account_kernel_io=False)
    return profile_from_trace(st.page_trace, idx.layout.num_pages)


def skewed_pool(queries: np.ndarray, hot: int = 4,
                repeats: int = 8) -> np.ndarray:
    """A pool where `hot` queries are offered `repeats` extra times each —
    their pages dominate the device load."""
    return np.concatenate([np.tile(queries[:hot], (repeats, 1)), queries])


def saturation_scaling(name: str, preset: str = SYSTEM):
    """Acceptance: flood each shard count and report goodput — saturation
    rate must increase monotonically 1 -> 4 shards under round-robin."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    rows, sats = [], {}
    for shards in SHARDS:
        rep = _server(idx, cfg, shards).serve_open_loop(
            ds.queries, rate_qps=500_000.0, duration_us=DURATION_US / 2)
        sats[shards] = rep.qps
        rows.append({"dataset": name, "system": preset, "shards": shards,
                     "placement": "round-robin",
                     "sat_qps": round(rep.qps, 1),
                     "mean_latency_us": round(rep.mean_latency_us, 1),
                     "shard_imbalance": rep.row().get("shard_imbalance", 1.0),
                     "max_shard_util": rep.row().get("max_shard_util", "")})
    upto4 = [sats[s] for s in SHARDS if s <= 4]
    mono = all(b > a for a, b in zip(upto4, upto4[1:]))
    print(f"# {name} saturation goodput by shards: "
          + " ".join(f"S={s}:{q:.0f}" for s, q in sats.items())
          + ("   [monotone 1->4: OK]" if mono
             else "   [NOT MONOTONE 1->4 — regression]"))
    return rows, sats


def rate_sweep(name: str, sat_qps: float, preset: str = SYSTEM):
    """Open-loop rate sweep per (shards, placement): the §8 concurrency
    frontier, now with the device count as an axis."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    rows = []
    for shards in SHARDS:
        # placement is moot on a single device — one cell, not three
        placements = (("round-robin",) if shards == 1
                      else ("round-robin", "contiguous"))
        for placement in placements:
            for factor in (0.5, 1.0, 2.0):
                srv = _server(idx, cfg, shards, placement)
                rep = srv.serve_open_loop(ds.queries,
                                          rate_qps=factor * sat_qps,
                                          duration_us=DURATION_US)
                rec = (recall_at_k(rep.stats.ids, ds.gt[rep.query_indices],
                                   cfg.k) if rep.completed else 0.0)
                row = {"dataset": name, "system": preset,
                       "shards": shards, "placement": placement,
                       "load_x": factor, **rep.row(),
                       "recall@10": round(rec, 4)}
                # print_table derives columns from the FIRST row, which is
                # the unsharded baseline — pin the shard columns so the
                # placement comparison survives into the table
                row.setdefault("shard_imbalance", 1.0)
                row.setdefault("max_shard_util", "")
                rows.append(row)
    return rows


def skewed_placements(name: str, sat_qps: float, preset: str = SYSTEM,
                      shards: int = 4):
    """The placement showdown at a skewed workload: profile the pool once,
    then serve it under each placement at moderate load and at saturation."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    pool = skewed_pool(ds.queries)
    prof = page_profile(idx, cfg, pool)
    rows = []
    for placement in ("round-robin", "contiguous", "replicated"):
        profile = prof if placement == "replicated" else None
        for label, rate in (("0.5x", 0.5 * sat_qps), ("flood", 500_000.0)):
            srv = _server(idx, cfg, shards, placement, page_profile=profile)
            rep = srv.serve_open_loop(pool, rate_qps=rate,
                                      duration_us=DURATION_US)
            rows.append({"dataset": name, "shards": shards,
                         "placement": placement, "load": label,
                         "qps": round(rep.qps, 1),
                         "mean_latency_us": round(rep.mean_latency_us, 1),
                         "p99_latency_us": round(rep.p99_latency_us, 1),
                         "shard_imbalance":
                             rep.row().get("shard_imbalance", ""),
                         "max_shard_util":
                             rep.row().get("max_shard_util", "")})
    base = {r["load"]: r for r in rows if r["placement"] == "round-robin"}
    repl = {r["load"]: r for r in rows if r["placement"] == "replicated"}
    for load in base:
        better = (repl[load]["mean_latency_us"]
                  <= base[load]["mean_latency_us"])
        print(f"# {name} skewed @ {load}: replicated "
              f"mean={repl[load]['mean_latency_us']} "
              f"imb={repl[load]['shard_imbalance']} vs round-robin "
              f"mean={base[load]['mean_latency_us']} "
              f"imb={base[load]['shard_imbalance']}"
              + ("   [replicated wins]" if better else ""))
    return rows


def main(datasets=("sift-like",)):
    scale_rows, sweep_rows, skew_rows = [], [], []
    for ds in datasets:
        rows, sats = saturation_scaling(ds)
        scale_rows.extend(rows)
        sweep_rows.extend(rate_sweep(ds, sats[min(SHARDS)]))
        skew_rows.extend(skewed_placements(ds, sats[min(SHARDS)]))
    common.print_table(scale_rows)
    print()
    common.print_table(sweep_rows)
    print()
    common.print_table(skew_rows)
    return scale_rows, sweep_rows, skew_rows


if __name__ == "__main__":
    main()
