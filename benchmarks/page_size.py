"""Paper Fig. 23 / Finding 12: page-size trade-off on the high-dimensional
dataset — PS+PSe is ineffective when a page holds ~1 record."""
from __future__ import annotations

from benchmarks import common


def main(dataset="gist-like", Ls=(24, 48)):
    rows = []
    for page_bytes in (8192, 16384):
        for preset in ("baseline", "C1"):
            for L in Ls:
                r = common.run(dataset, preset, L, page_bytes=page_bytes)
                r["page_bytes"] = page_bytes
                rows.append(r)
    common.print_table(rows, cols=["page_bytes", "preset", "L", "recall@10",
                                   "qps", "pages_per_query"])
    idx8 = common.index(dataset, "baseline", page_bytes=8192)
    idx16 = common.index(dataset, "baseline", page_bytes=16384)
    print(f"# n_p: 8KB={idx8.layout.n_p} 16KB={idx16.layout.n_p}; "
          f"disk: 8KB={idx8.layout.disk_bytes/2**20:.1f}MiB "
          f"16KB={idx16.layout.disk_bytes/2**20:.1f}MiB")
    return rows


if __name__ == "__main__":
    main()
