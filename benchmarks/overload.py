"""Overload sweep: offered load x admission policy x tenant mix.

The open-loop sweep (benchmarks/open_loop.py) shows WHERE the device
saturates; this one shows what the serving layer should DO about it. An
uncontrolled open loop past saturation has unbounded backlog: its p99 is a
function of how long you measure, not of the system. The sweep therefore

  1. probes the saturation goodput (an uncontrolled burst well past any
     plausible knee — completions/elapsed IS the service capacity),
  2. offers 0.5x / 1x / 2x / 4x that capacity under each admission policy
     (`none`, `reject`, `shed-oldest`, `degrade`), reporting goodput vs
     offered load, p99-of-admitted, shed/degraded counts — at 2x the
     window AND at 2x twice the window, so the reader can SEE bounded vs
     duration-divergent p99 (the acceptance criterion),
  3. runs a two-tenant mix (one well-behaved tenant, one flooding) over a
     shared vs partitioned vs partition+rebalanced page cache, reporting
     per-tenant hit rates and their min/max fairness ratio.

Env knobs (dataset sizing in benchmarks/common.py):
  REPRO_OV_DURATION   arrival window in us of virtual time (default 20000)
  REPRO_OV_QUEUE_CAP  bounded-queue capacity (default 32)
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro.core import get_preset, recall_at_k
from repro.serving import AdmissionConfig, AnnServer, ServerConfig

DURATION_US = float(os.environ.get("REPRO_OV_DURATION", 20000.0))
QUEUE_CAP = int(os.environ.get("REPRO_OV_QUEUE_CAP", 32))
LOAD_FACTORS = (0.5, 1.0, 2.0, 4.0)
SYSTEM = "starling"
L = 32


def _admission(policy: str):
    if policy == "none":
        return None
    return AdmissionConfig(policy=policy, queue_cap=QUEUE_CAP,
                           degrade_levels=(1.0, 0.5, 0.25))


def _server(idx, cfg, policy: str, max_batch: int = 16, **kw):
    return AnnServer(idx, cfg, common.MODEL, ServerConfig(
        max_batch=max_batch, admission=_admission(policy), **kw))


def probe_saturation(name: str, preset: str = SYSTEM) -> float:
    """Service capacity in qps: offer an uncontrolled flood and measure
    goodput (completions / elapsed virtual time) — past saturation that
    ratio is the device ceiling, independent of the offered rate."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    rep = _server(idx, cfg, "none").serve_open_loop(
        ds.queries, rate_qps=500_000.0, duration_us=DURATION_US / 2)
    return rep.qps


def sweep_policies(name: str, sat_qps: float, preset: str = SYSTEM):
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    rows = []
    for policy in ("none", "reject", "shed-oldest", "degrade"):
        for factor in LOAD_FACTORS:
            # a fresh server per cell: each measures its own cold-to-warm
            # trajectory instead of inheriting the previous cell's backlog
            srv = _server(idx, cfg, policy)
            rep = srv.serve_open_loop(ds.queries,
                                      rate_qps=factor * sat_qps,
                                      duration_us=DURATION_US)
            rec = (recall_at_k(rep.stats.ids, ds.gt[rep.query_indices],
                               cfg.k) if rep.completed else 0.0)
            rows.append({"dataset": name, "system": preset,
                         "policy": policy, "load_x": factor, **rep.row(),
                         "recall@10": round(rec, 4)})
    return rows


def p99_vs_duration(name: str, sat_qps: float, preset: str = SYSTEM):
    """The acceptance check: at 2x saturation, doubling the window doubles
    the uncontrolled p99 (backlog keeps growing) but leaves the bounded
    policies' p99-of-admitted where it was."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    out = {}
    for policy in ("none", "shed-oldest", "degrade"):
        p99s = []
        for dur in (DURATION_US, 2 * DURATION_US):
            rep = _server(idx, cfg, policy).serve_open_loop(
                ds.queries, rate_qps=2.0 * sat_qps, duration_us=dur)
            p99s.append(rep.p99_latency_us)
        growth = p99s[1] / p99s[0] if p99s[0] else float("inf")
        out[policy] = (p99s, growth)
        print(f"# {name} 2x-saturation p99 {policy:11s}: "
              f"{p99s[0]:10.1f} -> {p99s[1]:10.1f} us "
              f"(x{growth:.2f} for 2x window)"
              + ("   [UNBOUNDED: grows with the window]" if growth > 1.5
                 else "   [bounded]"))
    return out


def tenant_mix(name: str, sat_qps: float, preset: str = SYSTEM):
    """Two tenants, one flooding: per-tenant hit rates under one shared
    cache vs a partitioned one vs partition + utility rebalance."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    nq = len(ds.queries)
    # tenant 0: a small revisited working set (first 8 queries, re-offered);
    # tenant 1: the whole pool (a flood with little page re-use)
    tenants = np.ones(nq, np.int64)
    tenants[:8] = 0
    pool = np.concatenate([np.tile(ds.queries[:8], (4, 1)), ds.queries])
    tmap = np.concatenate([np.zeros(32, np.int64), tenants])
    pages = 256
    cells = [("shared", dict(tenants=1)),
             ("partitioned", dict(tenants=2)),
             ("rebalanced", dict(tenants=2, cache_rebalance_every=512))]
    rows = []
    for label, kw in cells:
        srv = AnnServer(idx, cfg, common.MODEL, ServerConfig(
            max_batch=16, cache_policy="lru",
            cache_bytes=pages * idx.layout.page_bytes,
            admission=_admission("shed-oldest"), **kw))
        rep = srv.serve_open_loop(pool, rate_qps=1.5 * sat_qps,
                                  duration_us=2 * DURATION_US,
                                  tenants=tmap)
        per = rep.per_tenant or {}
        hr = [per.get(t, {}).get("cache_hit_rate", 0.0) for t in (0, 1)]
        fair = min(hr) / max(hr) if max(hr) > 0 else 1.0
        rows.append({"dataset": name, "cache": label,
                     "qps": round(rep.qps, 1), "shed": rep.shed,
                     "hit_rate_t0": hr[0], "hit_rate_t1": hr[1],
                     "fairness_minmax": round(fair, 4),
                     "cache_pages_t0": per.get(0, {}).get("cache_pages"),
                     "cache_pages_t1": per.get(1, {}).get("cache_pages")})
    return rows


def main(datasets=("sift-like",)):
    all_rows, mix_rows = [], []
    for ds in datasets:
        sat = probe_saturation(ds)
        print(f"# {ds} saturation goodput ~ {sat:.0f} qps "
              f"({SYSTEM}, L={L})")
        all_rows.extend(sweep_policies(ds, sat))
        p99_vs_duration(ds, sat)
        mix_rows.extend(tenant_mix(ds, sat))
    common.print_table(all_rows)
    print()
    common.print_table(mix_rows)
    return all_rows, mix_rows


if __name__ == "__main__":
    main()
