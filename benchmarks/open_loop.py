"""Open-loop serving sweep: arrival rate x cache policy x prefetch.

The paper's §8 guideline — storage-centric designs (Starling/OctopusANN:
fewer pages per query) vs. hybrid designs (PipeANN: overlap I/O with
compute) flip with concurrency — is really an *arrival rate* statement:
under light open-loop load the device is idle and latency-hiding wins; as
the offered rate approaches device saturation, throughput is decided purely
by pages issued, so page-frugal designs (and a warm shared cache in front
of them) win. This sweep drives `AnnServer.serve_open_loop` (Poisson
arrivals, SLO-aware batching) across arrival rates and the stateful cache
subsystem's policy space, reporting qps / p99 / hit-rate per cell.

Env knobs (see benchmarks/common.py for the dataset sizing ones):
  REPRO_OL_RATES      comma-separated arrival rates in QPS
  REPRO_OL_DURATION   arrival window in us of virtual time

`--trace out.json` (or REPRO_OL_TRACE) records the sweep's FIRST cell
(lowest rate, first policy) as a Perfetto-loadable Chrome trace — one
cell, not the whole sweep, so the trace stays one server's coherent
virtual timeline. The export is validated (span balance, flow
resolution, latency conservation) before it is written.
"""
from __future__ import annotations

import os

from benchmarks import common
from repro.core import get_preset, recall_at_k
from repro.obs import Tracer, validate_chrome_trace
from repro.serving import AnnServer, ServerConfig

RATES = tuple(float(r) for r in os.environ.get(
    "REPRO_OL_RATES", "2000,8000,32000,128000").split(","))
DURATION_US = float(os.environ.get("REPRO_OL_DURATION", 20000.0))
# (cache_policy, cache_pages, prefetch) cells; pages are multiplied by the
# layout page size so the byte budget tracks the configured page_bytes
POLICIES = (("none", 0, 0),
            ("lru", 256, 0),
            ("fifo", 256, 0),
            ("2q", 256, 0),
            ("lru", 256, 2))
SYSTEMS = ("starling", "pipeann")   # storage-centric vs hybrid


def sweep(name: str, preset: str, rates=RATES, policies=POLICIES,
          L: int = 32, duration_us: float = DURATION_US, max_batch: int = 16,
          slo_p99_us: float = None, tracer: Tracer = None, **over):
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L, **over)
    idx = common.index(name, preset, **over)
    rows = []
    for policy, pages, prefetch in policies:
        for rate in rates:
            # fresh server per cell: each (rate, policy) measures its own
            # cold-to-warm trajectory instead of inheriting the last cell's
            server = AnnServer(idx, cfg, common.MODEL, ServerConfig(
                max_batch=max_batch, cache_policy=policy,
                cache_bytes=pages * idx.layout.page_bytes,
                prefetch=prefetch, slo_p99_us=slo_p99_us))
            # trace exactly one cell (the first still-empty tracer wins):
            # a trace is one virtual timeline, not a pile of sweep cells
            cell_tr = tracer if tracer is not None and not len(tracer) \
                else None
            rep = server.serve_open_loop(ds.queries, rate_qps=rate,
                                         duration_us=duration_us,
                                         tracer=cell_tr)
            rec = (recall_at_k(rep.stats.ids, ds.gt[rep.query_indices], cfg.k)
                   if rep.completed else 0.0)
            rows.append({"dataset": name, "system": preset, "L": L,
                         "policy": policy, "cache_pages": pages,
                         "prefetch": prefetch, **rep.row(),
                         "recall@10": round(rec, 4)})
    return rows


def main(datasets=("sift-like",), systems=SYSTEMS, rates=RATES,
         policies=POLICIES, L: int = 32, duration_us: float = DURATION_US,
         trace_out: str = None):
    tracer = Tracer() if trace_out else None
    rows = []
    for ds in datasets:
        for sysname in systems:
            rows.extend(sweep(ds, sysname, rates=rates, policies=policies,
                              L=L, duration_us=duration_us, tracer=tracer))
    common.print_table(rows)
    if tracer is not None:
        problems = validate_chrome_trace(tracer.to_chrome())
        assert problems == [], f"trace invalid: {problems[:5]}"
        tracer.export(trace_out)
        s = tracer.summary()
        print(f"# wrote {trace_out}: {len(tracer)} spans, "
              f"{s.queries} queries, max residual "
              f"{s.max_residual_us:.2e}us")

    # the §8 crossover: best system per (rate, policy) at the extremes
    for ds in datasets:
        for rate in (min(rates), max(rates)):
            at = {r["system"]: r for r in rows
                  if r["dataset"] == ds and r["rate_qps"] == round(rate, 1)
                  and r["policy"] == "none"}
            if len(at) < 2:
                continue
            best = max(at, key=lambda s: at[s]["qps"])
            print(f"# {ds} @ {rate:g} qps offered: best={best} "
                  f"qps={at[best]['qps']} p99={at[best]['p99_latency_us']}")
        # locality diagnostic: prefetch cells manufacture hits by
        # construction (every looked-ahead page hits on its demand access),
        # so only pure-cache cells say anything about page reuse
        cached = [r for r in rows if r["dataset"] == ds
                  and r["policy"] != "none" and r["prefetch"] == 0]
        if cached:
            best = max(cached, key=lambda r: r["cache_hit_rate"])
            print(f"# {ds} best hit-rate (no prefetch): {best['policy']} "
                  f"@ {best['rate_qps']:g} qps -> {best['cache_hit_rate']}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=os.environ.get("REPRO_OL_TRACE"),
                    metavar="OUT.json",
                    help="record the first sweep cell as a Chrome trace")
    main(trace_out=ap.parse_args().trace)
