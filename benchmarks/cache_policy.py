"""Beyond-paper ablation: SSSP cache (the paper's choice, §4.1.2) vs
workload-frequency cache at equal budget."""
from __future__ import annotations

from benchmarks import common


def main(dataset="sift-like", L=48, frac=0.02):
    rows = []
    for policy in ("sssp", "freq"):
        r = common.run(dataset, "cache", L, cache_frac=frac,
                       cache_policy=policy)
        r["policy"] = policy
        rows.append(r)
    common.print_table(rows, cols=["policy", "recall@10", "qps",
                                   "pages_per_query", "hops"])
    return rows


if __name__ == "__main__":
    main()
