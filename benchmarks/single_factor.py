"""Paper Figs. 11-13 + Table 5: single-factor Pareto sweeps — QPS / latency /
I/O-per-query vs Recall@10 for each technique, plus modeled device counters."""
from __future__ import annotations

from benchmarks import common

PRESETS = ("baseline", "cache", "memgraph", "pageshuffle", "dynamicwidth",
           "pipeline", "pagesearch")
LS = (12, 16, 24, 32, 48, 64, 96)


def main(datasets=("sift-like", "deep-like", "spacev-like", "gist-like"),
         presets=PRESETS, Ls=LS):
    rows = []
    for ds in datasets:
        for p in presets:
            over = {"page_bytes": 16384} if ds == "gist-like" else {}
            for L in Ls:
                rows.append(common.run(ds, p, L, **over))
    common.print_table(rows)

    # Finding 3/4/5 qualitative checks at the mid-grid L
    l_ref = sorted(Ls)[len(Ls) // 2]
    for ds in datasets:
        at = {r["preset"]: r for r in rows if r["dataset"] == ds
              and r["L"] == l_ref}
        b = at["baseline"]
        print(f"# {ds}: baseline pages={b['pages_per_query']} "
              f"memgraph {at['memgraph']['pages_per_query']} "
              f"dw {at['dynamicwidth']['pages_per_query']} "
              f"pipe {at['pipeline']['pages_per_query']} "
              f"(io_frac={b['io_fraction']})")
    return rows


if __name__ == "__main__":
    main()
