"""Concurrency sweep (paper §8 guidelines): QPS / latency for the Table-2
systems under a closed-loop serving load at 1-64 workers.

Reproduces the storage-centric-vs-hybrid crossover: hybrid (pipeline +
dynamic-width, e.g. PipeANN) wins at low concurrency by overlapping I/O with
compute, while storage-centric page-utility systems (Starling/OctopusANN)
win once the device saturates and throughput is decided purely by pages per
query. Also reports the cross-query page dedup the serving layer's
BatchedPageStore achieves over per-query accounting.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import get_preset, recall_at_k
from repro.serving import AnnServer, ServerConfig

SYSTEMS = ("diskann", "starling", "pipeann", "octopusann")
WORKERS = (1, 2, 4, 8, 16, 32, 64)


def sweep(name: str, preset: str, workers=WORKERS, L: int = 32,
          rounds: int = 2, max_batch: int = 16, **over):
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L, **over)
    idx = common.index(name, preset, **over)
    server = AnnServer(idx, cfg, common.MODEL,
                       ServerConfig(max_batch=max_batch))
    rows = []
    for w in workers:
        rep = server.serve_closed_loop(ds.queries, workers=w, rounds=rounds)
        rec = recall_at_k(rep.stats.ids, ds.gt[rep.query_indices], cfg.k)
        rows.append({"dataset": name, "system": preset, "L": L,
                     **rep.row(), "recall@10": round(rec, 4)})
    return rows


def main(datasets=("sift-like",), systems=SYSTEMS, workers=WORKERS,
         L: int = 32, rounds: int = 2):
    rows = []
    for ds in datasets:
        over = {"page_bytes": 16384} if ds == "gist-like" else {}
        for sysname in systems:
            rows.extend(sweep(ds, sysname, workers=workers, L=L,
                              rounds=rounds, **over))
    common.print_table(rows)

    # crossover check: best system at the lowest vs highest worker count
    for ds in datasets:
        for w in (min(workers), max(workers)):
            at = {r["system"]: r for r in rows
                  if r["dataset"] == ds and r["workers"] == w}
            if not at:
                continue
            best = max(at, key=lambda s: at[s]["qps"])
            print(f"# {ds} @ {w} workers: best={best} "
                  f"qps={at[best]['qps']} "
                  f"(dedup_saved={at[best]['dedup_saved_frac']})")
    return rows


if __name__ == "__main__":
    main()
