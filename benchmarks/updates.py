"""Streaming-update sweep: insert rate x compaction policy.

PR 0–4 measured the paper's page-level complexity model (path length x
page locality) on FROZEN indexes. This sweep opens the streaming workload
(repro/mutation/): mixed read/insert/delete arrivals served open-loop over
a page-shuffled index, across the compaction policies

  none        flushes accumulate in the append zone, tombstones pile up —
              locality decays monotonically, window after window
  threshold   a bounded re-pack runs whenever the dirty-page fraction
              crosses the line (FreshDiskANN-style batch consolidation)
  continuous  a bounded re-pack rides every dispatched batch

How to read the output (one row per serving window, state carried across
windows):
  overlap_ratio     live-vertex OR(G) after the window — the locality the
                    mutation stream destroys and compaction repairs. The
                    acceptance criterion: monotone decay under `none`,
                    strictly higher final value under compaction.
  probe_pages_per_hop   the decay made operational: a fixed probe sweep
                    after each window, reporting the model's PAGE-LOCALITY
                    term directly — distinct pages charged per hop. (Raw
                    pages-per-query is confounded here: well-wired midpoint
                    inserts SHORTCUT the graph and cut hops, so total pages
                    can fall while locality rots; per-hop strips the
                    path-length factor out, which is exactly the model's
                    factorization.) Monotone rise under `none`, pulled back
                    toward the build-time value under compaction.
  bg_util           device time spent on flush/compaction I/O over the
                    window — the goodput cost of the repair. With shards,
                    `max_shard_util` includes the background I/O billed to
                    each page's home shard, so compaction is visible in
                    the same per-device utilization column as query reads.

The second sweep prices DURABILITY (repro/mutation/journal.py): the same
streaming cell run over a journal-equipped index, group-commit batch x
snapshot cadence. Read it as the write-amplification budget of crash
safety:
  journal_writes    journal pages committed during the window, billed at
                    the write unit on the background device clock (so a
                    per-op-sync journal visibly taxes goodput at high
                    mutation rates; group commit amortizes it)
  snap_pages        pages a snapshot() checkpoint cost after the window
                    (0 on non-checkpoint windows) — the cadence trade:
                    frequent snapshots keep recovery short but pay the
                    full-image write each time
Each durability cell ends with a kill/recover acceptance guard: the live
index is dropped, `recover()` rebuilds it from the journal (plus the last
snapshot when the cadence took one), and the probe sweep must return
BIT-IDENTICAL results — printed as [recovery OK]. Journal/snapshot writes
are also audited down the server store's conservation spine
(pages_written == data + journal + snapshot at every layer).

Env knobs (dataset sizing in benchmarks/common.py):
  REPRO_UP_DURATION   window length in us of virtual time (default 30000)
  REPRO_UP_WINDOWS    serving windows per cell            (default 4)
  REPRO_UP_RATE       offered arrival rate in qps         (default 8000)
  REPRO_UP_SHARDS     devices                             (default 2)
  REPRO_UP_DURABILITY durability sweep: 1 on, 0 off       (default 1)
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro.core import get_preset
from repro.mutation import (JournalConfig, MutableIndex, MutationConfig,
                            MutationJournal, MutationMix, recover)
from repro.serving import AnnServer, ServerConfig

DURATION_US = float(os.environ.get("REPRO_UP_DURATION", 30000.0))
WINDOWS = int(os.environ.get("REPRO_UP_WINDOWS", 4))
RATE = float(os.environ.get("REPRO_UP_RATE", 8000.0))
SHARDS = int(os.environ.get("REPRO_UP_SHARDS", 2))
DURABILITY = os.environ.get("REPRO_UP_DURABILITY", "1") != "0"
SYSTEM = "pageshuffle"          # high build-time overlap: decay is visible
L = 32
POLICIES = ("none", "threshold", "continuous")
GROUP_COMMITS = (1, 8)          # per-op sync vs. amortized commit
SNAP_CADENCES = (0, 2)          # snapshot() every N windows (0 = never)


def insert_pool(vectors: np.ndarray, size: int = 1024,
                seed: int = 11) -> np.ndarray:
    """In-distribution inserts: midpoints of random base-vector pairs."""
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, len(vectors), (size, 2))
    return (0.5 * (vectors[pairs[:, 0]]
                   + vectors[pairs[:, 1]])).astype(np.float32)


def probe(mi: MutableIndex, cfg, queries) -> dict:
    """Fixed probe sweep through the facade: the locality term
    (pages/hop), raw pages/query, and mean hops on a frozen query set."""
    st = mi.search(queries, cfg)
    hops = max(float(st.hops.sum()), 1.0)
    return {"probe_pages_per_hop": round(float(st.page_reads.sum()) / hops,
                                         3),
            "probe_pages_per_query": round(float(st.page_reads.mean()), 2),
            "probe_hops": round(float(st.hops.mean()), 2)}


def run_cell(name: str, insert_frac: float, policy: str,
             preset: str = SYSTEM):
    """One streaming cell: a fresh mutable index served for WINDOWS
    consecutive open-loop windows (index + cache state persist across
    windows; each row is one window)."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    mi = MutableIndex(idx, MutationConfig(
        flush_threshold=32, growth_chunk=512, insert_L=L))
    srv = AnnServer(mi, cfg, common.MODEL,
                    ServerConfig(max_batch=16, shards=SHARDS))
    mix = MutationMix(insert_frac=insert_frac,
                      delete_frac=insert_frac / 4,
                      compaction=policy, threshold=0.15, max_pages=16,
                      seed=3)
    pool = insert_pool(ds.vectors)
    rows, overlaps = [], [mi.overlap_ratio()]
    pph = [probe(mi, cfg, ds.queries)["probe_pages_per_hop"]]
    for w in range(WINDOWS):
        rep = srv.serve_open_loop(ds.queries, rate_qps=RATE,
                                  duration_us=DURATION_US, seed=w,
                                  mutation_mix=mix, insert_pool=pool)
        r = rep.row()
        pr = probe(mi, cfg, ds.queries)
        overlaps.append(rep.overlap_ratio)
        pph.append(pr["probe_pages_per_hop"])
        rows.append({
            "dataset": name, "system": preset,
            "insert_frac": insert_frac, "policy": policy, "window": w,
            "qps": r["qps"], "p99_latency_us": r["p99_latency_us"],
            "pages_per_query": r["pages_per_query"], **pr,
            "overlap_ratio": r.get("overlap_ratio", 0.0),
            "inserts": r.get("inserts", 0), "deletes": r.get("deletes", 0),
            "flushes": r.get("flushes", 0),
            "compactions": r.get("compactions", 0),
            "bg_util": r.get("bg_util", 0.0),
            "tombstones": len(mi.pending_tombstones),
            "dirty_pages": len(mi.dirty_pages),
            "shard_imbalance": r.get("shard_imbalance", ""),
            "max_shard_util": r.get("max_shard_util", ""),
        })
    return rows, overlaps, pph


def _audit_write_spine(store) -> bool:
    """pages_written == data + journal + snapshot at every layer of the
    server's store stack (the conservation invariant the durability layer
    bills through)."""
    layer, ok = store, True
    while layer is not None:
        c = layer.counters
        ok &= (c.pages_written
               == c.data_writes + c.journal_writes + c.snapshot_writes)
        layer = getattr(layer, "inner", None)
    return ok


def run_durability_cell(name: str, group_commit: int, snap_every: int,
                        insert_frac: float = 0.3, preset: str = SYSTEM):
    """One durable streaming cell: the `threshold` policy cell re-run over
    a journal-equipped index, checkpointed every `snap_every` windows,
    ending with the kill/recover acceptance probe."""
    ds = common.dataset(name)
    cfg = get_preset(preset, L=L)
    idx = common.index(name, preset)
    mcfg = MutationConfig(flush_threshold=32, growth_chunk=512, insert_L=L)
    jrn = MutationJournal(JournalConfig(group_commit=group_commit))
    mi = MutableIndex(idx, mcfg, journal=jrn)
    srv = AnnServer(mi, cfg, common.MODEL,
                    ServerConfig(max_batch=16, shards=SHARDS))
    mix = MutationMix(insert_frac=insert_frac,
                      delete_frac=insert_frac / 4,
                      compaction="threshold", threshold=0.15, max_pages=16)
    pool = insert_pool(ds.vectors)
    rows, snap = [], None
    for w in range(WINDOWS):
        rep = srv.serve_open_loop(ds.queries, rate_qps=RATE,
                                  duration_us=DURATION_US, seed=w,
                                  mutation_mix=mix, insert_pool=pool)
        r = rep.row()
        snap_pages = 0
        if snap_every and (w + 1) % snap_every == 0:
            snap = mi.snapshot()
            snap_pages = snap["snapshot_pages"]
        rows.append({
            "dataset": name, "group_commit": group_commit,
            "snap_every": snap_every, "window": w,
            "qps": r["qps"], "p99_latency_us": r["p99_latency_us"],
            "inserts": r.get("inserts", 0), "deletes": r.get("deletes", 0),
            "journal_writes": r.get("journal_writes", 0),
            "snap_pages": snap_pages, "bg_util": r.get("bg_util", 0.0),
        })
    # --- kill/recover acceptance: drop the live index, rebuild, re-probe
    live_probe = mi.search(ds.queries, cfg)
    live_or = mi.overlap_ratio()
    spine_ok = _audit_write_spine(srv.store)
    rec = recover(idx, jrn, mcfg, snapshot=snap)
    rec_probe = rec.search(ds.queries, cfg)
    ok = (np.array_equal(live_probe.ids, rec_probe.ids)
          and np.array_equal(live_probe.dists, rec_probe.dists)
          and rec.overlap_ratio() == live_or)
    return rows, ok, spine_ok, rec.last_recovery_us


def main(datasets=("sift-like",), insert_fracs=(0.3,)):
    all_rows = []
    for name in datasets:
        for frac in insert_fracs:
            traj = {}
            for policy in POLICIES:
                rows, overlaps, pph = run_cell(name, frac, policy)
                all_rows.extend(rows)
                traj[policy] = (overlaps, rows, pph)
            # --- acceptance: decay without compaction, recovery with it --
            ors_none, _, pph_none = traj["none"]
            # small tolerance: deletes alone nudge the live mean up a hair
            decay = all(b <= a + 2e-3
                        for a, b in zip(ors_none, ors_none[1:]))
            rise = all(b >= a - 2e-2
                       for a, b in zip(pph_none, pph_none[1:]))
            print(f"# {name} insert_frac={frac} overlap under none: "
                  + " -> ".join(f"{o:.4f}" for o in ors_none)
                  + ("   [monotone decay: OK]" if decay
                     else "   [NOT MONOTONE — regression]"))
            print(f"# {name} locality term (pages/hop) under none: "
                  + " -> ".join(f"{p:.3f}" for p in pph_none)
                  + ("   [monotone rise: OK]" if rise
                     else "   [NOT MONOTONE — regression]"))
            for policy in ("threshold", "continuous"):
                o_p = traj[policy][0][-1]
                p_p = traj[policy][2][-1]
                rec = o_p > ors_none[-1] and p_p < pph_none[-1]
                bg = max(r["bg_util"] for r in traj[policy][1])
                print(f"# {name} {policy}: final overlap {o_p:.4f} vs none "
                      f"{ors_none[-1]:.4f}, pages/hop {p_p:.3f} vs "
                      f"{pph_none[-1]:.3f}"
                      + ("   [recovers]" if rec else "   [NO recovery]")
                      + f", bg_util<= {bg:.4f} (the goodput cost)")
    common.print_table(all_rows)
    if not DURABILITY:
        return all_rows
    # --- durability sweep: group-commit batch x snapshot cadence ----------
    dur_rows = []
    for name in datasets:
        for gc in GROUP_COMMITS:
            for snap_every in SNAP_CADENCES:
                rows, ok, spine_ok, rec_us = run_durability_cell(
                    name, gc, snap_every)
                dur_rows.extend(rows)
                jw = sum(r["journal_writes"] for r in rows)
                print(f"# {name} durability gc={gc} snap_every={snap_every}"
                      f": {jw} journal pages, recovery {rec_us:.0f}us"
                      + ("   [recovery OK]" if ok
                         else "   [RECOVERY MISMATCH — regression]")
                      + ("" if spine_ok
                         else "   [WRITE SPINE NOT CONSERVED]"))
                if not (ok and spine_ok):
                    raise SystemExit(
                        "durability acceptance failed: recovered probe or "
                        "write-conservation audit diverged")
    common.print_table(dur_rows)
    return all_rows + dur_rows


if __name__ == "__main__":
    main()
