"""Benchmark orchestrator — one module per paper table/figure.

Default is the QUICK grid (2 datasets x 3 Ls — CPU-feasible end-to-end);
set REPRO_BENCH_FULL=1 for all four datasets and the full L sweeps.
Prints `name,us_per_call,derived`-style CSV sections per module.
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    full = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
    datasets = (("sift-like", "deep-like", "spacev-like", "gist-like")
                if full else ("sift-like", "gist-like"))
    Ls = (12, 16, 24, 32, 48, 64, 96) if full else (16, 32, 64)

    from benchmarks import (breakdown, build_overhead, cache_policy,
                            combinations, concurrency,
                            io_model, kernels, latency_breakdown,
                            memory_budget, open_loop, page_size, roofline,
                            single_factor, sota)

    sections = [
        ("kernels (microbench)", lambda: kernels.main()),
        ("fig2_latency_breakdown", lambda: latency_breakdown.main(datasets)),
        ("eq1_io_model", lambda: io_model.main()),
        ("fig11-13_single_factor+table5",
         lambda: single_factor.main(datasets, Ls=Ls)),
        ("fig16-18_combinations+table7",
         lambda: combinations.main(datasets, Ls=Ls)),
        ("fig19-21_sota", lambda: sota.main(
            datasets, targets=(0.90, 0.95) if full else (0.90,))),
        ("sec8_concurrency_serving", lambda: concurrency.main(
            datasets if full else datasets[:1],
            workers=(1, 2, 4, 8, 16, 32, 64) if full else (1, 4, 16, 64))),
        ("sec8_open_loop_cache_policies", lambda: open_loop.main(
            datasets if full else datasets[:1],
            rates=((2000.0, 8000.0, 32000.0, 128000.0) if full
                   else (2000.0, 32000.0)))),
        ("fig22_breakdown", lambda: breakdown.main()),
        ("fig23_page_size", lambda: page_size.main()),
        ("fig15_memory_budget", lambda: memory_budget.main()),
        ("table6_build_overhead", lambda: build_overhead.main(
            datasets[:2])),
        ("beyond-paper: cache policy ablation",
         lambda: cache_policy.main()),
        ("roofline (from dry-run artifacts)", lambda: roofline.main([])),
    ]
    failures = 0
    for name, fn in sections:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"# section done in {time.time()-t0:.1f}s")
        except Exception:
            failures += 1
            traceback.print_exc()
    print(f"\nbenchmarks complete ({'full' if full else 'quick'} grid), "
          f"failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
