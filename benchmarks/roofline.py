"""§Roofline: three-term roofline per (arch x shape x mesh) from the compiled
dry-run artifacts (benchmarks/artifacts/dryrun*/...), plus a disk-kernel
section giving the SAME compute/memory terms to the search hot-path kernels
(page_scan / pq_adc / fused_page_rank) so the fused pipeline's position on
the roofline sits next to the model kernels'.

Terms (per device, seconds per step), priced on the named device table
shared with the analytic model (repro.core.device_model.TPU_DEVICES;
REPRO_TPU_DEVICE selects, default v5e):
  compute    = HLO_FLOPs / peak_FLOPs            (v5e: 197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw                (v5e: 819 GB/s)
  collective = collective_bytes / link_bw        (v5e: ~50 GB/s/link ICI)

HLO_FLOPs/bytes are trip-count-corrected per-device numbers from
repro.parallel.hloanalysis (XLA's cost_analysis counts loop bodies once).
NOTE the memory term is an upper bound on this container: the CPU backend
fuses far less than TPU, so elementwise temporaries that a TPU would keep in
registers/VMEM are counted as HBM traffic. MODEL_BYTES (analytic minimum:
params+states+saved activations+KV reads) brackets it from below.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference) + attention
term; ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.core.device_model import tpu_device

# module-level names kept for importers; values now come from the shared
# device table (REPRO_TPU_DEVICE selects the entry, default v5e)
_DEV = tpu_device()
PEAK_FLOPS = _DEV.peak_flops
HBM_BW = _DEV.hbm_bw
LINK_BW = _DEV.link_bw

ART = Path(__file__).resolve().parent / "artifacts"


def model_flops(cfg, shape, n_dev: int) -> float:
    """Useful FLOPs per device per step (PaLM-style accounting)."""
    n_act = cfg.active_param_count()
    if shape.mode == "train":
        toks = shape.tokens
        factor = 6.0
        s_ctx = shape.seq_len
    elif shape.mode == "prefill":
        toks = shape.tokens
        factor = 2.0
        s_ctx = shape.seq_len
    else:  # decode: one token per sequence
        toks = shape.global_batch
        factor = 2.0
        s_ctx = shape.seq_len          # attends over the full cache
    n_attn_layers = sum(1 for i in range(cfg.num_layers)
                        if cfg.is_attn_layer(i))
    # attention: 2 matmuls (QK^T, PV) x 2 dims x causal/decode factor
    if shape.mode == "decode":
        att = 4.0 * n_attn_layers * cfg.num_heads * cfg.head_dim * s_ctx * toks
    else:
        att = (2.0 * n_attn_layers * cfg.num_heads * cfg.head_dim
               * s_ctx * toks)  # x0.5 causal x ... (2 matmuls x 2 flops x 0.5)
        att *= 2.0 * 0.5 * (3 if shape.mode == "train" else 1)
    total = factor * n_act * toks + att
    return total / n_dev


def model_bytes(cfg, shape, n_dev: int, rec) -> float:
    """Analytic minimum HBM traffic per device per step (what a fused TPU
    program must move; the CPU-HLO `traffic_bytes` is an upper bound that
    counts every unfused elementwise temp + non-donated cache copies)."""
    p_dev = cfg.param_count() * 2 / n_dev          # bf16 shards
    from repro.models import transformer as T
    ns = T.num_stages(cfg)
    if shape.mode == "train":
        toks_dev = shape.tokens / n_dev
        act_saves = ns * toks_dev * cfg.d_model * 2     # bf16 carry per stage
        opt = p_dev * (1.0 if cfg.opt_state_dtype == "bfloat16" else 2.0) * 2
        # params: read fwd + read bwd-recompute + read+write update;
        # grads: write + read; act saves: write + read; opt: read + write
        return (p_dev * 4 + p_dev * 2 + act_saves * 2 + opt)
    if shape.mode == "prefill":
        toks_dev = shape.tokens / n_dev
        kv_write = (2 * sum(1 for i in range(cfg.num_layers)
                            if cfg.is_attn_layer(i))
                    * cfg.num_kv_heads * cfg.head_dim * toks_dev * 2)
        return p_dev + kv_write + toks_dev * cfg.d_model * 2 * ns
    # decode: params once + the full KV-cache/state read (+1 token write)
    cache_read = rec["memory"]["argument_bytes"] - p_dev
    return p_dev + max(cache_read, 0.0)


def load(mesh_tag: str, tag: str = ""):
    d = ART / (f"dryrun_{tag}" if tag else "dryrun") / mesh_tag
    rows = []
    for f in sorted(d.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def analyze(mesh_tag="single", tag=""):
    from repro.configs import get_config, get_shape
    out = []
    for rec in load(mesh_tag, tag):
        if not rec.get("ok"):
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "error": rec.get("error", "?")})
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        n_dev = rec["n_devices"]
        coll_bytes = sum(v for k, v in rec["collectives"].items()
                        if not k.endswith("_count"))
        t_comp = rec["flops"] / PEAK_FLOPS
        t_mem = rec["traffic_bytes"] / HBM_BW
        t_coll = coll_bytes / LINK_BW
        mf = model_flops(cfg, shape, n_dev)
        mb = model_bytes(cfg, shape, n_dev, rec)
        t_mem_model = mb / HBM_BW
        # dominant term: compute (HLO, trip-corrected), memory (analytic
        # model; CPU-HLO traffic reported alongside as an upper bound),
        # collective (HLO, exact SPMD sizes)
        terms = {"compute": t_comp, "memory": t_mem_model,
                 "collective": t_coll}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        # roofline fraction: the time the USEFUL work needs at hardware peak
        # (its compute at peak FLOPs, or its minimal traffic at peak BW)
        # over the modeled step bound — 1.0 = step runs as fast as its
        # useful work possibly allows
        useful = max(mf / PEAK_FLOPS, t_mem_model)
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh_tag,
            "compute_s": f"{t_comp:.4f}",
            "memory_s_model": f"{t_mem_model:.4f}",
            "memory_s_hlo_ub": f"{t_mem:.4f}",
            "collective_s": f"{t_coll:.4f}",
            "dominant": dom,
            "model_flops_per_dev": f"{mf:.3e}",
            "hlo_flops_per_dev": f"{rec['flops']:.3e}",
            "useful_ratio": f"{mf / max(rec['flops'], 1e-9):.3f}",
            "roofline_fraction": f"{useful / max(bound, 1e-12):.3f}",
            "hbm_gib_per_dev": f"{(rec['memory']['argument_bytes'] + rec['memory']['temp_bytes']) / 2**30:.1f}",
        })
    return out


def disk_kernels(n_pages: int = 8, n_p: int = 8, d: int = 128, m: int = 16,
                 q: int = 32):
    """Analytic roofline terms for the disk-path search kernels, per beam
    step of `n_pages` pages — no artifacts needed (the kernels' FLOP/byte
    counts are closed-form in their shapes). The fused kernel's row is the
    two halves' work under ONE memory pass and one dispatch; its bound is
    max(compute, memory) instead of their sum, which is exactly the overlap
    the measured benchmark (benchmarks/fused_pipeline.py) checks."""
    recs = n_pages * n_p
    vec_bytes = recs * d * 4
    code_bytes = recs * m
    lut_bytes = m * 256 * q * 4
    out_bytes = recs * q * 4
    scan_flops = recs * q * (2 * d + 3)          # x2 - 2xq + q2 per pair
    adc_flops = recs * q * 2 * m * 256           # one-hot matmul form
    rows = []
    for name, flops, bytes_ in (
            ("page_scan", scan_flops, vec_bytes + q * d * 4 + out_bytes),
            ("pq_adc", adc_flops, code_bytes + lut_bytes + out_bytes),
            ("fused_page_rank", scan_flops + adc_flops,
             vec_bytes + code_bytes + q * d * 4 + lut_bytes + 2 * out_bytes)):
        t_c = _DEV.compute_s(flops)
        t_m = _DEV.memory_s(bytes_)
        fused = name == "fused_page_rank"
        bound = max(t_c, t_m) if fused else t_c + t_m
        rows.append({
            "kernel": name, "device": _DEV.name,
            "pages": n_pages, "n_p": n_p, "d": d, "M": m, "Q": q,
            "flops": f"{flops:.3e}", "bytes": f"{bytes_:.3e}",
            "intensity_flop_per_byte": f"{flops / bytes_:.1f}",
            "compute_us": f"{t_c * 1e6:.3f}",
            "memory_us": f"{t_m * 1e6:.3f}",
            "bound": ("compute" if t_c > t_m else "memory"),
            "step_us": f"{bound * 1e6:.3f}",
        })
    return rows


def main(argv=None):
    argv = argv or sys.argv[1:]
    tag = argv[argv.index("--tag") + 1] if "--tag" in argv else ""
    for mesh in ("single", "multi"):
        rows = analyze(mesh, tag)
        if not rows:
            continue
        cols = list(rows[0])
        print(f"== roofline ({mesh}) ==")
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
    rows = disk_kernels()
    cols = list(rows[0])
    print(f"== roofline (disk-path kernels, {_DEV.name}) ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    return 0


if __name__ == "__main__":
    main()
