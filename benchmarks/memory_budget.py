"""Paper Fig. 15 / Finding 7: PQ-dims vs MemGraph-ratio budget allocation."""
from __future__ import annotations

from benchmarks import common


def main(dataset="sift-like", L=48):
    rows = []
    for m in (8, 16, 32):
        r = common.run(dataset, "baseline", L, pq_m=m)
        r["knob"] = f"pq_m={m}"
        rows.append(r)
    for frac in (0.001, 0.01, 0.05):
        r = common.run(dataset, "memgraph", L, memgraph_frac=frac)
        r["knob"] = f"mg={frac}"
        rows.append(r)
    common.print_table(rows, cols=["knob", "recall@10", "qps",
                                   "pages_per_query", "hops"])
    return rows


if __name__ == "__main__":
    main()
