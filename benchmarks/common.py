"""Shared benchmark substrate: cached dataset/graph/index construction.

Vamana builds are minutes-scale on this 1-core container, so graphs are
disk-cached under benchmarks/artifacts/ann/. Sizes come from env:
  REPRO_BENCH_N        base vectors per dataset   (default 8192)
  REPRO_BENCH_QUERIES  queries                    (default 192)
  REPRO_BENCH_R/L      Vamana params              (default 32 / 64; the paper
                       uses 64 / 125 at 100M scale — noted in EXPERIMENTS.md)
"""
from __future__ import annotations

import functools
import os
import time
from pathlib import Path

import numpy as np

from repro.core import (SSDModel, build_index, get_preset, make_dataset,
                        recall_at_k)

ART = Path(__file__).resolve().parent / "artifacts" / "ann"
BENCH_N = int(os.environ.get("REPRO_BENCH_N", 8192))
BENCH_Q = int(os.environ.get("REPRO_BENCH_QUERIES", 192))
BENCH_R = int(os.environ.get("REPRO_BENCH_R", 32))
BENCH_L = int(os.environ.get("REPRO_BENCH_L", 64))

MODEL = SSDModel()


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    return make_dataset(name, n=BENCH_N, nq=BENCH_Q)


@functools.lru_cache(maxsize=None)
def graph(name: str):
    from repro.core.vamana import build_vamana
    ART.mkdir(parents=True, exist_ok=True)
    key = ART / f"{name}_{BENCH_N}_R{BENCH_R}_L{BENCH_L}.npz"
    ds = dataset(name)
    if key.exists():
        z = np.load(key)
        return z["G"], int(z["medoid"]), {"build_s": float(z["build_s"]),
                                          "cached": True}
    G, med, stats = build_vamana(ds.vectors, R=BENCH_R, L=BENCH_L)
    np.savez(key, G=G, medoid=med, build_s=stats["build_s"])
    return G, med, stats


@functools.lru_cache(maxsize=None)
def index(name: str, preset: str, **over):
    ds = dataset(name)
    G, med, _ = graph(name)
    cfg = get_preset(preset, **dict(over))
    return build_index(ds, cfg, graph=G, medoid_id=med)


_RUN_CACHE = {}


def run(name: str, preset: str, L: int, **over):
    """Search + metrics row for one (dataset, preset, L) cell (memoized —
    sota/combination sweeps revisit the same cells)."""
    key = (name, preset, L, tuple(sorted(over.items())))
    if key in _RUN_CACHE:
        return dict(_RUN_CACHE[key])
    row = _run(name, preset, L, **over)
    _RUN_CACHE[key] = row
    return dict(row)


def metrics_row(res, ds, cfg) -> dict:
    """One code path from QueryStats to a benchmark row: every script that
    reports search metrics goes through QueryStats.summary (the device-model
    summary) instead of hand-plumbing its own dict of fields."""
    s = res.summary(MODEL, d=ds.d, pq_m=cfg.pq_m,
                    page_bytes=cfg.page_bytes, pipeline=cfg.pipeline)
    return {
        "recall@10": round(recall_at_k(res.ids, ds.gt, cfg.k), 4),
        "qps": round(s["qps"], 1),
        "mean_latency_us": round(s["mean_latency_us"], 1),
        "pages_per_query": round(s["mean_pages_per_query"], 2),
        "hops": round(s["mean_hops"], 2),
        "io_fraction": round(s["io_fraction"], 3),
        "u_io": round(s["u_io"], 4),
        "iops": round(s["iops"], 0),
        "bw_mbps": round(s["bw_mbps"], 1),
    }


def _run(name: str, preset: str, L: int, **over):
    ds = dataset(name)
    cfg = get_preset(preset, L=L, **over)
    idx = index(name, preset, **over)
    t0 = time.time()
    res = idx.search(ds.queries, cfg)
    wall = time.time() - t0
    return {
        "dataset": name, "preset": preset, "L": L,
        **metrics_row(res, ds, cfg),
        "wall_s": round(wall, 2),
    }


def qps_at_recall(name: str, preset: str, target: float,
                  Ls=(12, 16, 24, 32, 48, 64, 96, 128), **over):
    """Interpolated QPS at matched Recall@10 (the paper's comparison mode)."""
    rows = [run(name, preset, L, **over) for L in Ls]
    rows.sort(key=lambda r: r["recall@10"])
    prev = None
    for r in rows:
        if r["recall@10"] >= target:
            if prev is None or r["recall@10"] == prev["recall@10"]:
                return r["qps"], r
            f = ((target - prev["recall@10"])
                 / (r["recall@10"] - prev["recall@10"]))
            return prev["qps"] + f * (r["qps"] - prev["qps"]), r
        prev = r
    return (rows[-1]["qps"], rows[-1]) if rows else (0.0, None)


def print_table(rows, cols=None):
    if not rows:
        return
    cols = cols or list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
