"""Kernel microbench: interpret-mode wall time (CPU, correctness path) plus
the ANALYTIC device numbers the kernel is designed for (HBM-bound page_scan,
MXU-bound pq_adc) — the dry-run/roofline methodology at kernel granularity.
Peaks come from the shared device table (repro.core.device_model;
REPRO_TPU_DEVICE selects the entry, default v5e)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_model import tpu_device
from repro.kernels import page_scan, pq_adc

_DEV = tpu_device()
HBM_BW = _DEV.hbm_bw     # module-level names kept for importers
PEAK = _DEV.peak_flops


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main():
    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")
    # page_scan: W=16 pages of (8,128) vs 128 queries
    pages = jnp.asarray(rng.normal(size=(1024, 8, 128)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 1024, 16).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    us = _time(page_scan, pages, ids, q)
    bytes_moved = 16 * 8 * 128 * 4
    flops = 2 * 16 * 8 * 128 * 128
    t_mem = bytes_moved / HBM_BW * 1e6
    t_mxu = flops / PEAK * 1e6
    print(f"page_scan_16x8x128_q128,{us:.1f},"
          f"v5e_mem_us={t_mem:.3f};v5e_mxu_us={t_mxu:.3f};bound="
          f"{'memory' if t_mem > t_mxu else 'compute'}")
    # pq_adc: 64k codes x M=16
    codes = jnp.asarray(rng.integers(0, 256, (65536, 16)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    us = _time(pq_adc, codes, lut)
    bytes_moved = 65536 * 16
    flops = 2 * 65536 * 16 * 256  # one-hot matmul form
    print(f"pq_adc_64k_m16,{us:.1f},"
          f"v5e_mem_us={bytes_moved / HBM_BW * 1e6:.3f};"
          f"v5e_mxu_us={flops / PEAK * 1e6:.3f}")
    return 0


if __name__ == "__main__":
    main()
