"""§Fused pipeline: MEASURED wall clock of the fused double-buffered beam
kernel vs. the two separately-jitted calls it replaces — the bridge between
the repo's two latency worlds (the Pallas kernels and the analytic SSD/HBM
model, which until now only met through `SSDModel`'s overlap rebate).

Part 1 — kernel sweep (synthetic shapes): beam width x page size x
LAANN-style look-ahead depth. Each cell builds the hop-major page schedule
a pipelined beam search issues (width confirmed pages per hop + `lookahead`
speculative pages staged from the frontier) and times
  fused   : kernels.fused_page_rank — ONE grid; the DMA of step i+1's
            vector+code tiles is double-buffered behind step i's fused
            exact-scan + ADC compute
  unfused : kernels.page_scan then kernels.page_adc — the same tiles
            through two separately-jitted grids, back to back
reporting per-hop step wall clock, the ACHIEVED overlap ratio
(1 - fused/unfused) next to the ANALYTIC rebate the device model would
grant the same shape (0.9 * min(io, compute) / (io + compute), the
`pipeline=True` term priced on the shared TPU device table), and
pages/query.

Part 2 — search path at the default shape: a real index searched with
pipeline=True vs pipeline="fused"; results must be bit-identical, and the
fused schedule must beat the split execution of the SAME traced schedule.

Wall clock here is interpret-mode (this container has no TPU): the kernel
bodies run as Python/jnp per grid step, so the ABSOLUTE numbers are not
device times — but fused and unfused pay the same interpreter tax per
step, so the ratio (and the fused-not-slower guard) is meaningful, and on
a TPU backend the same script times the compiled kernels unchanged.

Env: REPRO_FP_WIDTHS / REPRO_FP_NP / REPRO_FP_LOOKAHEAD (sweep axes),
REPRO_FP_HOPS / REPRO_FP_QUERIES (shape), REPRO_FP_GUARD=1 (assert fused
<= unfused * REPRO_FP_SLACK at the default shape — the CI smoke guard).
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_preset, tpu_device
from repro.core.search_kernel import measure_step_us
from repro.kernels import fused_page_rank, page_adc, page_scan

D = 128
M = 16
N_PAGES = 512

WIDTHS = [int(x) for x in
          os.environ.get("REPRO_FP_WIDTHS", "4,8,16").split(",")]
PAGE_NP = [int(x) for x in os.environ.get("REPRO_FP_NP", "8,16").split(",")]
LOOKAHEAD = [int(x) for x in
             os.environ.get("REPRO_FP_LOOKAHEAD", "0,2,4").split(",")]
HOPS = int(os.environ.get("REPRO_FP_HOPS", 8))
QUERIES = int(os.environ.get("REPRO_FP_QUERIES", 32))
DEFAULT = (8, 8, 2)          # (width, n_p, lookahead) — the guarded cell


def _time_us(fn, iters=3):
    jax.block_until_ready(fn())          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6


def analytic_overlap(dev, pages: int, n_p: int, q: int) -> dict:
    """The rebate the device model's pipeline term grants this shape on the
    shared TPU table: io/compute priced at peak, overlapped execution
    max(io, c) + 0.1 * min(io, c) vs sequential io + c."""
    bytes_moved = pages * n_p * (D * 4 + M)          # vector + code tiles
    flops = pages * n_p * q * 2 * (D + 256 * M)      # exact + one-hot ADC
    t_io = dev.memory_s(bytes_moved)
    t_c = dev.compute_s(flops)
    seq = t_io + t_c
    piped = max(t_io, t_c) + 0.1 * min(t_io, t_c)
    return {"t_io_us": t_io * 1e6, "t_compute_us": t_c * 1e6,
            "overlap": (seq - piped) / seq if seq else 0.0}


def kernel_sweep():
    dev = tpu_device()
    rng = np.random.default_rng(0)
    rows = []
    for n_p in PAGE_NP:
        pages = jnp.asarray(
            rng.normal(size=(N_PAGES, n_p, D)).astype(np.float32))
        codes = jnp.asarray(
            rng.integers(0, 256, (N_PAGES, n_p, M)).astype(np.uint8))
        q = jnp.asarray(rng.normal(size=(QUERIES, D)).astype(np.float32))
        lut = jnp.asarray(
            (rng.normal(size=(QUERIES, M, 256)) ** 2).astype(np.float32))
        for w in WIDTHS:
            for la in LOOKAHEAD:
                per_hop = w + la
                sched = jnp.asarray(rng.integers(
                    0, N_PAGES, HOPS * per_hop).astype(np.int32))
                fused_us = _time_us(
                    lambda: fused_page_rank(pages, codes, sched, q, lut))
                unfused_us = _time_us(
                    lambda: (page_scan(pages, sched, q),
                             page_adc(codes, sched, lut)))
                ana = analytic_overlap(dev, HOPS * per_hop, n_p, QUERIES)
                rows.append({
                    "width": w, "n_p": n_p, "lookahead": la,
                    "hops": HOPS, "pages_per_query": round(
                        HOPS * per_hop / QUERIES, 2),
                    "fused_step_us": round(fused_us / HOPS, 1),
                    "unfused_step_us": round(unfused_us / HOPS, 1),
                    "measured_overlap": round(1.0 - fused_us / unfused_us, 4),
                    "analytic_overlap": round(ana["overlap"], 4),
                    f"{dev.name}_io_us": round(ana["t_io_us"], 3),
                    f"{dev.name}_compute_us": round(ana["t_compute_us"], 3),
                })
    return rows


def search_path_check():
    """The default shape through the REAL search path: bit-identical
    results, measured fused vs split wall clock of the traced schedule."""
    from benchmarks.common import dataset, index
    ds = dataset("deep-like")
    idx = index("deep-like", "pipeline")
    cfg = get_preset("pipeline", L=48)
    r_model = idx.search(ds.queries, cfg)
    r_fused = idx.search(ds.queries, cfg.replace(pipeline="fused"))
    assert np.array_equal(r_model.ids, r_fused.ids), \
        "pipeline='fused' changed search results — the fused kernel is a " \
        "measurement surface and must not touch the result path"
    # re-time both executions of the SAME traced schedule
    store = idx.page_store(use_cache=False)
    from repro.core.search_kernel import search_batched
    st = search_batched(store, idx.pq, cfg, ds.queries[:QUERIES],
                        medoid=idx.medoid, collect_visited=False,
                        collect_trace=True, account_kernel_io=False)
    fused = measure_step_us(store, idx.pq, ds.queries[:QUERIES],
                            st.page_trace, mode="fused")
    split = measure_step_us(store, idx.pq, ds.queries[:QUERIES],
                            st.page_trace, mode="split")
    return {
        "pages_per_query": round(float(r_fused.page_reads.mean()), 2),
        "modeled_mean_latency_us": round(float(
            r_fused.summary(_ssd_model(), d=ds.d, pq_m=cfg.pq_m,
                            page_bytes=cfg.page_bytes,
                            pipeline=True)["mean_latency_us"]), 1),
        "measured_step_us_per_query": round(
            float(r_fused.measured_step_us.mean()), 1),
        "fused_wall_us": round(fused["wall_us"], 1),
        "unfused_wall_us": round(split["wall_us"], 1),
        "schedule_pages": fused["pages"],
        "measured_overlap": round(
            1.0 - fused["wall_us"] / split["wall_us"], 4)
        if split["wall_us"] else 0.0,
    }


def _ssd_model():
    from benchmarks.common import MODEL
    return MODEL


def main(argv=None):
    rows = kernel_sweep()
    cols = list(rows[0])
    print("== fused pipeline (kernel sweep: width x page size x "
          "look-ahead) ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))

    check = search_path_check()
    print("== fused pipeline (search path, default shape) ==")
    print(",".join(check))
    print(",".join(str(v) for v in check.values()))

    dw, dnp, dla = DEFAULT
    cell = next((r for r in rows
                 if (r["width"], r["n_p"], r["lookahead"]) == (dw, dnp, dla)),
                rows[0])
    faster = cell["fused_step_us"] < cell["unfused_step_us"]
    print(f"default shape w={cell['width']} n_p={cell['n_p']} "
          f"lookahead={cell['lookahead']}: fused "
          f"{'FASTER' if faster else 'SLOWER'} "
          f"({cell['fused_step_us']} vs {cell['unfused_step_us']} us/step, "
          f"measured overlap {cell['measured_overlap']}, "
          f"analytic {cell['analytic_overlap']})")
    if os.environ.get("REPRO_FP_GUARD"):
        slack = float(os.environ.get("REPRO_FP_SLACK", 1.25))
        assert cell["fused_step_us"] <= cell["unfused_step_us"] * slack, (
            f"wall-clock smoke guard: fused step "
            f"{cell['fused_step_us']}us exceeds unfused "
            f"{cell['unfused_step_us']}us x {slack} slack")
        assert check["fused_wall_us"] <= check["unfused_wall_us"] * slack, (
            f"wall-clock smoke guard (search path): fused "
            f"{check['fused_wall_us']}us exceeds unfused "
            f"{check['unfused_wall_us']}us x {slack} slack")
        print(f"guard OK (slack {slack})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
