"""Fleet traffic replay: a generated production trace served by replica
groups — the million-user serving story at simulation scale.

The trace generator composes the four load dimensions real serving fleets
are sized against, all from ONE seeded rng:

  diurnal rate        an inhomogeneous Poisson process (thinning) whose
                      rate follows a sin^2 day curve: quiet base -> peak
                      -> back to base inside the window
  Zipf-drift skew     request popularity is Zipf over the query pool and
                      the hot set DRIFTS: the rank permutation is redrawn
                      every epoch, so pages that were hot go cold
  tenant mix          each request carries a tenant id drawn from a fixed
                      mix (cache partitions + per-tenant report columns)
  mutation mix        a slice of arrivals are inserts/deletes with
                      threshold compaction (MutableIndex + Compactor)

Three acceptance scenarios run against `FleetServer`
(repro/serving/fleet.py), each recorded in the machine-readable artifact
`benchmarks/artifacts/BENCH_fleet.json` (path: REPRO_FLEET_OUT):

  1. goodput_scaling   flood a fixed 2-shard store with 1/2/4 replica
                       groups: saturation goodput must rise MONOTONICALLY
                       with the group count (more copies = more devices).
  2. migration         the diurnal + Zipf-drift + tenant trace over the
                       deliberately bad CONTIGUOUS placement, migration on
                       vs off at the SAME seed. Search results are
                       bit-identical by construction (migration moves I/O,
                       never results), so recall is matched exactly — and
                       p99 under the diurnal peak must be STRICTLY lower
                       with the hot-page rebalancer on.
  3. autoscale         the full trace (mutations included) against a
                       hysteresis autoscaler: the fleet must ADD groups on
                       the diurnal ramp, DRAIN-AND-DROP them after the
                       peak, and hold window utilization inside (or
                       correcting toward) the hysteresis band.

How to read the output: one CSV block per scenario (benchmarks/common.py
print_table); `r<N>_util` columns are per-group busy fractions, `shards`
counts (group x shard) device cells, `shard_imbalance` is max/mean issued
reads across ALL fleet devices. The JSON artifact carries the same rows
plus the boolean verdicts CI gates on.

Env knobs (dataset sizing in benchmarks/common.py):
  REPRO_FLEET_DURATION  trace window in us of virtual time (default 30000)
  REPRO_FLEET_GROUPS    scaling scenario group counts     (default 1,2,4)
  REPRO_FLEET_SHARDS    shards per group                  (default 2)
  REPRO_FLEET_FLOOD     scenario-1 flood rate in qps      (default 200000)
  REPRO_FLEET_BASE      diurnal base rate in qps (default: calibrated off
                        scenario 1's measured single-group saturation
                        goodput, so the day curve stresses the fleet the
                        same way at every dataset shape)
  REPRO_FLEET_PEAK      diurnal peak rate in qps          (same default)
  REPRO_FLEET_OUT       artifact path   (default benchmarks/artifacts/
                                         BENCH_fleet.json)
  REPRO_FLEET_GUARD     assert the three verdicts (default 1)
  REPRO_FLEET_TRACE     (or --trace) write the migration-ON run as a
                        Perfetto-loadable Chrome trace; validated before
                        writing, verdict recorded in the JSON artifact
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from benchmarks import common
from benchmarks.updates import insert_pool
from repro.core import get_preset, recall_at_k
from repro.mutation import MutableIndex, MutationConfig, MutationMix
from repro.obs import Tracer, validate_chrome_trace
from repro.serving import (AutoscaleConfig, FleetConfig, FleetServer,
                           MigrationConfig, ServerConfig)

DURATION_US = float(os.environ.get("REPRO_FLEET_DURATION", 30000.0))
GROUPS = tuple(int(g) for g in os.environ.get(
    "REPRO_FLEET_GROUPS", "1,2,4").split(","))
SHARDS = int(os.environ.get("REPRO_FLEET_SHARDS", 2))
FLOOD = float(os.environ.get("REPRO_FLEET_FLOOD", 200000.0))
# diurnal rates: explicit env overrides, else calibrated from the measured
# single-group saturation goodput (see main)
BASE_ENV = os.environ.get("REPRO_FLEET_BASE")
PEAK_ENV = os.environ.get("REPRO_FLEET_PEAK")
OUT = Path(os.environ.get(
    "REPRO_FLEET_OUT",
    Path(__file__).resolve().parent / "artifacts" / "BENCH_fleet.json"))
GUARD = os.environ.get("REPRO_FLEET_GUARD", "1") == "1"
SYSTEM = "starling"
L = 32
TRACE_SEED = 17
TENANT_MIX = (0.7, 0.3)         # two tenants, 70/30 request share


# -- trace generation --------------------------------------------------------

def diurnal_arrivals(rng: np.random.Generator, base_qps: float,
                     peak_qps: float, duration_us: float,
                     cycles: float = 1.0) -> np.ndarray:
    """Inhomogeneous Poisson arrivals by thinning: rate(t) = base +
    (peak - base) * sin^2(pi * cycles * t / duration) — one full day curve
    per `cycles` (quiet -> peak -> quiet)."""
    peak = max(base_qps, peak_qps)
    out = []
    t = 0.0
    while True:
        t += float(rng.exponential(1e6 / peak))
        if t >= duration_us:
            break
        r = base_qps + (peak_qps - base_qps) * np.sin(
            np.pi * cycles * t / duration_us) ** 2
        if rng.random() < r / peak:
            out.append(t)
    return np.asarray(out)


def zipf_drift_ids(rng: np.random.Generator, n_queries: int, length: int,
                   a: float = 1.2, epochs: int = 4) -> np.ndarray:
    """Request sequence over query ids: Zipf(a) popularity with the rank
    permutation redrawn every epoch — the hot set drifts through the pool
    over the trace, so a static hot-page ranking goes stale."""
    ranks = np.arange(1, n_queries + 1, dtype=np.float64) ** -a
    p = ranks / ranks.sum()
    per = -(-length // epochs)          # ceil
    ids = []
    for _ in range(epochs):
        perm = rng.permutation(n_queries)
        ids.append(perm[rng.choice(n_queries, size=per, p=p)])
    return np.concatenate(ids)[:length]


def make_trace(rng: np.random.Generator, queries: np.ndarray,
               duration_us: float, base: float, peak: float) -> dict:
    """The production trace: diurnal arrivals + a Zipf-drift request pool
    + a tenant id per request. `FleetServer.serve_fleet` consumes the pool
    round-robin in read order, so the pool ORDER is the drift."""
    arr = diurnal_arrivals(rng, base, peak, duration_us)
    ids = zipf_drift_ids(rng, len(queries), max(len(arr), 1))
    tenants = rng.choice(len(TENANT_MIX), size=len(ids), p=TENANT_MIX)
    return {"arrivals": arr, "ids": ids, "pool": queries[ids],
            "tenants": tenants,
            "rate_qps": len(arr) / (duration_us * 1e-6)}


def _fleet_row(tag: str, rep) -> dict:
    keep = ("qps", "p99_latency_us", "mean_latency_us", "shed",
            "cache_hit_rate", "shard_imbalance", "max_shard_util",
            "groups", "groups_final", "groups_added", "groups_dropped",
            "migrations", "promoted_pages", "mig_pages_written",
            "shed_budget", "seed")
    row = rep.row()
    return {"scenario": tag,
            **{k: row[k] for k in keep if k in row}}


# -- scenario 1: saturation goodput vs replica groups ------------------------

def goodput_scaling(name: str) -> dict:
    ds = common.dataset(name)
    cfg = get_preset(SYSTEM, L=L)
    idx = common.index(name, SYSTEM)
    scfg = ServerConfig(max_batch=16, shards=SHARDS, cache_policy="lru",
                        cache_bytes=1 << 18, prefetch=1)
    rows, qps = [], []
    for g in GROUPS:
        srv = FleetServer(idx, cfg, common.MODEL, scfg,
                          fleet_cfg=FleetConfig(replica_groups=g))
        rep = srv.serve_fleet(ds.queries, rate_qps=FLOOD,
                              duration_us=DURATION_US / 3, seed=5)
        rows.append({**_fleet_row("goodput", rep), "groups": g})
        qps.append(rep.qps)
    monotone = all(a < b for a, b in zip(qps, qps[1:]))
    return {"rows": rows, "goodput_qps": [round(q, 1) for q in qps],
            "monotone": monotone}


# -- scenario 2: hot-page migration under the diurnal peak -------------------

def migration_ab(name: str, base: float, peak: float,
                 tracer: Tracer = None) -> dict:
    """Same trace, same seed, contiguous base placement; migration on vs
    off. Results are bit-identical (recall matched by construction); the
    rebalancer must buy a strictly lower p99. A tracer, when given,
    records the migration-ON run (the one with background copy waves on
    its migration tracks)."""
    ds = common.dataset(name)
    cfg = get_preset(SYSTEM, L=L)
    idx = common.index(name, SYSTEM)
    trace = make_trace(np.random.default_rng(TRACE_SEED), ds.queries,
                       DURATION_US, base, peak)
    scfg = ServerConfig(max_batch=16, shards=SHARDS,
                        placement="contiguous", cache_policy="lru",
                        cache_bytes=1 << 18, prefetch=1,
                        tenants=len(TENANT_MIX))
    out = {}
    # a SMALL hot set in frequent, bounded waves: the replicated pages
    # must fit the per-shard cache slices, or duplication + demote churn
    # costs more misses than the device balance buys (swept: hot_frac
    # 0.2/max_moves 256 LOSES p99 by thrashing the 64-page group caches)
    for tag, mig in (("off", None),
                     ("on", MigrationConfig(every_us=DURATION_US / 10,
                                            hot_frac=0.05, max_moves=32))):
        srv = FleetServer(idx, cfg, common.MODEL, scfg,
                          fleet_cfg=FleetConfig(replica_groups=2,
                                                migration=mig))
        rep = srv.serve_fleet(
            trace["pool"], rate_qps=trace["rate_qps"],
            duration_us=DURATION_US, seed=TRACE_SEED,
            tenants=trace["tenants"], arrivals=trace["arrivals"],
            tracer=tracer if tag == "on" else None)
        rec = recall_at_k(
            rep.stats.ids, ds.gt[trace["ids"][rep.query_indices]], cfg.k)
        out[tag] = {**_fleet_row(f"migration_{tag}", rep),
                    "recall@10": round(rec, 4),
                    "p99_latency_us": round(rep.p99_latency_us, 1)}
    p99_on = out["on"]["p99_latency_us"]
    p99_off = out["off"]["p99_latency_us"]
    return {"rows": [out["off"], out["on"]],
            "p99_off": p99_off, "p99_on": p99_on,
            "p99_win": p99_on < p99_off,
            "matched_recall":
                out["on"]["recall@10"] == out["off"]["recall@10"]}


# -- scenario 3: autoscaling tracking the diurnal rate -----------------------

def autoscale_tracking(name: str, base: float, peak: float) -> dict:
    """The FULL trace (mutations included) against the hysteresis
    autoscaler: groups must be added on the ramp, drained-and-dropped
    after the peak, and the windowed occupancy must sit inside — or be
    actively corrected toward — the band."""
    ds = common.dataset(name)
    cfg = get_preset(SYSTEM, L=L)
    idx = common.index(name, SYSTEM)
    mi = MutableIndex(idx, MutationConfig(
        flush_threshold=32, growth_chunk=512, insert_L=L))
    trace = make_trace(np.random.default_rng(TRACE_SEED + 1), ds.queries,
                       2 * DURATION_US, base, peak)
    asc = AutoscaleConfig(check_every_us=DURATION_US / 10,
                          util_high=0.6, util_low=0.25,
                          min_groups=1, max_groups=4)
    srv = FleetServer(mi, cfg, common.MODEL,
                      ServerConfig(max_batch=16, shards=SHARDS,
                                   cache_policy="lru",
                                   cache_bytes=1 << 18,
                                   tenants=len(TENANT_MIX)),
                      fleet_cfg=FleetConfig(replica_groups=1,
                                            autoscale=asc))
    mix = MutationMix(insert_frac=0.02, delete_frac=0.005,
                      compaction="threshold", threshold=0.2, max_pages=16)
    rep = srv.serve_fleet(
        trace["pool"], rate_qps=trace["rate_qps"],
        duration_us=2 * DURATION_US, seed=TRACE_SEED + 1,
        tenants=trace["tenants"], arrivals=trace["arrivals"],
        mutation_mix=mix, insert_pool=insert_pool(ds.vectors))
    tl = rep.timeline or []
    # a sample tracks the band if util is inside it, the scaler just
    # acted to push it back (an out-of-band sample WITH a correction is
    # the hysteresis loop working, not failing), or the scaler is PINNED
    # at a configured bound with no corrective action left (util above
    # the band at max_groups / below it at min_groups)
    in_band = [asc.util_low <= u <= asc.util_high or ev != ""
               or (u > asc.util_high and g >= asc.max_groups)
               or (u < asc.util_low and g <= asc.min_groups)
               for _, g, u, ev in tl]
    return {"rows": [_fleet_row("autoscale", rep)],
            "timeline": [list(s) for s in tl],
            "groups_added": rep.groups_added,
            "groups_dropped": rep.groups_dropped,
            "in_band_frac": (round(float(np.mean(in_band)), 4)
                             if in_band else 0.0),
            "tracked": rep.groups_added >= 1 and rep.groups_dropped >= 1}


def main(name: str = "sift-like", trace_out: str = None) -> dict:
    tracer = Tracer() if trace_out else None
    scaling = goodput_scaling(name)
    # calibrate the day curve off the MEASURED single-group saturation
    # goodput: base well under one group (quiet tail a grown fleet must
    # scale back down from), peak several groups' worth (the ramp that
    # forces scale-up / shows migration's balancing win). The base ratio
    # is deliberately small: sat1 is measured at FULL batches, while the
    # quiet tail serves small batches whose per-query service is several
    # times worse (unamortized hop issue overhead), so 0.1 x sat1 of
    # offered load is roughly 0.5-0.7 of one group's low-rate capacity
    sat1 = max(scaling["goodput_qps"][0], 1.0)
    base = float(BASE_ENV) if BASE_ENV else round(0.1 * sat1, 1)
    peak = float(PEAK_ENV) if PEAK_ENV else round(2.5 * sat1, 1)
    result = {
        "config": {"n": common.BENCH_N, "queries": common.BENCH_Q,
                   "shards": SHARDS, "groups": list(GROUPS),
                   "duration_us": DURATION_US, "flood_qps": FLOOD,
                   "base_qps": base, "peak_qps": peak,
                   "sat1_qps": round(sat1, 1), "trace_seed": TRACE_SEED},
        "goodput_scaling": scaling,
        "migration": migration_ab(name, base, peak, tracer=tracer),
        "autoscale": autoscale_tracking(name, base, peak),
    }
    if tracer is not None:
        problems = validate_chrome_trace(tracer.to_chrome())
        tracer.export(trace_out)
        s = tracer.summary()
        result["trace"] = {
            "path": str(trace_out), "spans": len(tracer),
            "queries": s.queries, "batches": s.batches,
            "max_residual_us": s.max_residual_us,
            "valid": problems == [], "problems": problems[:10]}
    rows = (result["goodput_scaling"]["rows"]
            + result["migration"]["rows"]
            + result["autoscale"]["rows"])
    common.print_table(
        rows, cols=["scenario", "groups", "groups_final", "groups_added",
                    "groups_dropped", "qps", "p99_latency_us",
                    "migrations", "promoted_pages", "shard_imbalance",
                    "max_shard_util", "recall@10"])
    print(f"# goodput monotone in groups: "
          f"{result['goodput_scaling']['monotone']} "
          f"{result['goodput_scaling']['goodput_qps']}")
    print(f"# migration p99 win: {result['migration']['p99_win']} "
          f"(off={result['migration']['p99_off']} "
          f"on={result['migration']['p99_on']}), matched recall: "
          f"{result['migration']['matched_recall']}")
    print(f"# autoscale tracked: {result['autoscale']['tracked']} "
          f"(+{result['autoscale']['groups_added']} "
          f"-{result['autoscale']['groups_dropped']}, in-band "
          f"{result['autoscale']['in_band_frac']})")
    if "trace" in result:
        t = result["trace"]
        print(f"# trace: {t['path']} ({t['spans']} spans, "
              f"{t['queries']} queries, residual "
              f"{t['max_residual_us']:.2e}us, valid={t['valid']})")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(result, indent=2))
    print(f"# wrote {OUT}")
    if GUARD:
        assert result["goodput_scaling"]["monotone"], \
            "goodput must rise monotonically with replica groups"
        assert result["migration"]["p99_win"], \
            "migration must strictly lower p99 under the diurnal peak"
        assert result["migration"]["matched_recall"], \
            "migration must not change search results"
        assert result["autoscale"]["tracked"], \
            "autoscaler must add on the ramp and drop after the peak"
        if "trace" in result:
            assert result["trace"]["valid"], \
                f"trace invalid: {result['trace']['problems']}"
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=os.environ.get("REPRO_FLEET_TRACE"),
                    metavar="OUT.json",
                    help="record the migration-ON run as a Chrome trace")
    main(trace_out=ap.parse_args().trace)
