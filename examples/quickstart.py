#!/usr/bin/env python
"""Quickstart: build an OctopusANN index and search it.

    PYTHONPATH=src python examples/quickstart.py

Builds a small disk-layout index over a synthetic dataset, runs the paper's
baseline (DiskANN-style, PQ-filtered beam search) and the full composition
OctopusANN (PQ + MemGraph + PageShuffle + PageSearch + DynamicWidth), and
prints recall / page-I/O / modeled-QPS for both.
"""
import time

from repro.core import (SSDModel, build_index, get_preset, make_dataset,
                        recall_at_k, summarize)


def main():
    print("generating dataset (sift-like, n=4096) ...")
    ds = make_dataset("sift-like", n=4096, nq=128)

    print("building Vamana graph + baseline index ...")
    t0 = time.time()
    base = build_index(ds, get_preset("baseline"), R=24, L_build=48)
    print(f"  built in {time.time()-t0:.1f}s   "
          f"OR(G)={base.build_stats['overlap_ratio']:.4f} "
          f"records/page={base.build_stats['n_p']}")

    print("building OctopusANN index (adds shuffle + memgraph) ...")
    octo = build_index(ds, get_preset("octopusann", memgraph_frac=0.02),
                       graph=base.graph, medoid_id=base.medoid)

    model = SSDModel()
    for name, idx in [("baseline(DiskANN-style)", base), ("OctopusANN", octo)]:
        cfg = idx.cfg.replace(L=48)
        res = idx.search(ds.queries, cfg)
        rec = recall_at_k(res.ids, ds.gt, 10)
        s = summarize(model, res, d=ds.d, pq_m=cfg.pq_m,
                      page_bytes=cfg.page_bytes)
        print(f"{name:24s} recall@10={rec:.3f} "
              f"pages/q={s['mean_pages_per_query']:6.1f} "
              f"QPS={s['qps']:8.0f} latency={s['mean_latency_us']:7.1f}us "
              f"io_frac={s['io_fraction']:.2f}")


if __name__ == "__main__":
    main()
