#!/usr/bin/env python
"""Serving example #4: batched decode against a long KV cache (the
decode_32k production shape, reduced) — measures tokens/s on CPU and prints
the per-token cache-read bytes that dominate the TPU roofline for decode.

    PYTHONPATH=src python examples/serve_decode_bench.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.engine import LMServer


def main():
    cfg = get_smoke_config("chatglm3-6b")   # GQA kv=2: serving-friendly
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, prompt_len, new = 4, 64, 32
    server = LMServer(params, cfg, max_len=prompt_len + new)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (B, prompt_len)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, new_tokens=new)
    dt = time.time() - t0
    kv_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim
                * (prompt_len + new) * 2)
    print(f"decode: {B}x{new} tokens in {dt:.2f}s -> {B*new/dt:.1f} tok/s")
    print(f"per-token KV read at full size would be ~{kv_bytes/1e6:.2f} MB "
          "-> decode is HBM-bound on TPU (see §Roofline decode rows)")
    print("ok:", out.shape)


if __name__ == "__main__":
    main()
