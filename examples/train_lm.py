#!/usr/bin/env python
"""End-to-end training driver: a ~100M-param TinyLlama-family model for a
few hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(A full-size config swaps in via --arch/--no-reduce; the production-mesh
version of exactly this step function is what launch/dryrun.py compiles.)
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    # ~100M-param llama-family config (same code path as tinyllama-1.1b)
    argv = ["--arch", "tinyllama-1.1b", "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50", "--log-every", "20"]
    losses = T.main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print("training example complete; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
