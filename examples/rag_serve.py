#!/usr/bin/env python
"""RAG serving: OctopusANN retrieval feeding an LM decode loop.

    PYTHONPATH=src python examples/rag_serve.py [--arch tinyllama-1.1b]

End-to-end serving path: a corpus of synthetic passages is embedded (toy
projection), indexed with OctopusANN; each query retrieves top-k passages
whose tokens are prepended to the prompt, and the selected --arch backbone
(reduced config) decodes the answer with its KV cache. The retrieval I/O
metrics and decode throughput are reported separately.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import build_index, get_preset, make_dataset
from repro.models import init_params
from repro.serving.engine import LMServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    print("== retrieval side (the paper's system) ==")
    ds = make_dataset("deep-like", n=4096, nq=args.queries)
    idx = build_index(ds, get_preset("octopusann", memgraph_frac=0.02),
                      R=24, L_build=48)
    t0 = time.time()
    res = idx.search(ds.queries)
    print(f"retrieved top-10 for {args.queries} queries in "
          f"{time.time()-t0:.2f}s wall; pages/q={res.page_reads.mean():.1f} "
          f"hops={res.hops.mean():.1f}")

    print(f"== generation side ({args.arch}, reduced config) ==")
    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    server = LMServer(params, cfg, max_len=256)
    # toy RAG contract: retrieved passage ids become context token prefixes
    rng = np.random.default_rng(0)
    question = rng.integers(1, cfg.vocab_size, (args.queries, 8))
    context = (res.ids[:, :8] % cfg.vocab_size).astype(np.int64)
    prompts = np.concatenate([context, question], axis=1).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"decoded {args.queries}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.queries*args.new_tokens/dt:.1f} tok/s on 1 CPU core)")
    print("sample output tokens:", out[0].tolist())


if __name__ == "__main__":
    main()
