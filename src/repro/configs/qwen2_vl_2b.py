"""Qwen2-VL-2B [arXiv:2409.12191; hf] — M-RoPE (temporal/height/width), GQA kv=2.
Vision frontend is a STUB (input_specs supplies patch embeddings + 3-part
position ids). Backbone only, per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
    rope_variant="mrope", norm="rmsnorm", act="swiglu",
    frontend="vision_stub", num_frames=256,
    source="arXiv:2409.12191; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    rope_variant="mrope", norm="rmsnorm", act="swiglu",
    frontend="vision_stub", num_frames=16,
)
