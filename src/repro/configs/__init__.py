from repro.configs.base import (
    ARCH_IDS,
    SHAPE_NAMES,
    SHAPES,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    all_cells,
    applicable_shapes,
    get_config,
    get_shape,
    get_smoke_config,
)
