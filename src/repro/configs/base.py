"""Architecture / shape config system.

One ``ModelConfig`` per assigned architecture (exact numbers from the
assignment table), one ``ShapeConfig`` per assigned input shape, and a
registry used by ``--arch`` selection in the launchers, the dry-run, the
smoke tests and the benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # apply MoE every `period` layers starting at `offset`; dense otherwise
    period: int = 1
    offset: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # experts are padded so EP degree divides the expert count
    ep_pad_to: int = 16

    @property
    def padded_experts(self) -> int:
        return _round_up(self.num_experts, self.ep_pad_to)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    variant: str = "mamba"  # "mamba" | "rwkv6"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64      # rwkv6 head size
    chunk_size: int = 128   # chunked-parallel scan block

    @property
    def d_inner_factor(self) -> int:
        return self.expand


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""
    # --- attention details ---
    rope_variant: str = "full"  # full | 2d (chatglm) | mrope (qwen2-vl) | none
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"         # swiglu | gelu
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # --- MoE / SSM / hybrid ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: which layers are attention (jamba: 1 attn per `attn_period`)
    attn_period: int = 1        # 1 => every layer is attention (or ssm if family==ssm)
    attn_offset: int = 0
    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    cross_attention: bool = False
    num_frames: int = 1500      # stub frontend output length (audio frames / vision patches)
    frontend: str = "none"      # none | audio_stub | vision_stub
    # --- dtypes ---
    param_dtype: str = "bfloat16"
    # optimizer choice for the 1T-class models
    factored_second_moment: bool = False
    opt_state_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        # pad so TP=16 (and the 128-lane tile) always divides
        return _round_up(self.vocab_size, 16 * 128)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_period == 1:
            return True
        return i % self.attn_period == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe.period == self.moe.offset

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k applies."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode step (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6ND)."""
        d, L = self.d_model, self.num_layers
        n = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for i in range(L):
            if self.is_attn_layer(i):
                q = d * self.num_heads * self.head_dim
                kv = 2 * d * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * d
                n += q + kv + o
            elif self.ssm is not None:
                di = d * self.ssm.expand
                if self.ssm.variant == "rwkv6":
                    n += 5 * d * d + d * d  # r,k,v,g,o + w lora-ish (approx)
                else:
                    n += 2 * d * di + di * d + di * self.ssm.d_state * 2
            if self.is_moe_layer(i):
                e = self.moe.num_experts + self.moe.num_shared_experts
                mult = 3 if self.act == "swiglu" else 2
                n += e * mult * d * self.moe.d_ff_expert
                n += d * self.moe.num_experts  # router
            else:
                mult = 3 if self.act == "swiglu" else 2
                n += mult * d * self.d_ff
        for _ in range(self.encoder_layers):
            n += 4 * d * d + (3 if self.act == "swiglu" else 2) * d * self.d_ff
            if self.cross_attention:
                n += 4 * d * d  # decoder cross-attn blocks counted here
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k + shared."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n = self.param_count()
        # subtract inactive experts
        for i in range(L):
            if self.is_moe_layer(i):
                inactive = self.moe.num_experts - self.moe.top_k
                mult = 3 if self.act == "swiglu" else 2
                n -= inactive * mult * d * self.moe.d_ff_expert
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    mode: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCH_IDS = (
    "tinyllama-1.1b",
    "stablelm-3b",
    "chatglm3-6b",
    "stablelm-12b",
    "rwkv6-3b",
    "kimi-k2-1t-a32b",
    "qwen2-moe-a2.7b",
    "jamba-v0.1-52b",
    "whisper-small",
    "qwen2-vl-2b",
)

_MODULES = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "stablelm-3b": "stablelm_3b",
    "chatglm3-6b": "chatglm3_6b",
    "stablelm-12b": "stablelm_12b",
    "rwkv6-3b": "rwkv6_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-small": "whisper_small",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE_CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable_shapes(cfg: ModelConfig) -> Sequence[str]:
    out = []
    for s in SHAPE_NAMES:
        if s == "long_500k" and not cfg.supports_long_context:
            continue  # quadratic full attention at 524k — skipped per DESIGN.md
        out.append(s)
    return tuple(out)


def all_cells():
    """All 40 (arch, shape) cells; yields (arch, shape, applicable: bool)."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPE_NAMES:
            yield a, s, (s in applicable_shapes(cfg))
