"""Qwen2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 4 shared + 60 routed top-4.

60 routed experts are padded to 64 for EP degree 16 (masked; see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
    rope_variant="full", norm="rmsnorm", act="swiglu",
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4, ep_pad_to=16),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-moe-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=512,
    rope_variant="full", norm="rmsnorm", act="swiglu",
    moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=64,
                  num_shared_experts=2, ep_pad_to=1, capacity_factor=64.0),
)
