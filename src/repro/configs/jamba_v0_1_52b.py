"""Jamba-v0.1-52B [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE 16e top-2.

Layer i is attention iff i % 8 == 4 (1:7 ratio, matching the released model);
MoE replaces the MLP on every second layer (i % 2 == 1).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
    rope_variant="none", norm="rmsnorm", act="swiglu",
    attn_period=8, attn_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  period=2, offset=1, ep_pad_to=16),
    ssm=SSMConfig(variant="mamba", d_state=16, d_conv=4, expand=2, chunk_size=128),
    source="arXiv:2403.19887; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    rope_variant="none", norm="rmsnorm", act="swiglu",
    attn_period=2, attn_offset=1,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  period=2, offset=1, ep_pad_to=1, capacity_factor=64.0),
    ssm=SSMConfig(variant="mamba", d_state=8, d_conv=4, expand=2, chunk_size=16),
)
