"""RWKV6-3B (Finch) [arXiv:2404.05892; hf] — attention-free, data-dependent decay.

head size 64 => 40 heads at d_model=2560; channel-mix d_ff=8960 (relu^2).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, d_ff=8960, vocab_size=65536,
    rope_variant="none", norm="layernorm", act="relu2",
    ssm=SSMConfig(variant="rwkv6", head_dim=64, chunk_size=32),
    source="arXiv:2404.05892; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=512,
    rope_variant="none", norm="layernorm", act="relu2",
    ssm=SSMConfig(variant="rwkv6", head_dim=32, chunk_size=16),
)
