"""Whisper-small [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a STUB
(input_specs supplies precomputed 1500-frame embeddings). Decode shapes exercise
the decoder + cross-attention KV cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    rope_variant="none", norm="layernorm", act="gelu",
    encoder_layers=12, cross_attention=True, num_frames=1500,
    frontend="audio_stub",
    source="arXiv:2212.04356; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    rope_variant="none", norm="layernorm", act="gelu",
    encoder_layers=2, cross_attention=True, num_frames=16,
    frontend="audio_stub",
)
