"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b; unverified] — MHA (kv=32), LayerNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", num_layers=32, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=6912, vocab_size=50304,
    rope_variant="full", norm="layernorm", act="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-3b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    rope_variant="full", norm="layernorm", act="swiglu",
)
