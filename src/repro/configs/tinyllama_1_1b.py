"""TinyLlama-1.1B [arXiv:2401.02385; hf] — llama2-arch small."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense", num_layers=22, d_model=2048,
    num_heads=32, num_kv_heads=4, d_ff=5632, vocab_size=32000,
    rope_variant="full", norm="rmsnorm", act="swiglu",
    source="arXiv:2401.02385; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="tinyllama-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    rope_variant="full", norm="rmsnorm", act="swiglu",
)
