"""Kimi-K2-1T-A32B [arXiv:2501.kimi2; unverified] — trillion-param MoE, 384e top-8.

Assignment specifies GQA kv=8 (real K2 uses MLA — we follow the assignment; see
DESIGN.md). 61L x 384e x 3 x 7168 x 2048 ~ 1.03T expert params. 1 shared expert
per the public K2 spec. Optimizer: factored second moment + bf16 state so the
1T-state fits 16 GB/chip on the 512-chip mesh.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
    num_heads=64, num_kv_heads=8, d_ff=2048, vocab_size=163840,
    rope_variant="full", norm="rmsnorm", act="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, ep_pad_to=16),
    factored_second_moment=True, opt_state_dtype="bfloat16",
    source="arXiv:2501.kimi2; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="kimi-k2-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=512,
    rope_variant="full", norm="rmsnorm", act="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                  num_shared_experts=1, ep_pad_to=1, capacity_factor=64.0),
    factored_second_moment=True, opt_state_dtype="bfloat16",
)
