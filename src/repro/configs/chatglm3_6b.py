"""ChatGLM3-6B [arXiv:2406.12793; hf] — 2d RoPE (rotary on half the head dim), GQA kv=2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=65024,
    rope_variant="2d", norm="rmsnorm", act="swiglu",
    source="arXiv:2406.12793; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="chatglm3-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    rope_variant="2d", norm="rmsnorm", act="swiglu",
)
