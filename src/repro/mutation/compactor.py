"""Background compaction scheduling + the serving-side mutation workload.

The MutableIndex (repro/mutation/mutable_index.py) exposes the MECHANISM
(`compact(max_pages)`); this module owns the POLICY — when the background
repair runs against a live serving loop:

  none        never compact: the dirty set and the tombstone backlog grow
              without bound, and the append zone's locality decay compounds
              — the degradation baseline `benchmarks/updates.py` measures.
  threshold   compact (one bounded run) whenever the dirty-page fraction
              crosses `threshold` — the batch-repair shape real systems
              ship (FreshDiskANN's periodic consolidation).
  continuous  a bounded run after every dispatched batch — smallest
              backlog, steadiest I/O tax.

Scheduling contract: the compactor never runs concurrently with itself,
every run is bounded by `max_pages`, and ALL of its I/O (page reads +
rewrites) is returned to the caller so the serving loop can charge it
against the device — compaction competes with query I/O for the same
queue, which is the entire point of measuring it.

`MutationMix` is the open-loop workload spec: the fraction of arrivals
that are inserts/deletes (the rest are reads), plus the compaction policy
riding on the same config so one object describes a streaming cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

#: serve_open_loop(mutation_mix=) / benchmarks compaction policy names.
COMPACTION_POLICIES = ("none", "threshold", "continuous")


@dataclasses.dataclass(frozen=True)
class MutationMix:
    """Open-loop arrival mix + compaction policy for one streaming cell."""

    insert_frac: float = 0.0     # fraction of arrivals that are inserts
    delete_frac: float = 0.0     # fraction of arrivals that are deletes
    compaction: str = "none"     # COMPACTION_POLICIES
    threshold: float = 0.25      # dirty-page fraction that triggers a
    #                              "threshold" run
    max_pages: int = 8           # dirty-page budget per compaction run
    seed: int = 0                # DEPRECATED and unread: serve_open_loop
    #                              draws arrival kinds and delete victims
    #                              from the SAME seeded rng as the Poisson
    #                              arrivals (one seed reproduces the whole
    #                              run); kept so existing cell specs parse

    def __post_init__(self):
        if not 0.0 <= self.insert_frac <= 1.0:
            raise ValueError(
                f"insert_frac={self.insert_frac} must be in [0, 1]")
        if not 0.0 <= self.delete_frac <= 1.0:
            raise ValueError(
                f"delete_frac={self.delete_frac} must be in [0, 1]")
        if self.insert_frac + self.delete_frac > 1.0:
            raise ValueError(
                f"insert_frac + delete_frac = "
                f"{self.insert_frac + self.delete_frac} leaves no reads "
                f"(must be <= 1)")
        if self.compaction not in COMPACTION_POLICIES:
            raise ValueError(
                f"compaction={self.compaction!r} must be one of "
                f"{COMPACTION_POLICIES}")
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError(
                f"threshold={self.threshold} must be in (0, 1]")
        if self.max_pages < 1:
            raise ValueError(f"max_pages={self.max_pages} must be >= 1")

    @property
    def read_frac(self) -> float:
        return 1.0 - self.insert_frac - self.delete_frac

    @property
    def mutating(self) -> bool:
        return self.insert_frac > 0 or self.delete_frac > 0


class Compactor:
    """Policy driver binding a MutationMix's compaction schedule to a
    MutableIndex. The serving loop calls the two hooks; each returns the
    run's accounting dict (see MutableIndex.compact) or None when the
    policy declined to run."""

    def __init__(self, index, mix: MutationMix):
        self.index = index
        self.mix = mix
        self.runs = 0

    def _run(self) -> Optional[dict]:
        if not self.index.dirty_pages:
            return None
        acct = self.index.compact(self.mix.max_pages)
        self.runs += 1
        return acct

    def after_mutation(self) -> Optional[dict]:
        """Hook after every applied insert/delete/flush: the "threshold"
        policy fires here when the dirty fraction crosses the line."""
        if self.mix.compaction != "threshold":
            return None
        if self.index.dirty_fraction < self.mix.threshold:
            return None
        return self._run()

    def after_batch(self) -> Optional[dict]:
        """Hook after every dispatched query batch: the "continuous"
        policy's steady bounded repair."""
        if self.mix.compaction != "continuous":
            return None
        return self._run()
