"""Streaming-update I/O layer: page versioning + invalidation over the
store stack.

A frozen index lets every layer of the store stack assume a page's bytes
never change: kernel arrays are uploaded once, caches keep copies forever.
Streaming mutations (repro/mutation/mutable_index.py) break that — an
append flush or a compaction run rewrites pages in place — so this module
adds the one store layer that knows pages have VERSIONS:

  MutablePageStore — a pass-through decorator on TOP of any build_store
      composition (Array/Cached/Batched/SharedCache/Prefetching/Sharded).
      Reads flow through untouched with mirrored accounting, so with zero
      mutations the stack behaves bit-identically to the unwrapped one.
      On a rewrite (`invalidate`) it bumps the page's version, walks the
      stack evicting every stale cached copy (shared caches, per-shard
      caches, tenant partitions), and drops the memoized kernel/device
      arrays so the next kernel launch sees the new bytes. On an append
      (`notify_append`) it grows the version vector, extends a sharded
      placement's page→shard map, and refreshes the static vertex mask.

Write traffic (`note_write`) rides the write half of the conservation
spine: every layer books `pages_written` split by kind (`data_writes` /
`journal_writes` / `snapshot_writes`) 1:1 and forwards down, so the
invariant pages_written == data + journal + snapshot holds at every
layer of every stack — the mirror of what `charge` keeps for reads.
Reads the background jobs issue (compaction reading dirty pages) go down
the accounting-only `charge` spine as before.

Durability (PR 8): this layer is where a page write can TEAR. With a
`journal` attached (repro/mutation/journal.py: MutationJournal) every
data-page write is two-phase — a synced intent record naming the pages,
then the pages themselves — and with a `crash` attached (CrashPoint)
each of those I/O boundaries is numbered and killable, which is what the
crash-point sweep in tests/test_durability.py drives. An index-owned
journal (MutableIndex(journal=)) supersedes a store-owned one: the index
journals logical ops and ticks the crash clock itself, and its attached
stores only book the traffic.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import numpy as np

from repro.io.page_store import (StoreCounters, book_writes,
                                 note_inner_writes, resolve_write)

#: StoreCounters fields mirrored from the inner store on every delegated
#: read-path call (pages_written is booked at this layer only).
_MIRRORED = ("pages_requested", "pages_fetched", "cache_hits",
             "records_fetched")


class MutablePageStore:
    """Decorator: page versioning + rewrite invalidation over a finished
    store stack. `build_store(..., mutable=True)` composes it on top."""

    def __init__(self, inner, journal=None, crash=None):
        self.inner = inner
        self.counters = StoreCounters()
        self.page_version = np.zeros(inner.num_pages, np.int64)
        self.invalidations = 0      # stale cached copies actually evicted
        # durability hooks (repro/mutation/journal.py): a store-owned
        # journal makes every data-page write two-phase (synced intent
        # record first); a CrashPoint numbers + kills the I/O boundaries
        self.journal = journal
        self.crash = crash

    # -- delegation with mirrored accounting ---------------------------------

    def _mirrored(self, method: str, *args, **kw):
        """Forward to the inner store, mirroring its full counter movement
        into this layer — the conservation property every decorator keeps
        (pages_fetched here == the device movement below)."""
        c = self.inner.counters
        before = [getattr(c, f) for f in _MIRRORED]
        out = getattr(self.inner, method)(*args, **kw)
        for f, b in zip(_MIRRORED, before):
            setattr(self.counters, f, getattr(self.counters, f)
                    + getattr(c, f) - b)
        return out

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        return self._mirrored("fetch", page_ids, vids=vids)

    def charge(self, page_ids: np.ndarray) -> None:
        return self._mirrored("charge", page_ids)

    def note_kernel_io(self, stats) -> None:
        return self._mirrored("note_kernel_io", stats)

    #: accounting paths that exist only when the inner stack provides them
    #: (replay needs a stateful cache, coalescing needs the batch store) —
    #: resolved in __getattr__ so hasattr() mirrors the inner capability
    _MIRRORED_METHODS = ("replay_batch", "coalesce", "fetch_for_queries")

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.inner.vertex_cache_mask()

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    def __getattr__(self, name: str):
        # public reporting/config surface (savings, hit_rate, cache,
        # caches, shard_rows, tenant_hit_rates, ...) passes through; private
        # names never delegate — memoized per-store state (_kernel_cache,
        # _device_cache_mask) must live on exactly one object
        if name.startswith("_"):
            raise AttributeError(name)
        if name in self._MIRRORED_METHODS:
            getattr(self.inner, name)        # capability check (may raise)
            return lambda *a, **kw: self._mirrored(name, *a, **kw)
        return getattr(self.inner, name)

    # -- the mutation surface ------------------------------------------------

    def _layers(self) -> List:
        out = [self.inner]
        while hasattr(out[-1], "inner"):
            out.append(out[-1].inner)
        return out

    def _drop_kernel_memos(self) -> None:
        """The jitted kernel indexes device copies of the layout arrays,
        memoized on the base store (`_kernel_cache`) and the cache mask
        memoized on THIS object (`_device_cache_mask`, stamped by
        search_batched). A rewrite makes both stale."""
        self.__dict__.pop("_device_cache_mask", None)
        for layer in self._layers():
            layer.__dict__.pop("_device_cache_mask", None)
            if hasattr(layer, "_kernel_cache"):
                layer._kernel_cache = None

    def invalidate(self, page_ids: Iterable[int]) -> int:
        """Pages were rewritten in place: bump their versions and evict
        every stale cached copy anywhere in the stack (the shared cache, a
        partitioned cache's per-tenant copies, per-shard cache slices).
        Returns the number of stale copies evicted. The NEXT demand access
        of an evicted page is a charged device read — exactly the locality
        cost a rewrite inflicts on a warm cache."""
        pages = np.asarray(list(page_ids), np.int64).reshape(-1)
        if len(pages) == 0:
            return 0
        if pages.min() < 0 or pages.max() >= len(self.page_version):
            raise IndexError(
                f"page id out of range for {len(self.page_version)} pages "
                f"(after an append, call notify_append first)")
        self.page_version[pages] += 1
        evicted = 0
        for layer in self._layers():
            cache = getattr(layer, "cache", None)
            if cache is not None and hasattr(cache, "invalidate"):
                for p in pages:
                    evicted += bool(cache.invalidate(int(p)))
            caches = getattr(layer, "caches", None)
            if caches is not None:
                for c in caches:
                    for p in pages:
                        evicted += bool(c.invalidate(int(p)))
        self.invalidations += evicted
        self._drop_kernel_memos()
        return evicted

    def notify_append(self, num_pages: int,
                      vertex_mask: Optional[np.ndarray] = None) -> None:
        """The page space grew (append flush): extend the version vector
        (new pages start at version 0), extend a sharded placement's
        page→shard map, refresh the static vertex mask (`vertex_mask` is
        the full new-length mask when the stack carries a CachedPageStore),
        and drop the kernel memos — the array SHAPES changed."""
        if num_pages < len(self.page_version):
            raise ValueError(
                f"page space cannot shrink: {num_pages} < "
                f"{len(self.page_version)}")
        grow = num_pages - len(self.page_version)
        if grow:
            self.page_version = np.concatenate(
                [self.page_version, np.zeros(grow, np.int64)])
        for layer in self._layers():
            if hasattr(layer, "extend_placement"):
                layer.extend_placement(num_pages)
            if vertex_mask is not None and \
                    hasattr(layer, "cached_vertices"):
                layer.cached_vertices = np.asarray(vertex_mask, bool)
        self._drop_kernel_memos()

    def note_write(self, page_ids: Optional[Iterable[int]] = None, *,
                   kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Book device page writes, 1:1 down the spine. With a store-owned
        journal, a data write is TWO-PHASE: the page ids are first made
        durable as a synced intent record (billed as journal writes on
        this same spine), and only then do the data pages move — each one
        a numbered, killable I/O boundary when a CrashPoint is armed. A
        kill between intent and data pages is exactly the torn-write state
        recovery must survive: the journal names pages whose bytes never
        landed, and logical replay rebuilds them."""
        pages, n = resolve_write(page_ids, count)
        if kind == "data" and self.journal is not None and n:
            jpages = self.journal.append(
                "intent", [int(p) for p in pages], sync=True)
            if jpages:
                book_writes(self.counters, jpages, "journal")
                note_inner_writes(self.inner, None, "journal", jpages)
        if kind == "data" and self.crash is not None:
            for _ in range(n):
                self.crash.tick()
        book_writes(self.counters, n, kind)
        note_inner_writes(self.inner, pages, kind, n)

    def version_of(self, page: int) -> int:
        return int(self.page_version[page])
