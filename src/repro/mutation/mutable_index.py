"""Streaming index mutations: the MutableIndex over a frozen DiskIndex.

The paper's page-level complexity model prices a search as path length x
page locality — and PR 0–4 only ever measured it on a frozen index. This
module opens the streaming workload: inserts and deletes arrive while the
index serves, and the locality that `page_shuffle` bought at build time
decays measurably (the Chen et al. survey's and PageANN's open gap).

Lifecycle of a mutation
-----------------------
  insert(vec) -> vid      the vector lands in the in-memory DeltaIndex
                          (repro/mutation/delta_index.py); the disk graph
                          carries no edge to it, so the kernel is untouched
                          and search correctness comes from merging the
                          delta's exact results into the result heap.
  delete(vid)             a delta vid dies in memory; a disk vid becomes a
                          TOMBSTONE: its record and edges stay on the page
                          (it keeps routing), results are filtered, and the
                          disk search overfetches (`MutationConfig.
                          overfetch`) so filtered slots can backfill.
  flush()                 the delta backlog is written to pages in ARRIVAL
                          order (append zone) — the locality-destroying
                          baseline every real system ships first. Inserts
                          get Vamana-style edges (beam search for
                          candidates + robust prune + back-edges), touched
                          pages are rewritten/invalidated, and the pages
                          become part of the DIRTY set.
  compact(max_pages)      the background repair: a bounded slice of the
                          dirty set is re-packed with the SAME greedy
                          packer PageShuffle uses (core/page_shuffle.py:
                          greedy_pack) restricted to the dirty
                          neighborhood, tombstones are purged (in-edges
                          spliced through), wholly-freed pages return to
                          the free list, and every rewritten page is
                          invalidated in the attached stores.

Attached stores (MutablePageStore, repro/mutation/mutable_store.py) are the
I/O-layer half: every flush/compaction charges its read traffic down the
normal accounting spine, books its writes, and evicts stale cached copies,
so the serving layer can price background I/O against query I/O.

With zero mutations every path is a pure pass-through: `search` returns
the same bits as `DiskIndex.search` (the golden facade contract extends to
the wrapper — tests/test_mutation.py pins it).

Durability (PR 8)
-----------------
Construct with `journal=` (repro/mutation/journal.py: MutationJournal)
and every logical op — insert / delete / flush / compact — is appended to
the write-ahead log BEFORE it is applied; flush and compact records are
force-synced (the two-phase rule: the intent must be durable before any
data page moves), inserts and deletes ride the group-commit buffer. A
`crash=` CrashPoint additionally numbers every I/O boundary (journal
syncs + each data-page write) and kills the index at the configured one.

`recover(base, journal)` rebuilds the pre-crash state by replaying the
committed log through these same deterministic code paths — the torn
tail is discarded by checksum, attached stores are charged the replay's
reads/writes down the conservation spine, and the result is bit-identical
to an index that applied the same op prefix uninterrupted
(tests/test_durability.py sweeps every kill point to prove it).

`snapshot()` checkpoints the full mutable state (priced as sequential
snapshot writes on the spine) and truncates the journal; `restore()` /
`recover(snapshot=)` start replay from the checkpoint instead of the
pristine base. The serving loop journals its rng cursor at the end of a
mutating run, so `recovered_rng()` resumes the exact arrival/victim
stream a same-seed uninterrupted run would produce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core import pq as pq_mod
from repro.core.engine import DiskIndex, SearchConfig
from repro.core.page_shuffle import bfs_order, greedy_pack, \
    undirected_adjacency
from repro.core.pages import PageLayout, overlap_ratio
from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats
from repro.core.vamana import beam_search_mem
from repro.io import build_store
from repro.mutation.delta_index import DeltaIndex
from repro.mutation.journal import CrashPoint, MutationJournal


@dataclasses.dataclass(frozen=True)
class MutationConfig:
    """Knobs of the streaming-update subsystem."""

    flush_threshold: int = 64    # delta size at which maybe_flush() flushes
    growth_chunk: int = 256      # vid-capacity growth quantum: arrays (and
    #                              page space) grow in chunks so the jitted
    #                              kernel recompiles per CHUNK, not per flush
    insert_L: int = 32           # beam width of the insert candidate search
    insert_width: int = 2
    insert_alpha: float = 1.2    # robust-prune slack for insert edges
    overfetch: int = 16          # extra disk-side k while tombstones are
    #                              pending (filtered slots backfill)
    compaction_pages: int = 8    # default dirty-page budget per compact()

    def __post_init__(self):
        if self.flush_threshold < 1:
            raise ValueError(
                f"flush_threshold={self.flush_threshold} must be >= 1")
        if self.growth_chunk < 1:
            raise ValueError(
                f"growth_chunk={self.growth_chunk} must be >= 1")
        if self.insert_L < 1 or self.insert_width < 1:
            raise ValueError("insert_L and insert_width must be >= 1")
        if self.insert_alpha < 1.0:
            raise ValueError(
                f"insert_alpha={self.insert_alpha} must be >= 1.0")
        if self.overfetch < 0:
            raise ValueError(f"overfetch={self.overfetch} must be >= 0")
        if self.compaction_pages < 1:
            raise ValueError(
                f"compaction_pages={self.compaction_pages} must be >= 1")


def _copy_layout(lay: PageLayout) -> PageLayout:
    """A private, mutable copy of the base layout — the base DiskIndex
    (and its golden tests) must never observe a mutation."""
    return PageLayout(
        page_bytes=lay.page_bytes, n_p=lay.n_p, num_pages=lay.num_pages,
        vid2page=lay.vid2page.copy(), vid2slot=lay.vid2slot.copy(),
        page_vids=lay.page_vids.copy(), page_vecs=lay.page_vecs.copy(),
        page_nbrs=lay.page_nbrs.copy(), record_bytes=lay.record_bytes,
        mapping_bytes=lay.mapping_bytes)


class MutableIndex:
    """Streaming wrapper over a DiskIndex: delta inserts, tombstoned
    deletes, append flushes, and localized background compaction. Exposes
    the DiskIndex surface the serving layer consumes (`layout`, `pq`,
    `cached`, `medoid`, `memgraph`, `cfg`) so `AnnServer` runs unchanged on
    top."""

    def __init__(self, base: DiskIndex,
                 mcfg: Optional[MutationConfig] = None,
                 journal: Optional[MutationJournal] = None,
                 crash: Optional[CrashPoint] = None):
        self.base = base
        self.cfg: SearchConfig = base.cfg
        self.mcfg = mcfg or MutationConfig()
        self.layout = _copy_layout(base.layout)
        self.graph = base.graph.copy()
        self.pq = pq_mod.PQ(centroids=base.pq.centroids,
                            codes=base.pq.codes.copy(),
                            m=base.pq.m, dsub=base.pq.dsub)
        self.medoid = base.medoid
        self.memgraph = base.memgraph
        self.cached = base.cached.copy()
        n = self.layout.vid2page.shape[0]
        idx = np.arange(n)
        self.vectors = self.layout.page_vecs[
            self.layout.vid2page[idx], self.layout.vid2slot[idx]].copy()
        self.d = self.vectors.shape[1]
        self.n_disk = n              # vids [0, n_disk) are on pages
        self.next_vid = n            # next id handed to insert()
        # deleted[v] filters results; rows beyond n_disk are pre-marked so
        # capacity padding and never-flushed gaps can never surface
        self.deleted = np.zeros(n, bool)
        self.pending_tombstones: Set[int] = set()   # deleted, still on disk
        self.delta = DeltaIndex(self.d)
        self.dirty_pages: Set[int] = set()   # pages awaiting compaction
        self.append_pages: Set[int] = set()  # dirty subset: arrival-order
        #                                      flush zone (re-pack eligible)
        self.free_pages: List[int] = []      # wholly-empty pages, reusable
        # reverse adjacency (v -> {u : u→v}), maintained incrementally at
        # every graph write so tombstone purges find in-edges without an
        # O(n·R) full-graph scan per compaction run (the "continuous"
        # policy runs one per dispatched batch)
        self._rev: List[Set[int]] = [set() for _ in range(n)]
        src, col = np.nonzero(self.graph >= 0)
        for u, v in zip(src.tolist(),
                        self.graph[src, col].tolist()):
            self._rev[v].add(int(u))
        self.flushes = 0
        self.compactions = 0
        self._mutated = False
        self._stores: List = []      # attached MutablePageStores
        self._facade_stores: Dict[bool, object] = {}
        # --- durability (repro/mutation/journal.py) ---
        self.journal = journal       # write-ahead log of the logical ops
        self.crash = crash           # numbered-I/O-boundary fault injection
        self.ops_applied = 0         # insert/delete/flush/compact ops this
        #                              index has applied (live or replayed) —
        #                              the resume cursor a crash harness uses
        self.last_recovery_us = 0.0  # device time the last recover() cost
        #                              (consumed/reported by serve_open_loop)
        self._recovered_rng_state: Optional[dict] = None  # journaled cursor
        self._replaying = False      # recovery replay must not re-journal

    # -- DiskIndex-compatible surface ---------------------------------------

    @property
    def capacity(self) -> int:
        return self.graph.shape[0]

    @property
    def mutated(self) -> bool:
        return self._mutated

    @property
    def live_count(self) -> int:
        return int((~self.deleted[:self.n_disk]).sum()) + len(self.delta)

    @property
    def dirty_fraction(self) -> float:
        return len(self.dirty_pages) / max(self.layout.num_pages, 1)

    def overlap_ratio(self) -> float:
        """OR(G) over LIVE vertices only — the locality signal whose decay
        and repair this subsystem exists to measure."""
        return overlap_ratio(self.layout, self.graph, alive=~self.deleted)

    def mutation_stats(self) -> dict:
        return {"n_disk": self.n_disk, "delta_size": len(self.delta),
                "pending_tombstones": len(self.pending_tombstones),
                "dirty_pages": len(self.dirty_pages),
                "free_pages": len(self.free_pages),
                "flushes": self.flushes, "compactions": self.compactions,
                "live": self.live_count,
                "overlap_ratio": round(self.overlap_ratio(), 4)}

    # -- store attachment ----------------------------------------------------

    def attach_store(self, store) -> None:
        """Register a MutablePageStore built over this index's layout: every
        flush/compaction will invalidate, charge, and (on growth) extend it."""
        if not hasattr(store, "invalidate") or \
                not hasattr(store, "notify_append"):
            raise ValueError(
                "attach_store needs a MutablePageStore "
                "(build_store(..., mutable=True)) — a frozen stack cannot "
                "be invalidated")
        self._stores.append(store)

    def page_store(self, use_cache: bool = True):
        """Facade store (mirrors DiskIndex.page_store): the composed stack
        wrapped mutable and attached, memoized per cache choice."""
        key = bool(use_cache and self.cached.any())
        if key not in self._facade_stores:
            st = build_store(self.layout,
                             cached_vertices=self.cached if key else None,
                             mutable=True)
            self.attach_store(st)
            self._facade_stores[key] = st
        return self._facade_stores[key]

    # -- durability plumbing -------------------------------------------------

    def _journal_append(self, kind: str, payload=None,
                        sync: bool = False) -> None:
        """WAL discipline: the record goes to the journal BEFORE the op is
        applied. Journal pages a group commit flushes are booked on every
        attached store's write spine (`journal_writes`); the serving loop
        separately drains `journal.take_pending_io()` onto the background
        device clock. Replay never re-journals (the log already holds the
        record)."""
        if self.journal is None or self._replaying:
            return
        pages = self.journal.append(kind, payload, sync=sync)
        if pages:
            for st in self._stores:
                st.note_write(kind="journal", count=pages)

    def _crash_ticks(self, n: int) -> None:
        """One numbered, killable I/O boundary per data-page write (the
        journal ticks its own boundaries at sync time)."""
        if self.crash is not None:
            for _ in range(n):
                self.crash.tick()

    def journal_rng_state(self, state) -> None:
        """Persist the serving loop's rng cursor (a `bit_generator.state`
        dict) — force-synced, so a resumed run draws the same arrival and
        delete-victim stream an uninterrupted one would."""
        self._recovered_rng_state = state
        self._journal_append("rng", state, sync=True)

    def recovered_rng(self) -> np.random.Generator:
        """A generator positioned at the last journaled rng cursor — pass
        as `serve_open_loop(rng=)` to resume a crashed streaming run."""
        if self._recovered_rng_state is None:
            raise ValueError(
                "no rng cursor on record: the journal holds no 'rng' "
                "record (serve_open_loop journals one at the end of every "
                "mutating run over a durable index)")
        gen = np.random.default_rng(0)
        gen.bit_generator.state = self._recovered_rng_state
        return gen

    # -- mutations -----------------------------------------------------------

    def insert(self, vec: np.ndarray) -> int:
        """Stage a vector in the delta; it becomes disk-resident at the
        next flush. Returns the assigned vid."""
        vec = np.asarray(vec, np.float32).reshape(-1)
        self._journal_append("insert", vec)
        self.ops_applied += 1
        vid = self.next_vid
        self.next_vid += 1
        self.delta.insert(vid, vec)
        self._mutated = True
        return vid

    def delete(self, vid: int) -> bool:
        """Tombstone a vid. Delta vids die in memory; disk vids keep their
        record (routing) until compaction purges the page."""
        vid = int(vid)
        self._journal_append("delete", vid)
        self.ops_applied += 1
        self._mutated = True
        if vid in self.delta:
            return self.delta.remove(vid)
        if vid < 0 or vid >= self.n_disk or self.deleted[vid]:
            return False
        self.deleted[vid] = True
        self.pending_tombstones.add(vid)
        self.dirty_pages.add(int(self.layout.vid2page[vid]))
        return True

    def random_live_vid(self, rng: np.random.Generator) -> Optional[int]:
        """A uniformly random live DISK vid (delete-workload driver).
        Rejection-sampled: expected O(1) while most vids are live — this
        runs once per delete ARRIVAL in the serving ingest path, so an
        O(n) mask scan per call would make the mutation sweep scale as
        arrivals x n. The full scan is only the fallback when sampling
        keeps hitting tombstones (a mostly-dead id space)."""
        n = self.n_disk
        if n == 0:
            return None
        for _ in range(16):
            v = int(rng.integers(n))
            if not self.deleted[v]:
                return v
        alive = np.flatnonzero(~self.deleted[:n])
        if len(alive) == 0:
            return None
        return int(alive[rng.integers(len(alive))])

    @property
    def needs_flush(self) -> bool:
        return len(self.delta) >= self.mcfg.flush_threshold

    def maybe_flush(self) -> Optional[dict]:
        return self.flush() if self.needs_flush else None

    # -- capacity growth (chunked: bounds kernel recompiles) -----------------

    def _ensure_vid_capacity(self, n: int) -> None:
        cap = self.capacity
        if n <= cap:
            return
        chunk = self.mcfg.growth_chunk
        new_cap = ((n + chunk - 1) // chunk) * chunk
        grow = new_cap - cap
        self.vectors = np.concatenate(
            [self.vectors, np.zeros((grow, self.d), np.float32)])
        self.graph = np.concatenate(
            [self.graph, np.full((grow, self.graph.shape[1]), -1,
                                 self.graph.dtype)])
        self.pq.codes = np.concatenate(
            [self.pq.codes, np.zeros((grow, self.pq.m), np.uint8)])
        self.pq.__dict__.pop("_device_arrays", None)
        self.deleted = np.concatenate([self.deleted, np.ones(grow, bool)])
        self.cached = np.concatenate([self.cached, np.zeros(grow, bool)])
        self._rev.extend(set() for _ in range(grow))
        lay = self.layout
        # unassigned vids map to page 0 slot 0 — never referenced (no edge
        # reaches a vid that was never flushed)
        lay.vid2page = np.concatenate(
            [lay.vid2page, np.zeros(grow, lay.vid2page.dtype)])
        lay.vid2slot = np.concatenate(
            [lay.vid2slot, np.zeros(grow, lay.vid2slot.dtype)])

    def _ensure_free_pages(self, pages_needed: int) -> List[int]:
        """Allocate `pages_needed` wholly-empty pages, appending a CHUNK of
        empty pages to the layout when the free list runs short (shape
        growth is the expensive event — amortize it)."""
        lay = self.layout
        if len(self.free_pages) < pages_needed:
            chunk = max(1, self.mcfg.growth_chunk // lay.n_p)
            short = pages_needed - len(self.free_pages)
            grow = ((short + chunk - 1) // chunk) * chunk
            P = lay.num_pages
            lay.page_vids = np.concatenate(
                [lay.page_vids,
                 np.full((grow, lay.n_p), -1, lay.page_vids.dtype)])
            lay.page_vecs = np.concatenate(
                [lay.page_vecs,
                 np.zeros((grow,) + lay.page_vecs.shape[1:],
                          lay.page_vecs.dtype)])
            lay.page_nbrs = np.concatenate(
                [lay.page_nbrs,
                 np.full((grow,) + lay.page_nbrs.shape[1:], -1,
                         lay.page_nbrs.dtype)])
            lay.num_pages = P + grow
            self.free_pages.extend(range(P, P + grow))
        taken = self.free_pages[:pages_needed]
        del self.free_pages[:pages_needed]
        return taken

    def _notify_growth(self) -> None:
        for st in self._stores:
            st.notify_append(self.layout.num_pages, vertex_mask=self.cached)

    def _charge_background(self, read_pages: np.ndarray,
                           written_pages: np.ndarray) -> None:
        """Background I/O reaches every attached store's books: reads down
        the conservation spine, writes at the mutable layer, stale copies
        evicted."""
        touched = np.union1d(read_pages, written_pages).astype(np.int64)
        for st in self._stores:
            if len(read_pages):
                st.charge(read_pages)
            if len(written_pages):
                st.note_write(written_pages)
            if len(touched):
                st.invalidate(touched)

    # -- page rewriting ------------------------------------------------------

    def _refresh_page(self, p: int) -> None:
        """Rebuild one page's records from the authoritative per-vid state
        (vectors + graph)."""
        lay = self.layout
        row = lay.page_vids[p]
        valid = row >= 0
        if valid.any():
            vids = row[valid]
            lay.page_vecs[p][valid] = self.vectors[vids]
            lay.page_nbrs[p][valid] = self.graph[vids]
        lay.page_vecs[p][~valid] = 0.0
        lay.page_nbrs[p][~valid] = -1

    # -- insert edge construction -------------------------------------------

    def _robust_prune(self, x_vec: np.ndarray,
                      cand: np.ndarray) -> np.ndarray:
        """Numpy RobustPrune (Vamana): pick nearest candidates, killing any
        candidate an earlier pick alpha-dominates (squared-distance form)."""
        a2 = self.mcfg.insert_alpha ** 2
        R = self.graph.shape[1]
        d2 = np.sum(np.square(self.vectors[cand] - x_vec), axis=1)
        order = np.argsort(d2, kind="stable")
        cand, d2 = cand[order], d2[order]
        alive = np.ones(len(cand), bool)
        out: List[int] = []
        for j in range(len(cand)):
            if not alive[j]:
                continue
            p = int(cand[j])
            out.append(p)
            if len(out) >= R:
                break
            dpc = np.sum(np.square(self.vectors[cand] - self.vectors[p]),
                         axis=1)
            alive &= a2 * dpc > d2
        return np.asarray(out, np.int64)

    def _add_back_edge(self, u: int, x: int) -> bool:
        """Append x to N(u) (free slot, else replace the farthest neighbor
        when x is closer). Returns whether N(u) changed. Maintains the
        reverse-adjacency index."""
        row = self.graph[u]
        if (row == x).any():
            return False                     # batch-mate already wired it
        free = np.flatnonzero(row < 0)
        if len(free):
            row[free[0]] = x
            self._rev[x].add(u)
            return True
        dux = float(np.sum(np.square(self.vectors[u] - self.vectors[x])))
        dn = np.sum(np.square(self.vectors[row] - self.vectors[u]), axis=1)
        far = int(np.argmax(dn))
        if dux < float(dn[far]):
            old = int(row[far])
            row[far] = x
            if not (row == old).any():       # seed graphs can carry dups
                self._rev[old].discard(u)
            self._rev[x].add(u)
            return True
        return False

    # -- flush ---------------------------------------------------------------

    def flush(self) -> dict:
        """Materialize the delta backlog onto pages in ARRIVAL order (the
        append zone), wire the inserts into the graph, and invalidate/charge
        every touched page. Returns the I/O accounting dict the serving
        layer prices: {flushed, pages_read, pages_written, read_pages,
        written_pages}."""
        # two-phase: the flush intent is durable BEFORE any page moves —
        # recovery re-runs the whole flush from the journaled inserts
        self._journal_append("flush", None, sync=True)
        self.ops_applied += 1
        vids, vecs = self.delta.drain()
        m = len(vids)
        if m == 0:
            return {"flushed": 0, "pages_read": 0, "pages_written": 0,
                    "read_pages": np.zeros(0, np.int64),
                    "written_pages": np.zeros(0, np.int64)}
        lay = self.layout
        self._ensure_vid_capacity(self.next_vid)
        self.vectors[vids] = vecs
        self.deleted[vids] = False
        self.pq.codes[vids] = pq_mod.encode(vecs, self.pq.centroids)
        self.pq.__dict__.pop("_device_arrays", None)

        # --- place in arrival order onto wholly-empty pages ----------------
        n_p = lay.n_p
        pages = self._ensure_free_pages((m + n_p - 1) // n_p)
        for i, vid in enumerate(vids):
            p, s = pages[i // n_p], i % n_p
            lay.page_vids[p, s] = vid
            lay.vid2page[vid] = p
            lay.vid2slot[vid] = s
        self.n_disk = self.next_vid

        # --- graph wiring: beam-search candidates + robust prune -----------
        mcfg = self.mcfg
        res = beam_search_mem(self.vectors, self.graph, self.medoid, vecs,
                              L=mcfg.insert_L, width=mcfg.insert_width)
        vis = np.asarray(res["visited_ids"])
        top = np.asarray(res["ids"])
        modified: Set[int] = set()
        # two passes: every new row is FINAL before any back-edge lands in
        # it — a one-pass interleave would wipe back-edges already placed
        # into a later batch-mate's row (and desync the reverse index)
        for i, vid in enumerate(vids):
            cand = np.concatenate([vis[i], top[i], vids])
            cand = np.unique(cand[(cand >= 0) & (cand < self.n_disk)])
            cand = cand[(cand != vid) & ~self.deleted[cand]]
            if len(cand) == 0:
                cand = np.asarray([self.medoid], np.int64)
            nbrs = self._robust_prune(vecs[i], cand)
            self.graph[vid] = -1
            self.graph[vid, :len(nbrs)] = nbrs
            for u in nbrs:
                self._rev[int(u)].add(int(vid))
        for vid in vids:
            for u in self.graph[vid]:
                if u >= 0 and self._add_back_edge(int(u), int(vid)):
                    modified.add(int(u))

        # --- rewrite + account ---------------------------------------------
        # back-edge pages are read-modify-written and invalidated, but NOT
        # marked dirty: one replaced neighbor slot barely moves their
        # locality, and handing a well-packed page to the localized
        # re-packer would dismantle co-location the packer cannot see
        # (its external edges). Only the arrival-order append zone is
        # compaction-eligible.
        back_pages = ({int(lay.vid2page[u]) for u in modified}
                      - set(pages))
        for p in list(pages) + sorted(back_pages):
            self._refresh_page(p)
        written = np.asarray(sorted(set(pages) | back_pages), np.int64)
        read = np.asarray(sorted(back_pages), np.int64)  # read-modify-write
        self.dirty_pages.update(int(p) for p in pages)
        self.append_pages.update(int(p) for p in pages)
        self.flushes += 1
        self._crash_ticks(len(written))   # each data-page write can kill
        self._notify_growth()
        self._charge_background(read, written)
        return {"flushed": m, "pages_read": len(read),
                "pages_written": len(written),
                "read_pages": read, "written_pages": written}

    # -- compaction ----------------------------------------------------------

    def _live_page_links(self, v: int) -> np.ndarray:
        """Pages of v's live neighbors (the co-location signal relocation
        trades on)."""
        nb = self.graph[v]
        nb = nb[nb >= 0]
        nb = nb[~self.deleted[nb]]
        return self.layout.vid2page[nb]

    def compact(self, max_pages: Optional[int] = None) -> dict:
        """One bounded background-compaction run over up to `max_pages`
        dirty pages, in three strictly locality-non-negative steps:

        1. PURGE: tombstoned records on the selected pages are cleared in
           place (their in-edges spliced through the deleted vertex's own
           neighbors) — no survivor moves, so a well-packed page keeps its
           packing and gains a HOLE.
        2. RELOCATE: each live resident of a selected APPEND page whose
           neighbors cluster on some other page with a hole moves into
           that hole when it strictly gains co-links — delete holes become
           the landing slots that pull the append zone back toward its
           graph neighborhood (the FreshDiskANN/PageANN consolidation
           move).
        3. RE-PACK: what remains on the selected append pages is re-packed
           among those same pages with the PageShuffle greedy packer
           (core/page_shuffle.py: greedy_pack on the dirty neighborhood
           only), so mutual-neighbor inserts stop sitting in arrival
           order; wholly-emptied pages return to the free list.

        Returns the flush() accounting shape plus {compacted_pages,
        purged, relocated, repacked}."""
        budget = max_pages or self.mcfg.compaction_pages
        if budget < 1:
            raise ValueError(f"max_pages={budget} must be >= 1")
        # journal the RESOLVED budget: replay must compact the same slice
        self._journal_append("compact", int(budget), sync=True)
        self.ops_applied += 1
        if not self.dirty_pages:
            return {"compacted_pages": 0, "purged": 0, "relocated": 0,
                    "repacked": 0, "pages_read": 0, "pages_written": 0,
                    "read_pages": np.zeros(0, np.int64),
                    "written_pages": np.zeros(0, np.int64)}
        self._mutated = True
        lay = self.layout
        pages = sorted(self.dirty_pages)[:budget]
        page_set = set(int(p) for p in pages)
        pv = lay.page_vids[pages]
        vids = pv[pv >= 0]
        purged = vids[self.deleted[vids]]

        # --- 1. purge: splice in-edges, clear slots in place ---------------
        outside_touched: Set[int] = set()
        if len(purged):
            purged_set = set(int(v) for v in purged)
            # in-edges come from the incrementally maintained reverse
            # index — no O(n·R) full-graph scan per run
            hit_rows = sorted(set().union(
                *(self._rev[v] for v in purged_set)) - purged_set)
            for u in hit_rows:
                u = int(u)
                row = self.graph[u]
                present = set(int(v) for v in row if v >= 0)
                for j, v in enumerate(row):
                    if int(v) in purged_set:
                        repl = -1
                        for w in self.graph[int(v)]:
                            w = int(w)
                            if w >= 0 and w != u and not self.deleted[w] \
                                    and w not in present:
                                repl = w
                                break
                        row[j] = repl
                        self._rev[int(v)].discard(u)
                        if repl >= 0:
                            self._rev[repl].add(u)
                            present.add(repl)
                outside_touched.add(u)
            for v in purged_set:
                p, s = int(lay.vid2page[v]), int(lay.vid2slot[v])
                lay.page_vids[p, s] = -1            # the hole stays put
                for w in self.graph[v]:             # out-edges die with v
                    if w >= 0:
                        self._rev[int(w)].discard(v)
            self.graph[purged] = -1
            for v in purged_set:
                self._rev[v].clear()
                self.pending_tombstones.discard(v)
            if self.medoid in purged_set:
                # the entry point just lost its out-edges — re-elect the
                # live vertex nearest the live mean (a tombstoned medoid
                # keeps routing until THIS moment, so only purge needs it)
                alive = np.flatnonzero(~self.deleted[:self.n_disk])
                if len(alive):
                    av = self.vectors[alive]
                    mean = av.mean(axis=0)
                    self.medoid = int(alive[np.argmin(
                        np.sum(np.square(av - mean), axis=1))])

        # --- 2. relocate append residents into neighbor-page holes ---------
        relocated = 0
        reloc_targets: Set[int] = set()
        apages = [p for p in pages if p in self.append_pages]
        for p in apages:
            for s in range(lay.n_p):
                v = int(lay.page_vids[p, s])
                if v < 0:
                    continue
                links = self._live_page_links(v)
                if len(links) == 0:
                    continue
                here = int((links == p).sum())
                cands, counts = np.unique(links, return_counts=True)
                for oi in np.argsort(counts, kind="stable")[::-1]:
                    c, cnt = int(cands[oi]), int(counts[oi])
                    if cnt <= here:
                        break                       # no strict gain left
                    if c == p or (c in page_set and c in self.append_pages):
                        continue                    # re-pack handles those
                    hole = np.flatnonzero(lay.page_vids[c] < 0)
                    if len(hole) == 0:
                        continue
                    lay.page_vids[c, hole[0]] = v
                    lay.page_vids[p, s] = -1
                    lay.vid2page[v] = c
                    lay.vid2slot[v] = hole[0]
                    reloc_targets.add(c)
                    relocated += 1
                    break

        # --- 3. greedy re-pack of what remains in the append zone ----------
        repacked = 0
        packed = np.zeros(0, np.int64)
        if apages:
            rem = lay.page_vids[apages]
            rem = np.sort(rem[rem >= 0])
            if len(rem):
                lid = {int(v): i for i, v in enumerate(rem)}
                sub = np.full((len(rem), self.graph.shape[1]), -1, np.int32)
                for i, v in enumerate(rem):
                    for j, w in enumerate(self.graph[int(v)]):
                        sub[i, j] = lid.get(int(w), -1)
                adj = undirected_adjacency(sub)
                packed = rem[greedy_pack(adj, bfs_order(adj, 0), lay.n_p)]
                repacked = len(packed)
            n_p = lay.n_p
            for i, p in enumerate(apages):
                seg = packed[i * n_p:(i + 1) * n_p]
                lay.page_vids[p] = -1
                lay.page_vids[p, :len(seg)] = seg
                if len(seg):
                    lay.vid2page[seg] = p
                    lay.vid2slot[seg] = np.arange(
                        len(seg), dtype=lay.vid2slot.dtype)

        # --- bookkeeping + rewrite + account -------------------------------
        for p in pages:
            p = int(p)
            self._refresh_page(p)
            self.dirty_pages.discard(p)
            self.append_pages.discard(p)
            if not (lay.page_vids[p] >= 0).any():
                self.free_pages.append(p)
        outside_pages = (({int(lay.vid2page[u]) for u in outside_touched}
                          | reloc_targets) - page_set)
        for p in sorted(outside_pages):
            self._refresh_page(p)
        nonfree = set(int(p) for p in pages) - set(self.free_pages)
        read = np.asarray(sorted(page_set | outside_pages), np.int64)
        # freed pages need no device write — they leave the mapping
        written = np.asarray(sorted(nonfree | outside_pages), np.int64)
        self.compactions += 1
        self._crash_ticks(len(written))   # each data-page write can kill
        self._charge_background(read, written)
        return {"compacted_pages": len(pages), "purged": len(purged),
                "relocated": relocated, "repacked": repacked,
                "pages_read": len(read), "pages_written": len(written),
                "read_pages": read, "written_pages": written}

    # -- snapshots (consistent checkpoints) ----------------------------------

    def snapshot(self) -> dict:
        """A consistent checkpoint of the full mutable state: deep copies
        of the layout, graph, PQ codes, vectors, tombstones, delta
        contents, dirty/append/free page sets, counters, and the rng
        cursor. Priced as SEQUENTIAL snapshot writes on every attached
        store's spine (`snapshot_pages` = the page-space image plus the
        per-vid sidecars), and the journal is truncated — the checkpoint
        supersedes it. The returned dict feeds `restore()`/
        `recover(snapshot=)` and is never mutated by either, so one
        snapshot can seed any number of recoveries (and ROADMAP item 3's
        shard migration can ship it wholesale)."""
        lay = self.layout
        aux_bytes = (self.graph.nbytes + self.pq.codes.nbytes
                     + self.vectors.nbytes + self.deleted.nbytes)
        pages = lay.num_pages + -(-aux_bytes // lay.page_bytes)
        state = {
            "layout": _copy_layout(lay),
            "graph": self.graph.copy(),
            "codes": self.pq.codes.copy(),
            "vectors": self.vectors.copy(),
            "deleted": self.deleted.copy(),
            "cached": self.cached.copy(),
            "pending_tombstones": set(self.pending_tombstones),
            "delta": self.delta.state(),
            "dirty_pages": set(self.dirty_pages),
            "append_pages": set(self.append_pages),
            "free_pages": list(self.free_pages),
            "next_vid": self.next_vid, "n_disk": self.n_disk,
            "medoid": self.medoid,
            "flushes": self.flushes, "compactions": self.compactions,
            "mutated": self._mutated, "ops_applied": self.ops_applied,
            "rng_state": self._recovered_rng_state,
            "snapshot_pages": pages,
        }
        for st in self._stores:
            st.note_write(kind="snapshot", count=pages)
        if self.journal is not None:
            self.journal.truncate()
        return state

    def restore(self, snap: dict) -> None:
        """Load a `snapshot()` checkpoint into THIS index (built over the
        same base). Deep-copies everything out of `snap` so the snapshot
        stays reusable, and rebuilds the derived reverse adjacency."""
        self.layout = _copy_layout(snap["layout"])
        self.graph = snap["graph"].copy()
        self.pq.codes = snap["codes"].copy()
        self.pq.__dict__.pop("_device_arrays", None)
        self.vectors = snap["vectors"].copy()
        self.deleted = snap["deleted"].copy()
        self.cached = snap["cached"].copy()
        self.pending_tombstones = set(snap["pending_tombstones"])
        self.delta = DeltaIndex(self.d)
        self.delta.load(snap["delta"])
        self.dirty_pages = set(snap["dirty_pages"])
        self.append_pages = set(snap["append_pages"])
        self.free_pages = list(snap["free_pages"])
        self.next_vid = int(snap["next_vid"])
        self.n_disk = int(snap["n_disk"])
        self.medoid = int(snap["medoid"])
        self.flushes = int(snap["flushes"])
        self.compactions = int(snap["compactions"])
        self._mutated = bool(snap["mutated"])
        self.ops_applied = int(snap["ops_applied"])
        self._recovered_rng_state = snap["rng_state"]
        self._rev = [set() for _ in range(self.graph.shape[0])]
        src, col = np.nonzero(self.graph >= 0)
        for u, v in zip(src.tolist(), self.graph[src, col].tolist()):
            self._rev[v].add(int(u))
        for st in self._stores:
            st.notify_append(self.layout.num_pages, vertex_mask=self.cached)

    # -- search (the merged path) -------------------------------------------

    def disk_cfg(self, cfg: Optional[SearchConfig] = None) -> SearchConfig:
        """The SearchConfig the DISK side of a merged search runs: while
        tombstones are pending, the kernel overfetches so filtered slots
        can backfill from the candidate pool."""
        cfg = cfg or self.cfg
        if not self.pending_tombstones or self.mcfg.overfetch == 0:
            return cfg
        return cfg.replace(k=min(cfg.L, cfg.k + self.mcfg.overfetch))

    def merge_mutations(self, stats: QueryStats, queries: np.ndarray,
                        cfg: Optional[SearchConfig] = None) -> QueryStats:
        """Fold the delta's exact results into the kernel's result heap and
        filter tombstones, truncating back to cfg.k. The delta scan's
        distance evaluations are charged to `mem_evals` so the device model
        prices them."""
        cfg = cfg or self.cfg
        k = cfg.k
        ids = np.asarray(stats.ids)
        dists = np.asarray(stats.dists, np.float32)
        dead = (ids >= 0) & self.deleted[np.maximum(ids, 0)]
        dists = np.where(dead | (ids < 0), np.float32(np.inf), dists)
        ids = np.where(dead, -1, ids)
        d_ids, d_dists, evals = self.delta.search(queries, k)
        cat_ids = np.concatenate([ids.astype(np.int64), d_ids], axis=1)
        cat_d = np.concatenate([dists, d_dists], axis=1)
        order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
        stats.ids = np.take_along_axis(cat_ids, order, axis=1).astype(
            stats.ids.dtype)
        stats.dists = np.take_along_axis(cat_d, order, axis=1).astype(
            stats.dists.dtype)
        stats.mem_evals = stats.mem_evals + evals
        return stats

    def search(self, queries: np.ndarray,
               cfg: Optional[SearchConfig] = None,
               batch: int = 256) -> QueryStats:
        """The DiskIndex.search facade, mutation-aware: disk search (with
        tombstone overfetch) merged with the delta scan. With zero
        mutations this is bit-identical to the frozen facade."""
        cfg = cfg or self.cfg
        store = self.page_store(use_cache=cfg.cache_frac > 0)
        if not self._mutated:
            return search_batched(store, self.pq, cfg, queries,
                                  medoid=self.medoid,
                                  memgraph=self.memgraph, batch=batch,
                                  collect_visited=False)
        stats = search_batched(store, self.pq, self.disk_cfg(cfg), queries,
                               medoid=self.medoid, memgraph=self.memgraph,
                               batch=batch, collect_visited=False)
        return self.merge_mutations(stats, queries, cfg)


# -- crash recovery ----------------------------------------------------------

def recover(base: DiskIndex, journal: MutationJournal,
            mcfg: Optional[MutationConfig] = None,
            snapshot: Optional[dict] = None,
            model=None, attach=()) -> MutableIndex:
    """Rebuild a MutableIndex from its durable remains: the base (or a
    `snapshot()` checkpoint) plus the journal's committed record prefix.

    Replay goes through the SAME deterministic code paths the live index
    ran — insert staging, flush placement + graph wiring, compaction — so
    the recovered state is bit-identical to an index that applied the same
    op prefix uninterrupted. The journal's volatile group-commit buffer is
    dropped first (it died with the process), the torn tail is discarded
    by checksum (MutationJournal.replay), "intent" markers are skipped
    (logical replay rebuilds every page they named), and the last "rng"
    record restores the serving loop's generator cursor
    (`recovered_rng()`).

    `attach` takes MutablePageStores (built over the recovered index's
    layout) to attach BEFORE replay: the replayed flushes/compactions then
    charge their reads and book their writes down the conservation spine,
    exactly as the live run did. `model` (SSDModel, default-constructed
    when omitted) prices the recovery itself — journal pages read
    sequentially plus every redo read/write — into
    `MutableIndex.last_recovery_us`, which the next `serve_open_loop`
    reports (and clears) as its `recovery_us` column.

    Idempotent: recovering twice from the same remains yields bit-identical
    indexes (the journal is only read, the snapshot only copied)."""
    idx = MutableIndex(base, mcfg)
    for st in attach:
        idx.attach_store(st)
    if snapshot is not None:
        idx.restore(snapshot)
    journal.drop_uncommitted()
    records = journal.replay()
    redo_reads = redo_writes = 0
    idx._replaying = True
    try:
        for _seq, kind, payload in records:
            if kind == "insert":
                idx.insert(payload)
            elif kind == "delete":
                idx.delete(payload)
            elif kind == "flush":
                acct = idx.flush()
                redo_reads += acct["pages_read"]
                redo_writes += acct["pages_written"]
            elif kind == "compact":
                acct = idx.compact(payload)
                redo_reads += acct["pages_read"]
                redo_writes += acct["pages_written"]
            elif kind == "rng":
                idx._recovered_rng_state = payload
            # "intent"/"snapshot" markers carry no logical state
    finally:
        idx._replaying = False
    idx.journal = journal            # resumed ops append after the prefix
    if model is None:
        from repro.core.device_model import SSDModel
        model = SSDModel()
    idx.last_recovery_us = (
        journal.log_pages * model.read_service_us(journal.cfg.page_bytes)
        + redo_reads * model.read_service_us(idx.layout.page_bytes)
        + redo_writes * model.write_service_us(idx.layout.page_bytes))
    return idx
