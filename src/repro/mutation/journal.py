"""Durability layer, part 1: the write-ahead mutation journal.

The MutableIndex (repro/mutation/mutable_index.py) is an in-memory model
of an on-disk structure — before this module, process death lost the
delta, the tombstone set, and every in-flight compaction. The journal is
the classic fix, in logical-WAL form: every mutation is appended as a
framed record BEFORE it is applied, and `recover()` (mutable_index.py)
replays the committed prefix through the very same deterministic code
paths, reproducing the pre-crash state bit for bit.

Record framing (the simulated durable medium is a bytearray):

    [u32 body length][u32 crc32(body)][body = pickle((seq, kind, payload))]

A record is DURABLE only once its frame is in `self._log`; appends first
land in a group-commit buffer and reach the log on `sync` — either forced
(`sync=True`: flush/compact intent records, snapshot marks, rng state) or
when `JournalConfig.group_commit` records have accumulated. One sync is
one sequential device write of ceil(bytes / page_bytes) journal pages:
larger group commits amortize the per-sync page rounding, which is the
whole write-amplification story `benchmarks/updates.py` sweeps.

Torn tails: a crash can interrupt a sync half way (`CrashPoint` injects
exactly that: the buffered frames are half-written to the log before the
kill), truncate the last frame, or flip its bytes. `replay()` therefore
walks frames front to back and STOPS at the first length underrun or
crc32 mismatch — the torn tail is discarded, the committed prefix is
trusted. `tear_tail()`/`corrupt_tail()` produce those states on demand
for tests.

`CrashPoint` is the fault-injection hook shared with the data-page write
path (MutableIndex/MutablePageStore call `tick()` once per page write;
the journal ticks once per sync): construct with `kill_at=None` to count
a run's I/O boundaries, then sweep `kill_at` over 1..boundaries to kill
the run at every single one — the crash-point sweep in
tests/test_durability.py.

I/O pricing: the journal never sees the device model. It accumulates
`pending_pages`; `take_pending_io()` hands them (and clears) to whoever
owns the clock — `serve_open_loop` bills them at `write_service_us` on
the background-clock path, and the attached stores book them on the
write-conservation spine (`note_write(kind="journal")`).
"""
from __future__ import annotations

import dataclasses
import pickle
import struct
import zlib
from typing import Any, List, Optional, Tuple

#: record kinds. recover() replays the logical ops (insert/delete/flush/
#: compact); "intent" is the two-phase page-write marker MutablePageStore
#: syncs before touching data pages (replay skips it — logical replay
#: rebuilds every page); "rng" restores the serving loop's generator
#: cursor; "snapshot" marks a checkpoint boundary.
RECORD_KINDS = ("insert", "delete", "flush", "compact", "intent",
                "snapshot", "rng")

_HEADER = struct.Struct("<II")   # (body length, crc32)


@dataclasses.dataclass(frozen=True)
class JournalConfig:
    """Knobs of the write-ahead journal."""

    group_commit: int = 1        # records buffered per sync (1 = every
    #                              record is its own sequential write)
    page_bytes: int = 4096       # journal device page: one sync costs
    #                              ceil(buffered bytes / page_bytes) writes

    def __post_init__(self):
        if self.group_commit < 1:
            raise ValueError(
                f"group_commit={self.group_commit} must be >= 1")
        if self.page_bytes < 1:
            raise ValueError(
                f"page_bytes={self.page_bytes} must be >= 1")


class CrashError(RuntimeError):
    """The injected process death: raised by CrashPoint.tick() at the
    configured I/O boundary. Carries the boundary number so a sweep
    harness can label the kill."""

    def __init__(self, boundary: int):
        super().__init__(f"injected crash at I/O boundary {boundary}")
        self.boundary = boundary


class CrashPoint:
    """Numbered-I/O-boundary fault injection. Every journal sync and every
    data-page write is one boundary (`tick()`); with `kill_at=None` the
    object only counts (`boundaries` after a run is the sweep range), with
    `kill_at=k` the k-th boundary raises CrashError."""

    def __init__(self, kill_at: Optional[int] = None):
        if kill_at is not None and kill_at < 1:
            raise ValueError(f"kill_at={kill_at} must be >= 1 (boundaries "
                             f"are numbered from 1)")
        self.kill_at = kill_at
        self.boundaries = 0
        self.fired = False

    def fires_next(self) -> bool:
        return self.kill_at is not None \
            and self.boundaries + 1 == self.kill_at

    def tick(self) -> None:
        self.boundaries += 1
        if self.kill_at is not None and self.boundaries == self.kill_at:
            self.fired = True
            raise CrashError(self.boundaries)


class MutationJournal:
    """Append-only mutation log over a simulated durable medium.

    The uncommitted group-commit buffer models the volatile write path: a
    crash loses it (and may tear the in-flight sync's bytes into the log —
    see `sync`), while everything in `self._log` survives and `replay()`
    returns it. Sequence numbers are assigned at append time and strictly
    increase; replay validates monotonicity so a corrupted middle record
    cannot silently reorder recovery.
    """

    def __init__(self, cfg: Optional[JournalConfig] = None,
                 crash: Optional[CrashPoint] = None):
        self.cfg = cfg or JournalConfig()
        self.crash = crash
        self._log = bytearray()      # the durable medium
        self._buf: List[bytes] = []  # frames awaiting group commit
        self.seq = 0                 # last sequence number handed out
        self.commits = 0             # syncs that reached the log
        self.records_appended = 0
        self.pages_written = 0       # lifetime journal page writes
        self.pending_pages = 0       # unbilled pages (take_pending_io)
        self.torn_records = 0        # set by replay(): tail dropped as torn

    # -- append / commit ----------------------------------------------------

    @staticmethod
    def _frame(seq: int, kind: str, payload: Any) -> bytes:
        body = pickle.dumps((seq, kind, payload), protocol=4)
        return _HEADER.pack(len(body), zlib.crc32(body)) + body

    def append(self, kind: str, payload: Any = None, *,
               sync: bool = False) -> int:
        """Append one record; returns the journal pages committed by THIS
        call (0 while the record merely joined the group-commit buffer).
        `sync=True` forces the commit — flush/compact intent records must
        be durable before any data page moves (the two-phase rule)."""
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown record kind {kind!r}; one of "
                             f"{RECORD_KINDS}")
        self.seq += 1
        self.records_appended += 1
        self._buf.append(self._frame(self.seq, kind, payload))
        if sync or len(self._buf) >= self.cfg.group_commit:
            return self.sync()
        return 0

    def sync(self) -> int:
        """Commit the buffer as ONE sequential write of
        ceil(bytes / page_bytes) journal pages. This is an I/O boundary:
        with a CrashPoint armed for it, HALF the buffered bytes reach the
        log before the kill — the torn tail replay() must discard."""
        if not self._buf:
            return 0
        blob = b"".join(self._buf)
        if self.crash is not None:
            if self.crash.fires_next():
                self._log += blob[:len(blob) // 2]   # torn write
            self.crash.tick()
        self._log += blob
        self._buf.clear()
        pages = -(-len(blob) // self.cfg.page_bytes)
        self.commits += 1
        self.pages_written += pages
        self.pending_pages += pages
        return pages

    def take_pending_io(self) -> int:
        """Journal pages committed since the last take — the serving loop
        drains this onto the background device clock (write units)."""
        pages, self.pending_pages = self.pending_pages, 0
        return pages

    # -- durable-state inspection -------------------------------------------

    @property
    def log_bytes(self) -> int:
        return len(self._log)

    @property
    def log_pages(self) -> int:
        """Pages a recovery must READ to replay the log."""
        return -(-len(self._log) // self.cfg.page_bytes)

    def replay(self) -> List[Tuple[int, str, Any]]:
        """Decode the DURABLE log into (seq, kind, payload) records,
        discarding the torn tail: the walk stops at the first truncated
        frame, crc32 mismatch, undecodable body, or non-monotone sequence
        number. `self.torn_records` reports whether a tail was dropped."""
        out: List[Tuple[int, str, Any]] = []
        view = bytes(self._log)
        off = 0
        self.torn_records = 0
        last_seq = 0
        while off + _HEADER.size <= len(view):
            length, crc = _HEADER.unpack_from(view, off)
            body = view[off + _HEADER.size: off + _HEADER.size + length]
            if len(body) < length or zlib.crc32(body) != crc:
                self.torn_records = 1
                break
            try:
                seq, kind, payload = pickle.loads(body)
            except Exception:
                self.torn_records = 1
                break
            if seq <= last_seq or kind not in RECORD_KINDS:
                self.torn_records = 1
                break
            out.append((seq, kind, payload))
            last_seq = seq
            off += _HEADER.size + length
        if off != len(view):
            self.torn_records = 1
        return out

    # -- crash surface for tests --------------------------------------------

    def drop_uncommitted(self) -> int:
        """Model the crash's loss of the volatile buffer; returns how many
        records evaporated. (recover() only ever reads the log, so this is
        bookkeeping hygiene for harnesses that reuse the object.)"""
        n = len(self._buf)
        self._buf.clear()
        return n

    def tear_tail(self, nbytes: int = 1) -> None:
        """Truncate the durable log mid-frame (a torn append)."""
        if nbytes < 1:
            raise ValueError(f"nbytes={nbytes} must be >= 1")
        del self._log[max(0, len(self._log) - nbytes):]

    def corrupt_tail(self) -> None:
        """Flip a byte in the last frame's body (bit rot the crc catches)."""
        if not self._log:
            raise ValueError("cannot corrupt an empty journal")
        self._log[-1] ^= 0xFF

    # -- snapshot interplay --------------------------------------------------

    def truncate(self) -> int:
        """A consistent snapshot supersedes the log: drop it (and any
        uncommitted buffer — the snapshot captured that state directly).
        Returns the bytes released. Sequence numbers keep increasing so
        post-snapshot records never collide with pre-snapshot ones."""
        released = len(self._log)
        self._log = bytearray()
        self._buf.clear()
        return released
