"""Streaming index mutations: delta graph, tombstones, page versioning,
and background compaction (see docs in each module and ARCHITECTURE.md)."""
from repro.mutation.compactor import (COMPACTION_POLICIES, Compactor,
                                      MutationMix)
from repro.mutation.delta_index import DeltaIndex
from repro.mutation.mutable_index import MutableIndex, MutationConfig
from repro.mutation.mutable_store import MutablePageStore

__all__ = ["COMPACTION_POLICIES", "Compactor", "DeltaIndex",
           "MutableIndex", "MutablePageStore", "MutationConfig",
           "MutationMix"]
