"""Streaming index mutations: delta graph, tombstones, page versioning,
background compaction, and the durability layer — write-ahead journal,
crash-point fault injection, snapshots, and `recover()` (see docs in each
module and ARCHITECTURE.md)."""
from repro.mutation.compactor import (COMPACTION_POLICIES, Compactor,
                                      MutationMix)
from repro.mutation.delta_index import DeltaIndex
from repro.mutation.journal import (RECORD_KINDS, CrashError, CrashPoint,
                                    JournalConfig, MutationJournal)
from repro.mutation.mutable_index import (MutableIndex, MutationConfig,
                                          recover)
from repro.mutation.mutable_store import MutablePageStore

__all__ = ["COMPACTION_POLICIES", "Compactor", "CrashError", "CrashPoint",
           "DeltaIndex", "JournalConfig", "MutableIndex",
           "MutablePageStore", "MutationConfig", "MutationJournal",
           "MutationMix", "RECORD_KINDS", "recover"]
