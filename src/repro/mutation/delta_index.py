"""Streaming-update memory tier: the delta index over fresh inserts.

FreshDiskANN-style staging: an inserted vector is NOT written to the disk
layout at insert time — it lands in this in-memory delta, is searched
exactly (bruteforce over at most `flush_threshold` vectors) alongside every
disk search, and only reaches pages when the mutable index flushes the
backlog. Until then the disk graph carries no edge to it, so the kernel
never sees a vid beyond the layout and the golden facade stays
bit-identical while the delta is empty.

The bruteforce cost is REAL and charged: `search` reports the number of
full-precision distance evaluations it performed per query, which the
mutable index folds into `QueryStats.mem_evals` — the device model then
prices delta scans exactly like any other in-memory distance work, so a
lazily-flushed fat delta visibly taxes every query's latency. (A mini-graph
over the delta is the natural upgrade once deltas outgrow bruteforce; at
`flush_threshold`-bounded sizes the scan is the honest baseline.)
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

INF = np.float32(np.inf)


class DeltaIndex:
    """Exact in-memory index over vectors inserted since the last flush."""

    def __init__(self, d: int):
        self.d = int(d)
        self._vecs: List[np.ndarray] = []
        self._vids: List[int] = []
        self._pos: Dict[int, int] = {}   # vid -> slot in the lists

    def __len__(self) -> int:
        return len(self._vids)

    def __contains__(self, vid: int) -> bool:
        return int(vid) in self._pos

    def insert(self, vid: int, vec: np.ndarray) -> None:
        vec = np.asarray(vec, np.float32).reshape(-1)
        if vec.shape[0] != self.d:
            raise ValueError(f"vector has dim {vec.shape[0]}, delta holds "
                             f"dim {self.d}")
        vid = int(vid)
        if vid in self._pos:
            raise ValueError(f"vid {vid} already in the delta")
        self._pos[vid] = len(self._vids)
        self._vids.append(vid)
        self._vecs.append(vec)

    def remove(self, vid: int) -> bool:
        """Delete-before-flush: the vector never existed on disk, so the
        tombstone resolves entirely in memory (swap-remove)."""
        vid = int(vid)
        pos = self._pos.pop(vid, None)
        if pos is None:
            return False
        last = len(self._vids) - 1
        if pos != last:
            self._vids[pos] = self._vids[last]
            self._vecs[pos] = self._vecs[last]
            self._pos[self._vids[pos]] = pos
        self._vids.pop()
        self._vecs.pop()
        return True

    def state(self) -> Tuple[np.ndarray, np.ndarray]:
        """Peek the backlog WITHOUT draining: (vids (m,), vecs (m, d)) in
        insertion order — the snapshot/checkpoint path (a snapshot must
        capture the delta but leave the live index untouched)."""
        if not self._vids:
            return (np.zeros(0, np.int64), np.zeros((0, self.d), np.float32))
        return (np.asarray(self._vids, np.int64),
                np.stack(self._vecs).astype(np.float32))

    def load(self, state: Tuple[np.ndarray, np.ndarray]) -> None:
        """Restore a `state()` capture into this (empty) delta, preserving
        insertion order."""
        vids, vecs = state
        if len(self):
            raise ValueError(
                f"load() needs an empty delta (holds {len(self)} vectors)")
        for vid, vec in zip(np.asarray(vids, np.int64),
                            np.asarray(vecs, np.float32)):
            self.insert(int(vid), vec)

    def drain(self) -> Tuple[np.ndarray, np.ndarray]:
        """Hand the backlog to a flush: (vids (m,), vecs (m, d)) in
        insertion order, clearing the delta."""
        if not self._vids:
            return (np.zeros(0, np.int64), np.zeros((0, self.d), np.float32))
        order = np.argsort(np.asarray(self._vids, np.int64), kind="stable")
        vids = np.asarray(self._vids, np.int64)[order]
        vecs = np.stack(self._vecs)[order].astype(np.float32)
        self._vids.clear()
        self._vecs.clear()
        self._pos.clear()
        return vids, vecs

    def search(self, queries: np.ndarray,
               k: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Exact top-k over the delta for each query. Returns
        (ids (B, k) int64 with -1 padding, dists (B, k) float32 with +inf
        padding, evals_per_query) — squared L2, matching the kernel's
        distance space so the merged heap compares like with like."""
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        m = len(self._vids)
        ids = np.full((B, k), -1, np.int64)
        dists = np.full((B, k), INF, np.float32)
        if m == 0:
            return ids, dists, 0
        X = np.stack(self._vecs).astype(np.float32)           # (m, d)
        d2 = (np.sum(np.square(queries), 1)[:, None]
              - 2.0 * queries @ X.T + np.sum(np.square(X), 1)[None, :])
        d2 = np.maximum(d2, 0.0).astype(np.float32)
        take = min(k, m)
        order = np.argsort(d2, axis=1, kind="stable")[:, :take]
        vids = np.asarray(self._vids, np.int64)
        ids[:, :take] = vids[order]
        dists[:, :take] = np.take_along_axis(d2, order, axis=1)
        return ids, dists, m
