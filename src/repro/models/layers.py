"""Core NN primitives (pure JAX, no flax): norms, RoPE variants, GQA attention
with blocked (flash-style) softmax, dense MLPs.

Parameters are plain nested dicts of jnp arrays; init fns are pure so the
full-size configs can be materialized as ShapeDtypeStructs via jax.eval_shape
in the dry-run without allocating.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers


def _dense_init(key, in_dim, out_dim, dtype):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def _embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def init_norm(dim, norm_type, dtype):
    p = {"scale": jnp.ones((dim,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p, x, norm_type, eps=1e-5):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if norm_type == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE variants
#   full  : rotate the whole head_dim (llama)
#   2d    : rotate only the first half of head_dim (chatglm-style 2d rope)
#   mrope : qwen2-vl multimodal rope — head_dim split in sections rotated with
#           (temporal, height, width) position streams


def _rope_angles(positions, rot_dim, theta):
    """positions (..., S) -> (..., S, rot_dim/2) angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return positions[..., None].astype(jnp.float32) * inv


def _rotate(x, angles):
    """x (..., S, H, rot_dim) with angles (..., S, rot_dim/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, variant, theta=10000.0, mrope_sections=(16, 24, 24)):
    """x: (B, S, H, D). positions: (B, S) int or (3, B, S) for mrope."""
    if variant == "none":
        return x
    d = x.shape[-1]
    if variant == "full":
        ang = _rope_angles(positions, d, theta)              # (B,S,d/2)
        return _rotate(x, ang).astype(x.dtype)
    if variant == "2d":
        rot = d // 2
        xr, xp = x[..., :rot], x[..., rot:]
        ang = _rope_angles(positions, rot, theta)
        return jnp.concatenate([_rotate(xr, ang).astype(x.dtype), xp], axis=-1)
    if variant == "mrope":
        # positions: (3, B, S); sections over half-dims. qwen2-vl uses
        # (16, 24, 24) at head_dim 128; scale the same 1:1.5:1.5 split
        # proportionally for other head dims (smoke configs).
        half = d // 2
        secs = list(mrope_sections)
        if sum(secs) != half:
            t = max(1, half // 4)
            h = (half - t) // 2
            secs = [t, h, half - t - h]
        ang_full = _rope_angles(positions, d, theta)          # (3,B,S,half)
        parts, off = [], 0
        for i, s in enumerate(secs):
            parts.append(ang_full[i, ..., off:off + s])
            off += s
        ang = jnp.concatenate(parts, axis=-1)                 # (B,S,half)
        return _rotate(x, ang).astype(x.dtype)
    raise ValueError(variant)


# ---------------------------------------------------------------------------
# attention


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": _dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": _dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": _dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def blocked_attention(q, k, v, *, causal, q_offset=0, block=1024):
    """Flash-style streaming-softmax attention, blocked over KV.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D) with H % KV == 0.
    Memory is O(Sq x block) per head instead of O(Sq x Skv).
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)

    nblk = (skv + block - 1) // block
    pad = nblk * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, kv, d)
    vb = v.reshape(b, nblk, block, kv, d)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        kj, vj, j = xs
        kv_pos = j * block + jnp.arange(block)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kj.astype(jnp.float32)) * scale
        if causal:
            mask = kv_pos[None, :] <= q_pos[:, None]
        else:
            mask = jnp.ones((sq, block), bool)
        mask = jnp.logical_and(mask, (kv_pos < skv)[None, :])
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vj.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    # remat each kv-block step: backward recomputes scores/masks instead of
    # saving (B,KV,G,Sq,block)-sized residuals per block
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(step), (acc0, m0, l0), (kb_t, vb_t, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len):
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); cur_len: scalar int32 —
    number of valid cache positions (including the token just written).
    """
    b, _, h, d = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qf = q.reshape(b, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, k_cache.astype(jnp.float32))
    s = s / math.sqrt(d)
    mask = jnp.arange(smax) < cur_len
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention_apply(p, x, cfg, *, positions, cache=None, cache_index=None,
                    kv_override=None, causal=True):
    """GQA attention. Returns (out, new_cache).

    cache: None (train/prefill, no cache kept) or dict(k, v) of
    (B, Smax, KV, D) — decode writes at `cache_index` then attends.
    kv_override: (k, v) already-projected cross-attention KV (whisper).
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = _split_heads(x @ p["wq"], cfg.num_heads, hd)
    if kv_override is None:
        k = _split_heads(x @ p["wk"], cfg.num_kv_heads, hd)
        v = _split_heads(x @ p["wv"], cfg.num_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_variant, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_variant, cfg.rope_theta)
    else:
        k, v = kv_override

    extra = None
    if cache is not None and kv_override is None:
        # decode: write this token's kv into the cache at cache_index
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        extra = {"k": kc, "v": vc}
        out = decode_attention(q, kc, vc, cache_index + 1)
    elif cache is not None:
        out = decode_attention(q, k, v, k.shape[1])  # cross-attn, full source
        extra = cache
    else:
        out = blocked_attention(q, k, v, causal=causal)
        extra = {"k": k, "v": v}  # projected kv, so prefill can fill a cache
    out = out.reshape(b, s, cfg.num_heads * hd)
    return out @ p["wo"], extra


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(key, d_model, d_ff, act, dtype):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": _dense_init(ks[0], d_model, d_ff, dtype),
            "wg": _dense_init(ks[1], d_model, d_ff, dtype),
            "wo": _dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "wi": _dense_init(ks[0], d_model, d_ff, dtype),
        "wo": _dense_init(ks[2], d_ff, d_model, dtype),
    }


def apply_mlp(p, x, act):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        raise ValueError(act)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# embeddings


def init_embed(key, vocab, dim, dtype):
    return {"table": _embed_init(key, vocab, dim, dtype)}


def sinusoidal_positions(length, dim):
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)
