"""Expert-parallel Mixture-of-Experts.

Design (see DESIGN.md §5):
  - experts sharded over the `model` mesh axis (EP); expert d_ff additionally
    sharded over `data` (FSDP) and — for the 1T-class config — expert d_model
    over `pod`. Weights are all-gathered per layer inside the shard_map body
    (classic FSDP), which shows up honestly in the collective roofline term.
  - tokens stay sharded over the data axes and are *replicated* along `model`,
    so dispatch needs no all-to-all: each device scatters its local tokens
    into buffers for its local experts, runs the expert FFNs, scatters back,
    and a single psum over `model` combines partial outputs (same collective
    volume as a standard TP MLP all-reduce).
  - sort-based static-capacity dispatch (MaxText-style): no (T, E, C) one-hot
    dispatch tensor is ever materialized (which would be TBs at 384 experts).
  - experts padded to a multiple of the EP degree (qwen2-moe: 60 -> 64),
    padded experts masked to -inf in the router.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init, apply_mlp, init_mlp


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.padded_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * scale
                   ).astype(jnp.float32),  # router kept f32 (standard practice)
        "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
               * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * m.num_shared_experts, cfg.act, dtype)
    return p


def _capacity(tokens_local: int, m) -> int:
    c = int(math.ceil(tokens_local * m.top_k * m.capacity_factor / m.padded_experts))
    c = max(8, ((c + 7) // 8) * 8)
    # no point exceeding the worst case (every token to one expert)
    return min(c, ((tokens_local * m.top_k + 7) // 8) * 8)


def _dispatch_local(x2, top_idx, gates, wi, wg, wo, *, e_off, e_loc, cap,
                    psum_axes=()):
    """Per-device expert compute. x2 (T, D); top_idx/gates (T, K);
    wi/wg (e_loc, D, F), wo (e_loc, F, D) — already gathered to full D/F."""
    t, d = x2.shape
    k = top_idx.shape[1]
    flat_e = top_idx.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)

    local = (flat_e >= e_off) & (flat_e < e_off + e_loc)
    le = jnp.where(local, flat_e - e_off, e_loc)          # e_loc == drop bucket
    order = jnp.argsort(le)                                # stable group-by-expert
    le_s, tok_s, g_s = le[order], flat_t[order], flat_g[order]

    # rank within expert group: position - group start
    starts = jnp.searchsorted(le_s, jnp.arange(e_loc + 1))
    pos = jnp.arange(t * k) - starts[jnp.clip(le_s, 0, e_loc)]
    ok = (le_s < e_loc) & (pos < cap)
    slot = jnp.where(ok, le_s * cap + pos, e_loc * cap)    # overflow row dropped

    # Keep all (T*K, D)-sized intermediates out of memory: map slots -> token
    # ids / gate weights first, then gather/scatter in compact slot space.
    n_slot = e_loc * cap
    tok_for_slot = jnp.full((n_slot + 1,), t, jnp.int32).at[slot].set(
        tok_s.astype(jnp.int32))[:-1]
    gate_for_slot = jnp.zeros((n_slot + 1,), x2.dtype).at[slot].set(
        jnp.where(ok, g_s, 0.0).astype(x2.dtype))[:-1]
    x_pad = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)], axis=0)
    h = x_pad[jnp.minimum(tok_for_slot, t)].reshape(e_loc, cap, d)

    up = jnp.einsum("ecd,edf->ecf", h, wi.astype(x2.dtype))
    gate = jnp.einsum("ecd,edf->ecf", h, wg.astype(x2.dtype))
    act = jax.nn.silu(gate) * up
    out_e = jnp.einsum("ecf,efd->ecd", act, wo.astype(x2.dtype))

    flat_out = out_e.reshape(n_slot, d) * gate_for_slot[:, None]
    y = jnp.zeros((t + 1, d), x2.dtype).at[tok_for_slot].add(flat_out)[:-1]
    for ax in psum_axes:
        y = jax.lax.psum(y, ax)
    return y


def router_topk(p, x2, m):
    """Returns (gates (T,K) f32, idx (T,K) i32, aux_loss scalar)."""
    logits = x2.astype(jnp.float32) @ p["router"]
    if m.padded_experts > m.num_experts:
        pad_mask = jnp.arange(m.padded_experts) >= m.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing loss (bincount, no (T,E,K) one-hot)
    counts = jnp.zeros((m.padded_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    pbar = probs.mean(0)
    aux = m.num_experts * jnp.sum(f * pbar)
    return gates, idx, aux


def apply_moe(p, x, cfg, parallel=None):
    """x (B, S, D) -> (out (B,S,D), aux_loss).

    parallel: repro.parallel.api.ParallelContext or None (single-device path,
    used by smoke tests and CPU examples).
    """
    m = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)

    if parallel is None or not parallel.has_axis("model"):
        gates, idx, aux = router_topk(p, x2, m)
        gates = gates.astype(x.dtype)
        y = _dispatch_local(
            x2, idx, gates, p["wi"], p["wg"], p["wo"],
            e_off=0, e_loc=m.padded_experts,
            cap=_capacity(b * s, m))
    else:
        mesh = parallel.mesh
        ep = mesh.shape["model"]
        e_loc = m.padded_experts // ep
        dp_axes = parallel.batch_axes(b)   # axes the batch is sharded over
        dp_size = parallel.axes_size(dp_axes)
        t_loc = (b * s) // dp_size
        cap = _capacity(t_loc, m)
        waxes = parallel.moe_weight_axes(cfg)   # dict: d_model/d_ff -> axis|None

        tok_spec = P(dp_axes if dp_axes else None, None)
        wi_spec = P("model", waxes["d_model"], waxes["d_ff"])
        wo_spec = P("model", waxes["d_ff"], waxes["d_model"])

        quant = getattr(parallel, "gather_quant", False)

        def gather(w, ax_name, ax):
            """FSDP weight gather, optionally in fp8 (halves the wire bytes
            of the dominant kimi-1T collective — §Perf kimi iteration)."""
            if quant:
                w8 = w.astype(jnp.float8_e4m3fn)
                w8 = jax.lax.all_gather(w8, ax_name, axis=ax, tiled=True)
                return w8.astype(w.dtype)
            return jax.lax.all_gather(w, ax_name, axis=ax, tiled=True)

        def body(x2_l, router_l, wi_l, wg_l, wo_l):
            # router + top_k on LOCAL tokens (§Perf kimi iteration 2:
            # hoisting it outside shard_map made GSPMD all-gather the
            # (tokens, E) probs — 91.5 GiB/step on kimi)
            gates_l, idx_l, aux_l = router_topk({"router": router_l}, x2_l, m)
            gates_l = gates_l.astype(x2_l.dtype)
            if dp_axes:
                aux_l = jax.lax.pmean(aux_l, dp_axes)
            e_off = jax.lax.axis_index("model") * e_loc
            # FSDP gather of this layer's expert weights
            if waxes["d_ff"] is not None:
                wi_l = gather(wi_l, waxes["d_ff"], 2)
                wg_l = gather(wg_l, waxes["d_ff"], 2)
                wo_l = gather(wo_l, waxes["d_ff"], 1)
            if waxes["d_model"] is not None:
                wi_l = gather(wi_l, waxes["d_model"], 1)
                wg_l = gather(wg_l, waxes["d_model"], 1)
                wo_l = gather(wo_l, waxes["d_model"], 2)
            y_l = _dispatch_local(
                x2_l, idx_l, gates_l, wi_l, wg_l, wo_l,
                e_off=e_off, e_loc=e_loc, cap=cap, psum_axes=("model",))
            return y_l, aux_l

        y, aux = jax.shard_map(
            body, mesh=mesh,
            in_specs=(tok_spec, P(None, None), wi_spec, wi_spec, wo_spec),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(x2, p["router"], p["wi"], p["wg"], p["wo"])

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x2, cfg.act)
    return y.reshape(b, s, d), aux
