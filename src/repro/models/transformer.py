"""Stage-based transformer stack covering all assigned families.

Layers are grouped into repeating *stages* of length
lcm(attn_period, moe_period) (1 for homogeneous stacks, 8 for Jamba) and the
stack `lax.scan`s over stages with stacked parameters, so HLO size is
independent of depth (61-layer Kimi-K2 compiles the same module as 2 layers).

Modes:
  train   — full-seq causal, returns logits (+ MoE aux loss)
  prefill — full-seq causal, also returns populated KV caches / SSM states
  decode  — single token against caches at position `cur_index`
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# structure


def stage_len(cfg) -> int:
    sl = cfg.attn_period
    if cfg.moe is not None:
        sl = math.lcm(sl, cfg.moe.period)
    return sl


def num_stages(cfg) -> int:
    sl = stage_len(cfg)
    assert cfg.num_layers % sl == 0 or sl == 1, (cfg.num_layers, sl)
    return math.ceil(cfg.num_layers / sl)


def mixer_kind(cfg, j: int) -> str:
    if cfg.family == "ssm":
        return cfg.ssm.variant
    if cfg.is_attn_layer(j):
        return "attn"
    return cfg.ssm.variant  # hybrid non-attn layers


def ffn_kind(cfg, j: int) -> str:
    if cfg.family == "ssm" and cfg.ssm.variant == "rwkv6":
        return "rwkv_cm"  # channel-mix lives inside the rwkv params
    return "moe" if cfg.is_moe_layer(j) else "mlp"


# ---------------------------------------------------------------------------
# init


def _init_block(key, cfg, j, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.init_norm(cfg.d_model, cfg.norm, dtype)}
    mk = mixer_kind(cfg, j)
    if mk == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
        if cfg.cross_attention:
            p["ln_x"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
            p["xattn"] = L.init_attention(ks[2], cfg, dtype)
    elif mk == "rwkv6":
        p["rwkv"] = SSM.init_rwkv6(ks[0], cfg, dtype)
    elif mk == "mamba":
        p["mamba"] = SSM.init_mamba(ks[0], cfg, dtype)
    fk = ffn_kind(cfg, j)
    if fk != "rwkv_cm":
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
        if fk == "moe":
            p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    else:
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    return p


def _init_stage(key, cfg, dtype):
    sl = stage_len(cfg)
    ks = jax.random.split(key, sl)
    return {f"pos{j}": _init_block(ks[j], cfg, j, dtype) for j in range(sl)}


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    ns = num_stages(cfg)
    params = {
        "embed": L.init_embed(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "stages": jax.vmap(lambda k: _init_stage(k, cfg, dtype))(
            jax.random.split(ks[1], ns)),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "lm_head": L._dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dtype),
    }
    if cfg.encoder_layers:
        params["encoder"] = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(ks[3], cfg.encoder_layers))
        params["enc_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    return params


# ---------------------------------------------------------------------------
# caches


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Decode-state pytree, stacked over stages per stage-position."""
    ns = num_stages(cfg)
    sl = stage_len(cfg)

    def stk(x):
        return jnp.broadcast_to(x[None], (ns,) + x.shape)

    cache: Dict[str, Any] = {}
    for j in range(sl):
        mk = mixer_kind(cfg, j)
        c: Dict[str, Any] = {}
        if mk == "attn":
            kv = {
                "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            }
            c["kv"] = jax.tree.map(stk, kv)
            if cfg.cross_attention:
                xkv = {
                    "k": jnp.zeros((batch, cfg.num_frames, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((batch, cfg.num_frames, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                }
                c["xkv"] = jax.tree.map(stk, xkv)
        elif mk == "rwkv6":
            c["rwkv"] = jax.tree.map(stk, SSM.rwkv6_state_init(cfg, batch))
        elif mk == "mamba":
            c["mamba"] = jax.tree.map(stk, SSM.mamba_state_init(cfg, batch))
        cache[f"pos{j}"] = c
    return cache


# ---------------------------------------------------------------------------
# blocks


def _apply_block(bp, x, cfg, j, *, mode, positions, cache, cur_index, parallel,
                 enc_out=None):
    """One layer. Returns (x, new_cache_j, aux)."""
    aux = jnp.zeros((), jnp.float32)
    mk = mixer_kind(cfg, j)
    new_cache = dict(cache) if cache is not None else None

    h = L.apply_norm(bp["ln1"], x, cfg.norm)
    if mk == "attn":
        kv_cache = cache.get("kv") if (cache is not None and mode == "decode") else None
        out, extra = L.attention_apply(
            bp["attn"], h, cfg, positions=positions,
            cache=kv_cache, cache_index=cur_index)
        if mode == "decode":
            new_cache["kv"] = extra
        elif mode == "prefill" and cache is not None and "kv" in cache:
            new_cache["kv"] = {
                "k": jax.lax.dynamic_update_slice(
                    cache["kv"]["k"],
                    extra["k"].astype(cache["kv"]["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["kv"]["v"],
                    extra["v"].astype(cache["kv"]["v"].dtype), (0, 0, 0, 0)),
            }
        x = x + out
        if cfg.cross_attention:
            h2 = L.apply_norm(bp["ln_x"], x, cfg.norm)
            if mode == "decode":
                xkv = (cache["xkv"]["k"], cache["xkv"]["v"])
                out2, _ = L.attention_apply(
                    bp["xattn"], h2, cfg, positions=positions,
                    cache=cache["xkv"], kv_override=xkv, cache_index=cur_index)
            else:
                k = L._split_heads(enc_out @ bp["xattn"]["wk"],
                                   cfg.num_kv_heads, cfg.head_dim)
                v = L._split_heads(enc_out @ bp["xattn"]["wv"],
                                   cfg.num_kv_heads, cfg.head_dim)
                out2, _ = L.attention_apply(
                    bp["xattn"], h2, cfg, positions=positions,
                    kv_override=(k, v), causal=False)
                if cache is not None:  # prefill fills the cross cache
                    new_cache["xkv"] = {"k": k.astype(cache["xkv"]["k"].dtype),
                                        "v": v.astype(cache["xkv"]["v"].dtype)}
            x = x + out2
    elif mk == "rwkv6":
        st = {"shift": cache["rwkv"]["shift_tm"], "wkv": cache["rwkv"]["wkv"]}
        out, nst = SSM.rwkv6_time_mix(bp["rwkv"], h, cfg, st)
        new_cache["rwkv"] = dict(cache["rwkv"])
        new_cache["rwkv"]["shift_tm"] = nst["shift"].astype(
            cache["rwkv"]["shift_tm"].dtype)
        new_cache["rwkv"]["wkv"] = nst["wkv"]
        x = x + out
    elif mk == "mamba":
        out, nst = SSM.mamba_mix(bp["mamba"], h, cfg, cache["mamba"])
        new_cache["mamba"] = {
            "conv": nst["conv"].astype(cache["mamba"]["conv"].dtype),
            "ssm": nst["ssm"]}
        x = x + out

    fk = ffn_kind(cfg, j)
    h = L.apply_norm(bp["ln2"], x, cfg.norm)
    if fk == "moe":
        out, aux = MOE.apply_moe(bp["moe"], h, cfg, parallel)
    elif fk == "rwkv_cm":
        out, nshift = SSM.rwkv6_channel_mix(bp["rwkv"], h,
                                            cache["rwkv"]["shift_cm"])
        new_cache["rwkv"]["shift_cm"] = nshift.astype(
            cache["rwkv"]["shift_cm"].dtype)
    else:
        out = L.apply_mlp(bp["mlp"], h, cfg.act)
    x = x + out
    return x, new_cache, aux


def _needs_cache(cfg, mode):
    # SSM/hybrid layers always carry state (even in "train" we thread zeros,
    # cheap and uniform); attention only caches for prefill/decode.
    return True


def _stage_fn(cfg, mode, parallel, positions, cur_index, enc_out):
    sl = stage_len(cfg)

    def f(carry, xs):
        x, aux = carry
        sp, sc = xs
        new_sc = {}
        for j in range(sl):
            cj = sc[f"pos{j}"] if sc is not None else None
            x, ncj, a = _apply_block(
                sp[f"pos{j}"], x, cfg, j, mode=mode, positions=positions,
                cache=cj, cur_index=cur_index, parallel=parallel,
                enc_out=enc_out)
            if parallel is not None:
                x = parallel.constrain_tokens_major(x, x.shape[0])
            new_sc[f"pos{j}"] = ncj if ncj is not None else cj
            aux = aux + a
        return (x, aux), new_sc

    return f


def _encoder(params, cfg, frames):
    """frames: (B, F, D) stub embeddings."""
    pos = L.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames + pos[None].astype(frames.dtype)

    def f(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        out, _ = L.attention_apply(lp["attn"], h, cfg,
                                   positions=jnp.arange(frames.shape[1])[None],
                                   causal=False)
        x = x + out
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        return x + L.apply_mlp(lp["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(f, x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def forward(params, cfg, tokens, *, mode="train", cache=None, cur_index=None,
            frames=None, mrope_positions=None, parallel=None,
            remat_policy="none"):
    """tokens (B,S) int32. Returns dict(logits, cache, aux_loss)."""
    b, s = tokens.shape
    x = params["embed"]["table"][tokens]

    if cfg.rope_variant == "mrope":
        positions = (mrope_positions if mrope_positions is not None
                     else jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s)))
        if mode == "decode":
            positions = positions + cur_index
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if mode == "decode":
            positions = positions + cur_index
    if cfg.rope_variant == "none" and cfg.family in ("audio",):
        if mode == "decode":
            max_len = cache["pos0"]["kv"]["k"].shape[2]
            table = L.sinusoidal_positions(max_len, cfg.d_model)
            pos = jax.lax.dynamic_slice_in_dim(table, cur_index, 1, axis=0)
        else:
            pos = L.sinusoidal_positions(max(s, 1), cfg.d_model)[:s]
        x = x + pos[None].astype(x.dtype)

    enc_out = None
    if cfg.encoder_layers and mode != "decode":
        assert frames is not None, "whisper needs stub frame embeddings"
        enc_out = _encoder(params, cfg, frames)

    if cache is None:
        cache = init_cache(cfg, b, 1 if mode == "train" else s)
        if mode == "train":
            # attention layers don't need a real cache in train mode
            pass

    if parallel is not None:
        x = parallel.constrain_tokens_major(x, b)

    fn = _stage_fn(cfg, mode, parallel, positions, cur_index, enc_out)
    if remat_policy != "none":
        # "full": save only layer inputs, recompute everything in backward
        # (the +33% recompute shows up honestly in the roofline compute term)
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        fn = jax.checkpoint(fn, policy=policy)
    (x, aux), new_cache = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                       (params["stages"], cache))

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = x @ params["lm_head"]
    return {"logits": logits, "cache": new_cache, "aux_loss": aux}
