from repro.models.model import (
    abstract_params,
    decode_step,
    input_specs,
    loss_fn,
    prefill_step,
)
from repro.models.transformer import forward, init_cache, init_params
