"""Public model API: init / loss / train-prefill-decode steps / input_specs.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of the given (arch x shape) cell — weak-type-correct, shardable, no
device allocation — consumed by the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.transformer import forward, init_cache, init_params


def loss_fn(params, cfg, batch, parallel=None, remat_policy="none"):
    """Next-token cross-entropy + MoE aux loss. batch: dict(tokens (B,S))."""
    tokens = batch["tokens"]
    out = forward(params, cfg, tokens, mode="train",
                  frames=batch.get("frames"),
                  mrope_positions=batch.get("mrope_positions"),
                  parallel=parallel, remat_policy=remat_policy)
    logits = out["logits"].astype(jnp.float32)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    # mask padded-vocab targets (never produced by our pipeline, but safe)
    ce = (logz - gold).mean()
    aux = 0.01 * out["aux_loss"]
    return ce + aux, {"ce": ce, "aux": out["aux_loss"]}


def prefill_step(params, cfg, batch, parallel=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, s)
    out = forward(params, cfg, tokens, mode="prefill", cache=cache,
                  frames=batch.get("frames"),
                  mrope_positions=batch.get("mrope_positions"),
                  parallel=parallel)
    # next-token logits from the last position
    return out["logits"][:, -1], out["cache"]


def decode_step(params, cfg, tokens, cache, cur_index, parallel=None,
                mrope_positions=None):
    """tokens (B,1) int32; cur_index scalar int32. Returns (logits, cache)."""
    out = forward(params, cfg, tokens, mode="decode", cache=cache,
                  cur_index=cur_index, parallel=parallel,
                  mrope_positions=mrope_positions)
    return out["logits"][:, -1], out["cache"]


# ---------------------------------------------------------------------------
# dry-run input specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape) -> Dict[str, Any]:
    """ShapeDtypeStructs for every input of (cfg, shape). For decode shapes
    this includes the KV-cache/SSM-state pytree (input AND output of the
    step). Modality frontends are stubs: precomputed frame/patch embeddings.
    """
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.mode in ("train", "prefill"):
        specs["tokens"] = _sds((b, s), jnp.int32)
        if cfg.frontend == "audio_stub":
            specs["frames"] = _sds((b, cfg.num_frames, cfg.d_model), jnp.bfloat16)
        if cfg.rope_variant == "mrope":
            specs["mrope_positions"] = _sds((3, b, s), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["cur_index"] = _sds((), jnp.int32)
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        specs["cache"] = cache
        if cfg.rope_variant == "mrope":
            specs["mrope_positions"] = _sds((3, b, 1), jnp.int32)
    return specs


def abstract_params(cfg, dtype=None):
    """Parameter ShapeDtypeStructs without allocation (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))
