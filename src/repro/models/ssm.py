"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba selective scan.

Both use a *chunked parallel* form: within a chunk of length C the recurrence
is evaluated with dense (MXU-shaped) matmuls in log-decay space; across chunks
a `lax.scan` carries the recurrent state. This keeps HLO size independent of
sequence length and the live working set O(B * C * state) instead of
O(B * S * state) — the reason jamba/rwkv6 can run the long_500k shape.

RWKV6 keeps the Finch hallmark — *data-dependent decay* w_t produced by a
low-rank MLP — with static token-shift mixing coefficients (one shared LoRA
for the decay only; the five-way per-channel LoRA mixes of the full release
are simplified, as noted in DESIGN.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, apply_norm, init_norm

# RWKV chunk numerics: the matmul chunk form rescales keys by exp(-cumsum
# log decay); the cumsum is clamped at +/-60 (safe in f32) and the default
# chunk is kept small enough that typical decays stay inside the range —
# pairs that straddle the clamp correspond to contributions <= e^-60.
# (Mamba needs no clamp: its chunk scan is an exact linear-space
# associative scan.)
_LOG_CLIP = 60.0


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    hk = cfg.ssm.head_dim
    h = d // hk
    lora = max(32, d // 32)
    ks = jax.random.split(key, 12)
    return {
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "wr": _dense_init(ks[0], d, d, dtype), "wk": _dense_init(ks[1], d, d, dtype),
        "wv": _dense_init(ks[2], d, d, dtype), "wg": _dense_init(ks[3], d, d, dtype),
        "wo": _dense_init(ks[4], d, d, dtype),
        "w_base": jnp.full((d,), -1.0, jnp.float32),
        "lora_a": _dense_init(ks[5], d, lora, dtype),
        "lora_b": (jax.random.normal(ks[6], (lora, d), jnp.float32) * 0.01
                   ).astype(dtype),
        "u": (jax.random.normal(ks[7], (h, hk), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_x": init_norm(d, "layernorm", dtype),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype), "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": _dense_init(ks[8], d, cfg.d_ff, dtype),
        "cm_wv": _dense_init(ks[9], cfg.d_ff, d, dtype),
        "cm_wr": _dense_init(ks[10], d, d, dtype),
    }


def _shift(x, prev):
    """Token shift: x_{t-1}; prev (B, D) is the last token of the previous
    segment (zeros at sequence start)."""
    return jnp.concatenate(
        [prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _chunked_wkv(r, k, v, w, u, state, chunk):
    """r/k/w: (B,S,H,K) f32; v: (B,S,H,V) f32; w in (0,1); u: (H,K).
    state: (B,H,K,V). Returns (out (B,S,H,V), new_state)."""
    b, s, h, kk = r.shape
    vv = v.shape[-1]
    n = s // chunk
    rc = r.reshape(b, n, chunk, h, kk)
    kc = k.reshape(b, n, chunk, h, kk)
    vc = v.reshape(b, n, chunk, h, vv)
    lw = jnp.log(jnp.clip(w, 1e-8, 1.0)).reshape(b, n, chunk, h, kk)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    def step(S, xs):
        rj, kj, vj, lwj = xs                       # (B,C,H,*)
        cum = jnp.cumsum(lwj, axis=1)              # inclusive log-decay prods
        cum = jnp.clip(cum, -_LOG_CLIP, 0.0)
        c_excl = jnp.exp(cum - lwj)                # prod of w_1..w_{t-1}
        r_t = rj * c_excl
        k_t = kj * jnp.exp(-cum)
        # inter-chunk: r~ @ S
        inter = jnp.einsum("bchk,bhkv->bchv", r_t, S)
        # intra-chunk (strictly causal)
        att = jnp.einsum("bchk,bdhk->bhcd", r_t, k_t)
        att = att * causal[None, None]
        intra = jnp.einsum("bhcd,bdhv->bchv", att, vj)
        # diagonal bonus term u
        bonus = jnp.einsum("bchk,hk,bchk->bch", rj, u, kj)
        out = inter + intra + bonus[..., None] * vj
        c_last = jnp.exp(cum[:, -1])               # (B,H,K)
        S_new = c_last[..., None] * (S + jnp.einsum("bchk,bchv->bhkv", k_t, vj))
        return S_new, out

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lw, 1, 0))
    state, out = jax.lax.scan(jax.checkpoint(step), state, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, vv)
    return out, state


def rwkv6_time_mix(p, x, cfg, state):
    """state: dict(shift (B,D), wkv (B,H,K,V)). Returns (out, new_state)."""
    b, s, d = x.shape
    hk = cfg.ssm.head_dim
    h = d // hk
    xprev = (_shift(x, state["shift"]) if s > 1
             else state["shift"][:, None, :].astype(x.dtype))

    def mix(mu):
        return x + (xprev - x) * mu

    r = mix(p["mu_r"]) @ p["wr"]
    k = mix(p["mu_k"]) @ p["wk"]
    v = mix(p["mu_v"]) @ p["wv"]
    g = mix(p["mu_g"]) @ p["wg"]
    # Finch data-dependent decay
    dw = jnp.tanh(mix(p["mu_w"]) @ p["lora_a"]) @ p["lora_b"]
    w = jnp.exp(-jnp.exp(p["w_base"] + dw.astype(jnp.float32)))  # (B,S,D)

    rh = r.reshape(b, s, h, hk).astype(jnp.float32)
    kh = k.reshape(b, s, h, hk).astype(jnp.float32)
    vh = v.reshape(b, s, h, hk).astype(jnp.float32)
    wh = w.reshape(b, s, h, hk)

    if s == 1:  # decode step: plain recurrence
        S = state["wkv"]
        kv = jnp.einsum("bhk,bhv->bhkv", kh[:, 0], vh[:, 0])
        out = jnp.einsum("bhk,bhkv->bhv", rh[:, 0], S + p["u"][..., None] * kv)
        S = wh[:, 0][..., None] * S + kv
        out = out[:, None]
    else:
        chunk = min(cfg.ssm.chunk_size, s)
        assert s % chunk == 0, (s, chunk)
        out, S = _chunked_wkv(rh, kh, vh, wh, p["u"], state["wkv"], chunk)

    out = out.reshape(b, s, d).astype(x.dtype)
    out = apply_norm(p["ln_x"], out, "layernorm")
    out = (out * jax.nn.silu(g)) @ p["wo"]
    return out, {"shift": x[:, -1, :], "wkv": S}


def rwkv6_channel_mix(p, x, state):
    """state: shift (B, D)."""
    s = x.shape[1]
    xprev = (_shift(x, state) if s > 1 else state[:, None, :].astype(x.dtype))
    xk = x + (xprev - x) * p["cm_mu_k"]
    xr = x + (xprev - x) * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"])
    return out, x[:, -1, :]


def rwkv6_state_init(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    hk = cfg.ssm.head_dim
    h = d // hk
    return {
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, hk, hk), jnp.float32),
    }


# ===========================================================================
# Mamba (selective scan, as used in Jamba)
# ===========================================================================


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = d * cfg.ssm.expand
    n = cfg.ssm.d_state
    dtr = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.d_conv, di), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], di, dtr + 2 * n, dtype),
        "dt_proj": _dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(x, w, b, conv_state):
    """Depthwise causal conv. x (B,S,Di), w (K,Di), conv_state (B,K-1,Di)."""
    kk = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(kk))
    new_state = xp[:, -(kk - 1):, :] if kk > 1 else conv_state
    return out + b, new_state


def mamba_mix(p, x, cfg, state):
    """state: dict(conv (B,K-1,Di), ssm (B,Di,N)). Returns (out, new_state)."""
    b, s, d = x.shape
    di = d * cfg.ssm.expand
    n = cfg.ssm.d_state
    dtr = p["dt_proj"].shape[0]

    xz = x @ p["in_proj"]
    xh, z = jnp.split(xz, 2, axis=-1)
    xh, conv_state = _causal_conv(xh, p["conv_w"], p["conv_b"], state["conv"])
    xh = jax.nn.silu(xh)

    dbc = xh @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :dtr].astype(jnp.float32)
                         @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    b_ssm = dbc[..., dtr:dtr + n].astype(jnp.float32)
    c_ssm = dbc[..., dtr + n:].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                                   # (Di,N)

    xf = xh.astype(jnp.float32)
    if s == 1:
        h = state["ssm"]
        decay = jnp.exp(dt[:, 0][..., None] * a)               # (B,Di,N)
        inc = (dt[:, 0] * xf[:, 0])[..., None] * b_ssm[:, 0][:, None, :]
        h = decay * h + inc
        y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None]
        ssm_state = h
    else:
        chunk = min(cfg.ssm.chunk_size, s)
        assert s % chunk == 0
        nc = s // chunk

        def step(h0, xs):
            dt_j, b_j, c_j, x_j = xs                           # (B,C,*)
            decay = jnp.exp(dt_j[..., None] * a)                # (B,C,Di,N)
            inc = (dt_j * x_j)[..., None] * b_j[:, :, None, :]

            # associative scan in linear space: exact (products underflow to
            # the true limit instead of breaking decay ratios as a clipped
            # log-space cumsum would — see DESIGN.md numerics note)
            def comb(l, r):
                dl, il = l
                dr, ir = r
                return dl * dr, dr * il + ir

            pd, pi = jax.lax.associative_scan(comb, (decay, inc), axis=1)
            hs = pd * h0[:, None] + pi
            y_j = jnp.einsum("bcdn,bcn->bcd", hs, c_j)
            return hs[:, -1], y_j

        xs = tuple(v.reshape(b, nc, chunk, -1).swapaxes(0, 1)
                   for v in (dt, b_ssm, c_ssm, xf))
        # remat: backward recomputes the (B,C,Di,N) chunk states from the
        # carried (B,Di,N) chunk boundary instead of saving them all
        ssm_state, y = jax.lax.scan(jax.checkpoint(step), state["ssm"], xs)
        y = y.swapaxes(0, 1).reshape(b, s, di)

    y = y + p["d_skip"] * xf
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba_state_init(cfg, batch, dtype=jnp.float32):
    di = cfg.d_model * cfg.ssm.expand
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32),
    }
