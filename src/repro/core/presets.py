"""Named technique compositions: the paper's systems (Table 2) and the
combination study C1..C5 (§7.1), including OctopusANN = C5."""
from __future__ import annotations

from repro.core.engine import SearchConfig

_MG = dict(memgraph_frac=0.01, memgraph_entries=4)


def _mk(name, **kw):
    return SearchConfig(name=name, **kw)


PRESETS = {
    # --- single-factor configurations (§6) --------------------------------
    "baseline": _mk("baseline"),                           # PQ only (DiskANN minus cache)
    "cache": _mk("cache", cache_frac=0.01),
    "memgraph": _mk("memgraph", **_MG),
    "pageshuffle": _mk("pageshuffle", page_shuffle=True),
    "pagesearch": _mk("pagesearch", page_search=True),
    "dynamicwidth": _mk("dynamicwidth", dynamic_width=True),
    "pipeline": _mk("pipeline", pipeline=True),
    "ais": _mk("ais", all_in_storage=True),
    # --- combination study (§7.1) -----------------------------------------
    "C1": _mk("C1", page_shuffle=True, page_search=True),
    "C2": _mk("C2", pipeline=True, dynamic_width=True),
    "C3": _mk("C3", page_shuffle=True, page_search=True, **_MG),
    "C4": _mk("C4", pipeline=True, dynamic_width=True, **_MG),
    "C5": _mk("C5", page_shuffle=True, page_search=True, dynamic_width=True,
              **_MG),
    # --- systems (Table 2) --------------------------------------------------
    "diskann": _mk("diskann", cache_frac=0.01),
    "starling": _mk("starling", page_shuffle=True, page_search=True, **_MG),
    "pipeann": _mk("pipeann", pipeline=True, dynamic_width=True, **_MG),
    "aisaq": _mk("aisaq", all_in_storage=True),
    "octopusann": _mk("octopusann", page_shuffle=True, page_search=True,
                      dynamic_width=True, **_MG),
}


def get_preset(name: str, **overrides) -> SearchConfig:
    cfg = PRESETS[name]
    return cfg.replace(**overrides) if overrides else cfg
