"""MemGraph (§4.1.3): a memory-resident navigation graph over a random sample
of the base vectors. Queries first search the sampled graph (pure compute, no
page I/O), and the best hits become high-quality entry points for the
disk-resident search — shortening convergence paths (Finding 3)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import vamana


@dataclasses.dataclass
class MemGraph:
    sample_ids: np.ndarray   # (s,) int32 — vids of sampled vertices
    vectors: np.ndarray      # (s, d) float32 (memory-resident)
    graph: np.ndarray        # (s, R') int32
    medoid: int              # index into the sample
    build_s: float

    @property
    def memory_bytes(self) -> int:
        # topology + sample ids only is the paper's accounting for MemGraph;
        # we also keep sampled vectors resident (navigation needs them)
        return self.graph.nbytes + self.sample_ids.nbytes + self.vectors.nbytes

    def entry_points(self, queries: np.ndarray, n_entries: int = 4,
                     L: int = 32, width: int = 2) -> dict:
        """Returns dict(entries (B, n_entries) int32 vids in the FULL id
        space, hops (B,), dist_evals per query)."""
        res = vamana.beam_search_mem(self.vectors, self.graph, self.medoid,
                                     queries, L=L, width=width)
        ids = np.asarray(res["ids"])[:, :n_entries]
        valid = ids < self.vectors.shape[0]
        entries = np.where(valid, self.sample_ids[np.maximum(ids, 0)], -1)
        hops = np.asarray(res["hops"])
        # distance evaluations in memory: hops * width * R'
        evals = hops * width * self.graph.shape[1]
        return {"entries": entries.astype(np.int32), "hops": hops,
                "dist_evals": evals}


def build_memgraph(vectors: np.ndarray, frac: float = 0.01, R: int = 48,
                   L: int = 64, seed: int = 0) -> MemGraph:
    n = vectors.shape[0]
    s = max(64, int(round(frac * n)))
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.choice(n, s, replace=False)).astype(np.int32)
    sub = vectors[ids].astype(np.float32)
    g, med, stats = vamana.build_vamana(sub, R=min(R, s - 1), L=min(L, s),
                                        alpha=1.2, seed=seed,
                                        batch=min(1024, s))
    return MemGraph(sample_ids=ids, vectors=sub, graph=g, medoid=med,
                    build_s=stats["build_s"])
