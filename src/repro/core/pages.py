"""Page/layout abstraction — the paper's disk-layout dimension (§4.2).

A page stores n_p records; a record is (vector, degree, neighbor ids) exactly
like DiskANN's page-aligned format (Fig. 1). All-in-Storage (AiSAQ, §4.2.2)
additionally co-locates the PQ codes of the record's neighbors, which shrinks
n_p and grows the on-disk footprint — modeled by `record_bytes`.

On TPU (see DESIGN.md §2) a page is an HBM tile of shape (n_p, d) fetched to
VMEM by the page_scan Pallas kernel; n_p is padded to a sublane multiple.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PageLayout:
    page_bytes: int
    n_p: int                 # records per page
    num_pages: int
    vid2page: np.ndarray     # (n,) int32
    vid2slot: np.ndarray     # (n,) int32
    page_vids: np.ndarray    # (P, n_p) int32, -1 padded
    page_vecs: np.ndarray    # (P, n_p, d) float32   — the "disk"
    page_nbrs: np.ndarray    # (P, n_p, R) int32, -1 padded
    record_bytes: int
    mapping_bytes: int       # in-memory vid->page table cost (page shuffle)

    @property
    def disk_bytes(self) -> int:
        return self.num_pages * self.page_bytes


def records_per_page(page_bytes: int, d: int, vec_bytes_per_dim: int, R: int,
                     all_in_storage: bool = False, pq_m: int = 16) -> tuple:
    """DiskANN record: vector + degree(4B) + R neighbor ids (4B each).
    AiSAQ adds own PQ code + R neighbor PQ codes (pq_m bytes each)."""
    rec = d * vec_bytes_per_dim + 4 + 4 * R
    if all_in_storage:
        rec += pq_m * (R + 1)
    return max(1, page_bytes // rec), rec


def build_layout(vectors: np.ndarray, graph: np.ndarray, *,
                 page_bytes: int = 4096, vec_bytes_per_dim: int = 4,
                 perm: Optional[np.ndarray] = None,
                 all_in_storage: bool = False, pq_m: int = 16) -> PageLayout:
    """perm: order[i] = vid stored at global slot i (None => id order)."""
    n, d = vectors.shape
    R = graph.shape[1]
    n_p, rec = records_per_page(page_bytes, d, vec_bytes_per_dim, R,
                                all_in_storage, pq_m)
    order = np.arange(n, dtype=np.int32) if perm is None else perm.astype(np.int32)
    num_pages = (n + n_p - 1) // n_p
    pad = num_pages * n_p - n
    order_p = np.concatenate([order, np.full(pad, -1, np.int32)])
    page_vids = order_p.reshape(num_pages, n_p)

    vid2page = np.empty(n, np.int32)
    vid2slot = np.empty(n, np.int32)
    pg = np.repeat(np.arange(num_pages, dtype=np.int32), n_p)
    sl = np.tile(np.arange(n_p, dtype=np.int32), num_pages)
    valid = order_p >= 0
    vid2page[order_p[valid]] = pg[valid]
    vid2slot[order_p[valid]] = sl[valid]

    safe = np.where(page_vids >= 0, page_vids, 0)
    page_vecs = vectors[safe].astype(np.float32)
    page_nbrs = graph[safe].astype(np.int32)
    page_vecs[~valid.reshape(num_pages, n_p)] = 0.0
    page_nbrs[~valid.reshape(num_pages, n_p)] = -1

    mapping = 8 * n if perm is not None else 0  # vid->(page,slot) table
    return PageLayout(page_bytes=page_bytes, n_p=n_p, num_pages=num_pages,
                      vid2page=vid2page, vid2slot=vid2slot,
                      page_vids=page_vids, page_vecs=page_vecs,
                      page_nbrs=page_nbrs, record_bytes=rec,
                      mapping_bytes=mapping)


def overlap_ratio(layout: PageLayout, graph: np.ndarray,
                  alive: Optional[np.ndarray] = None) -> float:
    """OR(G) (§3.1): average over u of |B(u) ∩ N(u)| / (n_p - 1).

    `alive` (optional (n,) bool) restricts the average to live vertices —
    the form the streaming-mutation subsystem needs, where the vid space
    carries capacity padding and tombstoned entries that must not dilute
    the locality signal."""
    if layout.n_p <= 1:
        return 0.0
    n = graph.shape[0]
    pages_of_nbrs = np.where(graph >= 0, layout.vid2page[np.maximum(graph, 0)], -2)
    own = layout.vid2page[np.arange(n)][:, None]
    co = (pages_of_nbrs == own).sum(1)
    frac = co / (layout.n_p - 1)
    if alive is not None:
        alive = np.asarray(alive, bool)
        if not alive.any():
            return 0.0
        frac = frac[alive]
    return float(frac.mean())
