"""Vamana graph construction (DiskANN's logical graph), batched in JAX.

Algorithm (Subramanya et al. 2019), batch-parallel variant (parlayANN-style):
start from a random R-regular graph, then two refinement passes (alpha=1.0,
then alpha) — for each batch of nodes: greedy-search the current graph to
collect the visited set V, RobustPrune(V ∪ N(x)) into new out-edges, then add
reverse edges and re-prune overfull nodes. Deterministic given the seed.

Also exports `beam_search_mem`, the in-memory best-first search used for
build, for the MemGraph navigation layer, and as the oracle the page engine
is validated against.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.searchutils import (INF, SENTINEL, dedup_merge_topL, sq_dists,
                                    top_w_unexpanded)


def medoid(x: np.ndarray) -> int:
    mean = x.mean(0)
    return int(np.argmin(((x - mean) ** 2).sum(1)))


# ---------------------------------------------------------------------------
# in-memory best-first / beam search


@functools.partial(jax.jit, static_argnames=("L", "width", "max_iters",
                                             "visited_cap"))
def _beam_search_mem_batch(X, G, entries, entry_valid, q, *, L, width,
                           max_iters, visited_cap):
    """Batched over queries. entries (B, E) int32 (SENTINEL padded).
    Returns dict(ids (B,L), dists (B,L), visited_ids (B,V), visited_dists,
    hops (B,))."""

    def one(qv, ent, ent_ok):
        d0 = jnp.where(ent_ok, sq_dists(qv, X[jnp.minimum(ent, X.shape[0] - 1)]),
                       INF)
        ids = jnp.where(ent_ok, ent, SENTINEL)
        pad = L + width - ids.shape[0]
        ids = jnp.concatenate([ids, jnp.full((pad,), SENTINEL, jnp.int32)])
        keys = jnp.concatenate([d0, jnp.full((pad,), INF)])[:, None]
        flags = jnp.zeros((ids.shape[0], 1), bool)
        ids, keys, flags = dedup_merge_topL(ids, keys, flags, L)

        vis_ids = jnp.full((visited_cap,), SENTINEL, jnp.int32)
        vis_d = jnp.full((visited_cap,), INF)

        def cond(st):
            ids, keys, flags, vis_ids, vis_d, it, vn = st
            frontier_open = jnp.any((ids < SENTINEL) & ~flags[:, 0])
            return frontier_open & (it < max_iters)

        def body(st):
            ids, keys, flags, vis_ids, vis_d, it, vn = st
            fidx, active = top_w_unexpanded(keys[:, 0], flags[:, 0],
                                            ids < SENTINEL, width)
            fids = jnp.where(active, ids[fidx], SENTINEL)
            # record visited (expanded) nodes
            vis_ids = jax.lax.dynamic_update_slice(
                vis_ids, fids, (vn,))
            vis_d = jax.lax.dynamic_update_slice(
                vis_d, jnp.where(active, keys[fidx, 0], INF), (vn,))
            vn = vn + width
            flags = flags.at[fidx, 0].set(flags[fidx, 0] | active)
            # expand neighbors
            nbrs = G[jnp.minimum(fids, X.shape[0] - 1)]          # (w, R)
            nbrs = jnp.where((active[:, None]) & (nbrs >= 0), nbrs, SENTINEL)
            nflat = nbrs.reshape(-1)
            nd = jnp.where(nflat < SENTINEL,
                           sq_dists(qv, X[jnp.minimum(nflat, X.shape[0] - 1)]),
                           INF)
            all_ids = jnp.concatenate([ids, nflat])
            all_keys = jnp.concatenate([keys[:, 0], nd])[:, None]
            all_flags = jnp.concatenate(
                [flags, jnp.zeros((nflat.shape[0], 1), bool)])
            ids, keys, flags = dedup_merge_topL(all_ids, all_keys, all_flags, L)
            return ids, keys, flags, vis_ids, vis_d, it + 1, vn

        st = (ids, keys, flags, vis_ids, vis_d, jnp.int32(0), jnp.int32(0))
        ids, keys, flags, vis_ids, vis_d, it, vn = jax.lax.while_loop(
            cond, body, st)
        return {"ids": ids, "dists": keys[:, 0], "visited_ids": vis_ids,
                "visited_dists": vis_d, "hops": it}

    return jax.vmap(one)(q, entries, entry_valid)


def beam_search_mem(X, G, entry: int, q, L=64, width=1, max_iters=None,
                    visited_cap=None):
    """q: (B, d). Single fixed entry point (the medoid)."""
    B = q.shape[0]
    max_iters = max_iters or (4 * L)
    visited_cap = visited_cap or (width * max_iters)
    entries = jnp.full((B, 1), entry, jnp.int32)
    valid = jnp.ones((B, 1), bool)
    return _beam_search_mem_batch(
        jnp.asarray(X), jnp.asarray(G), entries, valid, jnp.asarray(q),
        L=L, width=width, max_iters=max_iters, visited_cap=visited_cap)


# ---------------------------------------------------------------------------
# RobustPrune


@functools.partial(jax.jit, static_argnames=("R", "alpha"))
def _robust_prune_batch(X, xs_ids, cand_ids, cand_dists, *, R, alpha):
    """Batched RobustPrune. xs_ids (B,), cand_ids (B, C) (SENTINEL pad,
    deduped, may include x itself — removed here), cand_dists (B, C) dist to x.
    Returns (B, R) int32 new out-neighbors (-1 padded)."""

    def one(xid, cids, cd):
        cids = jnp.where(cids == xid, SENTINEL, cids)
        cd = jnp.where(cids == SENTINEL, INF, cd)
        cvecs = X[jnp.minimum(cids, X.shape[0] - 1)]             # (C, d)
        alive = cids < SENTINEL

        def step(i, st):
            alive, out, order_d = st
            key = jnp.where(alive, order_d, INF)
            j = jnp.argmin(key)
            ok = key[j] < INF
            out = out.at[i].set(jnp.where(ok, cids[j], -1))
            # kill candidates dominated by the pick: alpha*d(p,c) <= d(x,c)
            dpc = sq_dists(cvecs[j], cvecs)
            kill = (alpha * alpha) * dpc <= order_d
            alive = alive & ~kill & ok
            alive = alive.at[j].set(False)
            return alive, out, order_d

        out0 = jnp.full((R,), -1, jnp.int32)
        _, out, _ = jax.lax.fori_loop(0, R, step, (alive, out0, cd))
        return out

    return jax.vmap(one)(xs_ids, cand_ids, cand_dists)


# ---------------------------------------------------------------------------
# build


def build_vamana(x: np.ndarray, R=64, L=125, alpha=1.2, seed=0,
                 batch=1024, passes=(1.0, None), log=lambda *a: None):
    """Returns (G (n, R) int32 with -1 padding, medoid id, build stats)."""
    t0 = time.time()
    n, d = x.shape
    rng = np.random.default_rng(seed)
    X = jnp.asarray(x, jnp.float32)
    med = medoid(x)

    # random initial R-regular graph
    G = rng.integers(0, n, (n, R), dtype=np.int64).astype(np.int32)
    G[G == np.arange(n)[:, None]] = (G[G == np.arange(n)[:, None]] + 1) % n
    G = jnp.asarray(G)

    max_iters = max(2 * L // 1, 48)
    vcap = max_iters
    peak_candidates = 0

    for p_i, a in enumerate(passes):
        a = float(a or alpha)
        order = rng.permutation(n)
        for s in range(0, n, batch):
            ids = order[s:s + batch]
            qb = X[ids]
            res = _beam_search_mem_batch(
                X, G, jnp.full((len(ids), 1), med, jnp.int32),
                jnp.ones((len(ids), 1), bool), qb,
                L=L, width=1, max_iters=max_iters, visited_cap=vcap)
            # candidate pool = visited ∪ current out-neighbors
            cur = G[jnp.asarray(ids)]
            cur = jnp.where(cur >= 0, cur, SENTINEL)
            cand = jnp.concatenate([res["visited_ids"], res["ids"], cur], axis=1)
            cd = jnp.concatenate(
                [res["visited_dists"], res["dists"],
                 jax.vmap(lambda q_, c_: sq_dists(
                     q_, X[jnp.minimum(c_, n - 1)]))(qb, cur)], axis=1)
            cd = jnp.where(cand < SENTINEL, cd, INF)
            # dedup candidates per row
            def dd(c_, d_):
                i_, k_, _ = dedup_merge_topL(
                    c_, d_[:, None], jnp.zeros((c_.shape[0], 1), bool),
                    c_.shape[0])
                return i_, k_[:, 0]
            cand, cd = jax.vmap(dd)(cand, cd)
            peak_candidates = max(peak_candidates, int(cand.shape[1]))
            newn = _robust_prune_batch(X, jnp.asarray(ids), cand, cd,
                                       R=R, alpha=a)
            G = G.at[jnp.asarray(ids)].set(newn)
            # reverse edges: u in newn[x] -> try add x to N(u)
            G = _add_reverse_edges(X, G, jnp.asarray(ids), newn, R, a)
        log(f"pass {p_i} (alpha={a}) done at {time.time()-t0:.1f}s")

    stats = {"build_s": time.time() - t0, "R": R, "L": L, "alpha": alpha,
             "n": n, "d": d}
    return np.asarray(G), med, stats


@functools.partial(jax.jit, static_argnames=("R",), donate_argnums=(1,))
def _add_reverse_edges(X, G, xs_ids, newn, R, alpha):
    """For each edge x->u, append x to N(u) if capacity remains; overfull
    nodes are handled by slot-replacement of the farthest neighbor."""
    n = X.shape[0]
    flat_u = newn.reshape(-1)
    flat_x = jnp.repeat(xs_ids, newn.shape[1])
    ok = flat_u >= 0
    # current degree of u
    deg = (G[jnp.maximum(flat_u, 0)] >= 0).sum(-1)
    # distance of the proposed reverse edge
    dxu = jnp.sum(jnp.square(X[jnp.maximum(flat_u, 0)]
                             - X[flat_x]), axis=-1)
    slot_free = jnp.minimum(deg, R - 1)
    # farthest current neighbor of u (replacement victim when full)
    nb = G[jnp.maximum(flat_u, 0)]
    nbd = jnp.where(nb >= 0,
                    jnp.sum(jnp.square(
                        X[jnp.maximum(nb, 0)] - X[jnp.maximum(flat_u, 0)][:, None, :]),
                        axis=-1), -INF)
    far_slot = jnp.argmax(nbd, axis=-1)
    far_d = jnp.max(nbd, axis=-1)
    full = deg >= R
    slot = jnp.where(full, far_slot, slot_free)
    accept = ok & (~full | (dxu < far_d))
    tgt_row = jnp.where(accept, flat_u, n)  # row n = scratch discard
    Gp = jnp.concatenate([G, jnp.zeros((1, R), jnp.int32)], 0)
    Gp = Gp.at[tgt_row, slot].set(jnp.where(accept, flat_x, 0))
    return Gp[:-1]
