"""Product Quantization (Jegou et al. 2011) — the paper's memory-layout
baseline technique (§4.1.1): compressed codes live in the fast tier and give
approximate distances without touching the capacity tier; full-precision
vectors on "disk" are used only for re-ranking.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PQ:
    centroids: np.ndarray  # (M, 256, dsub) float32
    codes: np.ndarray      # (n, M) uint8
    m: int
    dsub: int

    @property
    def memory_bytes(self) -> int:
        return self.codes.nbytes + self.centroids.nbytes

    def lut(self, q: np.ndarray) -> np.ndarray:
        """ADC lookup table for query q: (M, 256) float32 of squared dists."""
        qs = q.reshape(self.m, self.dsub)
        return np.asarray(_lut_jit(jnp.asarray(self.centroids), jnp.asarray(qs)))

    def adc(self, q: np.ndarray, ids: np.ndarray) -> np.ndarray:
        lut = self.lut(q)
        return lut[np.arange(self.m)[None, :], self.codes[ids]].sum(-1)


@functools.partial(jax.jit)
def _lut_jit(centroids, qs):
    # (M, 256, dsub) vs (M, dsub) -> (M, 256)
    return jnp.sum(jnp.square(centroids - qs[:, None, :]), axis=-1)


@functools.partial(jax.jit, static_argnames=("iters", "k"))
def _kmeans(x, key, iters=12, k=256):
    """x (ns, dsub) -> centroids (k, dsub). Lloyd with balanced re-seeding."""
    n = x.shape[0]
    idx = jax.random.choice(key, n, (k,), replace=n < k)
    c = x[idx]

    def step(c, _):
        d = (jnp.sum(jnp.square(x), 1)[:, None]
             - 2.0 * x @ c.T + jnp.sum(jnp.square(c), 1)[None, :])
        a = jnp.argmin(d, 1)
        onehot = jax.nn.one_hot(a, k, dtype=x.dtype)
        counts = onehot.sum(0)
        sums = onehot.T @ x
        c_new = sums / jnp.maximum(counts[:, None], 1.0)
        # dead centroids keep their previous position
        c_new = jnp.where(counts[:, None] > 0, c_new, c)
        return c_new, None

    c, _ = jax.lax.scan(step, c, None, length=iters)
    return c


def train_pq(x: np.ndarray, m: int = 16, sample: int = 16384,
             iters: int = 12, seed: int = 0) -> PQ:
    n, d = x.shape
    assert d % m == 0, (d, m)
    dsub = d // m
    rng = np.random.default_rng(seed)
    sub = x[rng.choice(n, min(sample, n), replace=False)]
    xs = sub.reshape(-1, m, dsub)
    keys = jax.random.split(jax.random.PRNGKey(seed), m)
    cents = np.stack([
        np.asarray(_kmeans(jnp.asarray(xs[:, j]), keys[j], iters=iters))
        for j in range(m)])
    codes = encode(x, cents)
    return PQ(centroids=cents, codes=codes, m=m, dsub=dsub)


def encode(x: np.ndarray, centroids: np.ndarray, block: int = 8192) -> np.ndarray:
    n, d = x.shape
    m, k, dsub = centroids.shape
    out = np.empty((n, m), np.uint8)
    cj = jnp.asarray(centroids)

    @jax.jit
    def enc(xb):
        xs = xb.reshape(-1, m, dsub)
        d_ = (jnp.sum(jnp.square(xs), -1)[..., None]
              - 2.0 * jnp.einsum("nmd,mkd->nmk", xs, cj)
              + jnp.sum(jnp.square(cj), -1)[None])
        return jnp.argmin(d_, -1).astype(jnp.uint8)

    for i in range(0, n, block):
        out[i:i + block] = np.asarray(enc(jnp.asarray(x[i:i + block])))
    return out
