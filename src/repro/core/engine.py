"""The disk-resident beam-search engine — the paper's eight techniques as one
composable configuration (§4), with exact page-level I/O accounting (§3.1).

Execution is REAL (every page read, hop, distance evaluation and recall value
is measured from the actual search); only wall-clock latency/QPS come from the
paper's measured device model (core/device_model.py) applied to these counts.

Technique mapping (SearchConfig):
  PQ            — always on (the paper's §6 baseline): neighbors ranked by
                  memory-resident ADC distances; exact distances only for
                  records whose page was fetched.
  Cache         — `cached` vertex mask: frontier reads of cached vertices are
                  free (served from memory).
  MemGraph      — entry points supplied by the navigation layer instead of
                  the medoid.
  PageShuffle   — a different PageLayout (perm); engine unchanged.
  AiS           — smaller n_p / bigger records (layout), memory freed.
  DynamicWidth  — beam width schedule: w starts at w_min, doubles each
                  iteration the best candidate set stops improving (approach
                  -> converge phase detection, PipeANN-style).
  Pipeline      — speculative frontier: issues reads for `spec` extra
                  candidates per step (extra I/O, overlapped latency —
                  reproduces Finding 5); on TPU this is the double-buffered
                  DMA in kernels/page_scan.py.
  PageSearch    — every record of a fetched page is scored exactly and
                  inserted into the pool (raises per-page utility).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.searchutils import (INF, SENTINEL, dedup_merge_topL, sq_dists,
                                    top_w_unexpanded)


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    name: str = "baseline"
    k: int = 10
    L: int = 64                  # candidate pool
    beam_width: int = 8
    max_iters: int = 96
    # --- memory layout ---
    pq_m: int = 16
    cache_frac: float = 0.0
    cache_policy: str = "sssp"   # "sssp" (paper) | "freq" (beyond-paper)
    memgraph_frac: float = 0.0
    memgraph_entries: int = 4
    memgraph_L: int = 32
    # --- disk layout ---
    page_shuffle: bool = False
    all_in_storage: bool = False
    page_bytes: int = 4096
    # --- search algorithm ---
    page_search: bool = False
    dynamic_width: bool = False
    dw_min: int = 2
    dw_max: int = 32
    pipeline: bool = False
    pipeline_spec: int = 2       # speculative reads per step

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray            # (B, k)
    dists: np.ndarray          # (B, k)
    hops: np.ndarray           # (B,)
    page_reads: np.ndarray     # (B,) unique page fetches charged to SSD
    cache_hits: np.ndarray     # (B,)
    n_read_records: np.ndarray  # (B,) records fetched (N_read, Eq. 3)
    n_eff: np.ndarray          # (B,) records actually expanded (N_eff)
    full_evals: np.ndarray     # (B,) full-precision distance computations
    pq_evals: np.ndarray       # (B,) ADC distance computations
    mem_hops: np.ndarray       # (B,) MemGraph in-memory hops
    mem_evals: np.ndarray      # (B,) MemGraph distance evals

    def io_utilization(self):
        return self.n_eff.sum() / max(self.n_read_records.sum(), 1)


# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("k", "L", "width", "max_iters", "n_p", "page_search",
                     "dynamic_width", "dw_min", "dw_max", "pipeline", "spec"))
def _search_batch(page_vids, page_vecs, page_nbrs, vid2page, vid2slot,
                  pq_centroids, pq_codes, cached, q, entries, entry_valid, *,
                  k, L, width, max_iters, n_p, page_search, dynamic_width,
                  dw_min, dw_max, pipeline, spec):
    n = vid2page.shape[0]
    m, ksub, dsub = pq_centroids.shape
    width = max(width, dw_max) if dynamic_width else width
    width = min(width, L)   # frontier can never exceed the candidate pool
    w_cap = min(width + (spec if pipeline else 0), L)

    def one(qv, ent, ent_ok):
        lut = jnp.sum(jnp.square(pq_centroids
                                 - qv.reshape(m, 1, dsub)), axis=-1)  # (M,256)

        def pq_dist(ids):
            safe = jnp.minimum(jnp.maximum(ids, 0), n - 1)
            codes = pq_codes[safe]                      # (.., M)
            d = jnp.take_along_axis(
                lut.T, codes.astype(jnp.int32), axis=0)  # broadcast gather
            # lut.T is (256, M); gather rows by code per column
            return jnp.sum(d, axis=-1)

        # candidate list: keys = [rank_key, exact_dist]; flags = [expanded,
        # exact_known]
        cap = L + w_cap * (n_p if page_search else 0) + w_cap * page_nbrs.shape[2]
        e_pq = pq_dist(ent)
        ids0 = jnp.where(ent_ok, ent, SENTINEL)
        pad = cap - ids0.shape[0]
        ids = jnp.concatenate([ids0, jnp.full((pad,), SENTINEL, jnp.int32)])
        keys = jnp.stack([jnp.where(ent_ok, e_pq, INF),
                          jnp.full(ids0.shape, INF)], 1)
        keys = jnp.concatenate([keys, jnp.full((pad, 2), INF)], 0)
        flags = jnp.zeros((cap, 2), bool)
        ids, keys, flags = dedup_merge_topL(ids, keys, flags, L)

        zero = jnp.zeros((), jnp.float32)
        # metrics: pages, cache_hits, nread, neff, fulle, pqe, hops
        met0 = (zero,) * 6
        st0 = (ids, keys, flags, jnp.int32(0), jnp.float32(dw_min), zero) + met0

        def cond(st):
            ids, keys, flags, it = st[0], st[1], st[2], st[3]
            open_ = jnp.any((ids < SENTINEL) & ~flags[:, 0]
                            & (keys[:, 0] < INF))
            return open_ & (it < max_iters)

        def body(st):
            (ids, keys, flags, it, w_dyn, stall,
             pages_m, cache_m, nread_m, neff_m, full_m, pq_m_) = st
            best_before = keys[0, 0]

            w_now = (jnp.minimum(jnp.float32(dw_max), w_dyn)
                     if dynamic_width else jnp.float32(width))
            w_sel = jnp.minimum(w_now, jnp.float32(width)).astype(jnp.int32)
            fidx, active = top_w_unexpanded(
                keys[:, 0], flags[:, 0], ids < SENTINEL, w_cap,
                w_dynamic=(w_sel + (spec if pipeline else 0)))
            # pipeline: the first w_sel are confirmed, the rest speculative
            fids = jnp.where(active, ids[fidx], SENTINEL)
            neff_m = neff_m + jnp.sum(
                active & (jnp.arange(w_cap) < w_sel))

            # --- page fetch accounting --------------------------------------
            safe_f = jnp.minimum(jnp.maximum(fids, 0), n - 1)
            fpages = jnp.where(fids < SENTINEL, vid2page[safe_f], -1)
            is_cached = (fids < SENTINEL) & cached[safe_f]
            # unique non-cached pages this step
            chargeable = jnp.where(is_cached, -1, fpages)
            srt = jnp.sort(chargeable)
            uniq = (srt >= 0) & jnp.concatenate(
                [jnp.ones((1,), bool), srt[1:] != srt[:-1]])
            pages_step = jnp.sum(uniq).astype(jnp.float32)
            pages_m = pages_m + pages_step
            cache_m = cache_m + jnp.sum(is_cached).astype(jnp.float32)
            nread_m = nread_m + pages_step * n_p

            # --- fetch records ----------------------------------------------
            pg = jnp.maximum(fpages, 0)
            rec_vids = page_vids[pg]                    # (w_cap, n_p)
            rec_vecs = page_vecs[pg]                    # (w_cap, n_p, d)
            rec_nbrs = page_nbrs[pg, vid2slot[safe_f]]  # (w_cap, R)
            page_ok = (fids < SENTINEL)

            # exact distance for every record on fetched pages
            rd = jax.vmap(lambda vs: sq_dists(qv, vs))(rec_vecs)  # (w_cap,n_p)
            rec_valid = (rec_vids >= 0) & page_ok[:, None]
            full_m = full_m + jnp.sum(rec_valid).astype(jnp.float32)

            # frontier's own exact distances (re-rank info, always used)
            own = rec_vids == jnp.where(fids < SENTINEL, fids, -2)[:, None]
            own_ids = jnp.where(page_ok, fids, SENTINEL)
            own_d = jnp.where(page_ok,
                              jnp.sum(jnp.where(own, rd, 0.0), 1), INF)

            # --- assemble merge inputs --------------------------------------
            parts_ids = [ids, own_ids]
            parts_rank = [keys[:, 0], own_d]
            parts_exact = [keys[:, 1], own_d]
            parts_exp = [flags[:, 0], page_ok]
            parts_exk = [flags[:, 1], page_ok]

            if page_search:
                pr_ids = jnp.where(rec_valid, rec_vids, SENTINEL).reshape(-1)
                pr_d = jnp.where(rec_valid, rd, INF).reshape(-1)
                parts_ids.append(pr_ids)
                parts_rank.append(pr_d)
                parts_exact.append(pr_d)
                parts_exp.append(jnp.zeros_like(pr_ids, bool))
                parts_exk.append(pr_ids < SENTINEL)

            nb = jnp.where(page_ok[:, None] & (rec_nbrs >= 0),
                           rec_nbrs, SENTINEL).reshape(-1)
            nb_pq = jnp.where(nb < SENTINEL, pq_dist(nb), INF)
            pq_m_ = pq_m_ + jnp.sum(nb < SENTINEL).astype(jnp.float32)
            parts_ids.append(nb)
            parts_rank.append(nb_pq)
            parts_exact.append(jnp.full_like(nb_pq, INF))
            parts_exp.append(jnp.zeros_like(nb, bool))
            parts_exk.append(jnp.zeros_like(nb, bool))

            all_ids = jnp.concatenate(parts_ids)
            all_keys = jnp.stack([jnp.concatenate(parts_rank),
                                  jnp.concatenate(parts_exact)], 1)
            all_flags = jnp.stack([jnp.concatenate(parts_exp),
                                   jnp.concatenate(parts_exk)], 1)
            ids, keys, flags = dedup_merge_topL(all_ids, all_keys, all_flags, L)
            # expanded entries keep exact distance as ranking key
            keys = keys.at[:, 0].set(
                jnp.where(flags[:, 1], keys[:, 1], keys[:, 0]))

            # dynamic width phase detection: no improvement => converge phase
            improved = keys[0, 0] < best_before
            stall = jnp.where(improved, 0.0, stall + 1.0)
            w_dyn = jnp.where(dynamic_width & (stall > 0),
                              jnp.minimum(w_dyn * 2.0, jnp.float32(dw_max)),
                              w_dyn)
            return (ids, keys, flags, it + 1, w_dyn, stall,
                    pages_m, cache_m, nread_m, neff_m, full_m, pq_m_)

        out = jax.lax.while_loop(cond, body, st0)
        ids, keys, flags, it = out[0], out[1], out[2], out[3]
        pages_m, cache_m, nread_m, neff_m, full_m, pq_m_ = out[6:12]

        # final top-k by exact distance (re-rank among exact-known)
        final_key = jnp.where(flags[:, 1], keys[:, 1], INF)
        order = jnp.argsort(final_key)[:k]
        topk = jnp.where(final_key[order] < INF, ids[order], -1)
        topd = final_key[order]
        return {"ids": topk, "dists": topd, "hops": it,
                "page_reads": pages_m, "cache_hits": cache_m,
                "n_read": nread_m, "n_eff": neff_m,
                "full_evals": full_m, "pq_evals": pq_m_}

    return jax.vmap(one)(q, entries, entry_valid)


# ---------------------------------------------------------------------------


class DiskIndex:
    """Bundles layout + PQ + optional cache/memgraph; see core/presets.py
    and core/builder.py for construction."""

    def __init__(self, layout, pq, graph, medoid, cfg: SearchConfig,
                 memgraph=None, cached: Optional[np.ndarray] = None,
                 build_stats: Optional[dict] = None):
        self.layout = layout
        self.pq = pq
        self.graph = graph
        self.medoid = medoid
        self.cfg = cfg
        self.memgraph = memgraph
        n = graph.shape[0]
        self.cached = (cached if cached is not None else np.zeros(n, bool))
        self.build_stats = build_stats or {}

    def memory_bytes(self) -> int:
        b = self.pq.memory_bytes if not self.cfg.all_in_storage else 0
        if self.memgraph is not None:
            b += self.memgraph.memory_bytes
        b += int(self.cached.sum()) * self.layout.record_bytes
        b += self.layout.mapping_bytes
        return b

    def search(self, queries: np.ndarray, cfg: Optional[SearchConfig] = None,
               batch: int = 256) -> SearchResult:
        cfg = cfg or self.cfg
        # the cache only serves reads when the search config enables it
        cached = (self.cached if cfg.cache_frac > 0
                  else np.zeros_like(self.cached))
        outs = []
        for s in range(0, len(queries), batch):
            qb = np.asarray(queries[s:s + batch], np.float32)
            if self.memgraph is not None and cfg.memgraph_frac > 0:
                mg = self.memgraph.entry_points(
                    qb, n_entries=cfg.memgraph_entries, L=cfg.memgraph_L)
                entries = mg["entries"]
                mem_hops, mem_evals = mg["hops"], mg["dist_evals"]
            else:
                entries = np.full((len(qb), 1), self.medoid, np.int32)
                mem_hops = np.zeros(len(qb), np.int32)
                mem_evals = np.zeros(len(qb), np.int32)
            valid = entries >= 0
            res = _search_batch(
                jnp.asarray(self.layout.page_vids),
                jnp.asarray(self.layout.page_vecs),
                jnp.asarray(self.layout.page_nbrs),
                jnp.asarray(self.layout.vid2page),
                jnp.asarray(self.layout.vid2slot),
                jnp.asarray(self.pq.centroids), jnp.asarray(self.pq.codes),
                jnp.asarray(cached),
                jnp.asarray(qb), jnp.asarray(entries), jnp.asarray(valid),
                k=cfg.k, L=cfg.L, width=cfg.beam_width,
                max_iters=cfg.max_iters, n_p=self.layout.n_p,
                page_search=cfg.page_search,
                dynamic_width=cfg.dynamic_width, dw_min=cfg.dw_min,
                dw_max=cfg.dw_max, pipeline=cfg.pipeline,
                spec=cfg.pipeline_spec)
            res = {k_: np.asarray(v) for k_, v in res.items()}
            res["mem_hops"] = mem_hops
            res["mem_evals"] = mem_evals
            outs.append(res)

        cat = {k_: np.concatenate([o[k_] for o in outs]) for k_ in outs[0]}
        return SearchResult(
            ids=cat["ids"], dists=cat["dists"], hops=cat["hops"],
            page_reads=cat["page_reads"], cache_hits=cat["cache_hits"],
            n_read_records=cat["n_read"], n_eff=cat["n_eff"],
            full_evals=cat["full_evals"], pq_evals=cat["pq_evals"],
            mem_hops=cat["mem_hops"], mem_evals=cat["mem_evals"])
