"""Engine facade over the layered search stack.

The former 326-line monolith is now three layers:

  I/O layer      repro/io/page_store.py   — PageStore protocol: array-backed
                                            "SSD", vertex-cache decorator,
                                            cross-query batch coalescing.
  Kernel layer   core/search_kernel.py    — the pure jitted beam search over
                                            store-provided arrays; emits
                                            QueryStats (core/stats.py).
  Serving layer  repro/serving/ann_server.py — closed-loop concurrent query
                                            server (queue + dynamic batcher +
                                            per-worker SSD queueing).

This module keeps the public surface the rest of the repo was built on:
`SearchConfig` (the paper's eight techniques as one composable
configuration, §4) and `DiskIndex` with a `search()` facade that is
bit-identical to the pre-refactor engine (see tests/test_golden_facade.py).
Execution is REAL (every page read, hop, distance evaluation and recall
value is measured from the actual search); only wall-clock latency/QPS come
from the measured device model (core/device_model.py) applied to the counts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats, SearchResult  # noqa: F401 (re-export)
from repro.io import build_store


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    name: str = "baseline"
    k: int = 10
    L: int = 64                  # candidate pool
    beam_width: int = 8
    max_iters: int = 96
    # --- memory layout ---
    pq_m: int = 16
    cache_frac: float = 0.0
    cache_policy: str = "sssp"   # "sssp" (paper) | "freq" (beyond-paper)
    memgraph_frac: float = 0.0
    memgraph_entries: int = 4
    memgraph_L: int = 32
    # --- disk layout ---
    page_shuffle: bool = False
    all_in_storage: bool = False
    page_bytes: int = 4096
    # --- search algorithm ---
    page_search: bool = False
    dynamic_width: bool = False
    dw_min: int = 2
    dw_max: int = 32
    # Pipeline execution mode: False = sequential; True = speculative
    # overlap priced by the device model's analytic rebate; "fused" = the
    # same search (bit-identical results) but the hot path additionally
    # re-executes the traced page schedule through the fused pipelined
    # Pallas kernel (kernels/fused_search.py) and carries MEASURED kernel
    # step time on QueryStats.measured_step_us next to the modeled time.
    pipeline: bool = False       # False | True | "fused"
    pipeline_spec: int = 2       # speculative reads per step

    def __post_init__(self):
        if self.k > self.L:
            raise ValueError(
                f"k={self.k} must be <= L={self.L}: the candidate pool "
                f"must hold at least the k results it returns")
        if self.dw_min > self.dw_max:
            raise ValueError(
                f"dw_min={self.dw_min} must be <= dw_max={self.dw_max} "
                f"(DynamicWidth doubles the beam from dw_min up to dw_max)")
        if not 0.0 <= self.cache_frac <= 1.0:
            raise ValueError(
                f"cache_frac={self.cache_frac} must be in [0, 1] "
                f"(fraction of vertices pinned in memory)")
        if self.pipeline_spec < 0:
            raise ValueError(
                f"pipeline_spec={self.pipeline_spec} must be >= 0 "
                f"(speculative reads per step)")
        if self.pipeline not in (False, True, "fused"):
            raise ValueError(
                f"pipeline={self.pipeline!r} must be False, True, or "
                f"'fused' (the measured double-buffered kernel path)")

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------


class DiskIndex:
    """Bundles layout + PQ + optional cache/memgraph; see core/presets.py
    and core/builder.py for construction. `search` is a thin compatibility
    facade over the io/kernel layers; the serving layer drives the same
    kernel through `page_store()` + search_kernel.search_batched."""

    def __init__(self, layout, pq, graph, medoid, cfg: SearchConfig,
                 memgraph=None, cached: Optional[np.ndarray] = None,
                 build_stats: Optional[dict] = None):
        self.layout = layout
        self.pq = pq
        self.graph = graph
        self.medoid = medoid
        self.cfg = cfg
        self.memgraph = memgraph
        n = graph.shape[0]
        self.cached = (cached if cached is not None else np.zeros(n, bool))
        self.build_stats = build_stats or {}
        self._stores = {}

    def memory_bytes(self) -> int:
        b = self.pq.memory_bytes if not self.cfg.all_in_storage else 0
        if self.memgraph is not None:
            b += self.memgraph.memory_bytes
        b += int(self.cached.sum()) * self.layout.record_bytes
        b += self.layout.mapping_bytes
        return b

    def page_store(self, use_cache: bool = True, batched: bool = False):
        """The index's I/O-layer view: array store + cache decorator (when
        the index holds a cache and the caller wants it) + optional batch
        coalescer. Memoized per (use_cache, batched) so repeated searches
        share counters and the kernel's device-array cache."""
        key = (bool(use_cache and self.cached.any()), batched)
        if key not in self._stores:
            self._stores[key] = build_store(
                self.layout,
                cached_vertices=self.cached if key[0] else None,
                batched=batched)
        return self._stores[key]

    def search(self, queries: np.ndarray, cfg: Optional[SearchConfig] = None,
               batch: int = 256) -> QueryStats:
        cfg = cfg or self.cfg
        # the cache only serves reads when the search config enables it
        store = self.page_store(use_cache=cfg.cache_frac > 0)
        # facade callers never batch across queries — skip the per-query
        # visited-page bitmaps (serving goes through search_batched itself)
        return search_batched(store, self.pq, cfg, queries,
                              medoid=self.medoid, memgraph=self.memgraph,
                              batch=batch, collect_visited=False)
