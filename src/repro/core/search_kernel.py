"""Kernel layer: the pure jitted beam-search over store-provided page arrays.

This is the compute core of the engine split (I/O layer: repro/io/,
serving layer: repro/serving/ann_server.py). `_search_batch` is a pure
function of the page arrays a `PageStore` exposes — it never touches the
store object itself, so the same kernel serves the in-memory facade, the
cached store and the batch-coalescing server path.

Besides the per-query scalar counters, the kernel emits `visited_pages`, a
(B, num_pages) bitmap of the pages each query charged to the device. The
scalar `page_reads` counter dedups pages only *within* a step (exactly the
pre-refactor accounting, kept bit-identical for the golden facade test);
the bitmap is what lets `BatchedPageStore` dedup across queries and steps.

When `track_trace` is set it additionally emits `page_trace`, a
(B, max_iters, w_cap) int32 array: row (b, h) holds the distinct pages
query b charged at hop h, -1 padded — the same pages as the bitmap but in
TEMPORAL order, which is what the stateful cache subsystem
(repro/io/page_cache.py: LRU/FIFO/2Q replay, look-ahead prefetch) consumes.
Both trackers are static flags, so untracked carries compile out entirely.

Technique mapping (SearchConfig):
  PQ            — always on (the paper's §6 baseline): neighbors ranked by
                  memory-resident ADC distances; exact distances only for
                  records whose page was fetched.
  Cache         — `cached` vertex mask: frontier reads of cached vertices are
                  free (served from memory).
  MemGraph      — entry points supplied by the navigation layer instead of
                  the medoid.
  PageShuffle   — a different PageLayout (perm); kernel unchanged.
  AiS           — smaller n_p / bigger records (layout), memory freed.
  DynamicWidth  — beam width schedule: w starts at w_min, doubles each
                  iteration the best candidate set stops improving (approach
                  -> converge phase detection, PipeANN-style).
  Pipeline      — speculative frontier: issues reads for `spec` extra
                  candidates per step (extra I/O, overlapped latency —
                  reproduces Finding 5); on TPU this is the double-buffered
                  DMA in kernels/page_scan.py.
  PageSearch    — every record of a fetched page is scored exactly and
                  inserted into the pool (raises per-page utility).
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.searchutils import (INF, SENTINEL, dedup_merge_topL, sq_dists,
                                    top_w_unexpanded)
from repro.core.stats import QueryStats


@functools.partial(
    jax.jit,
    static_argnames=("k", "L", "width", "max_iters", "n_p", "page_search",
                     "dynamic_width", "dw_min", "dw_max", "pipeline", "spec",
                     "track_visited", "track_trace"))
def _search_batch(page_vids, page_vecs, page_nbrs, vid2page, vid2slot,
                  pq_centroids, pq_codes, cached, q, entries, entry_valid, *,
                  k, L, width, max_iters, n_p, page_search, dynamic_width,
                  dw_min, dw_max, pipeline, spec, track_visited=True,
                  track_trace=False):
    n = vid2page.shape[0]
    num_pages = page_vids.shape[0]
    m, ksub, dsub = pq_centroids.shape
    width = max(width, dw_max) if dynamic_width else width
    width = min(width, L)   # frontier can never exceed the candidate pool
    w_cap = min(width + (spec if pipeline else 0), L)

    def one(qv, ent, ent_ok):
        lut = jnp.sum(jnp.square(pq_centroids
                                 - qv.reshape(m, 1, dsub)), axis=-1)  # (M,256)

        def pq_dist(ids):
            safe = jnp.minimum(jnp.maximum(ids, 0), n - 1)
            codes = pq_codes[safe]                      # (.., M)
            d = jnp.take_along_axis(
                lut.T, codes.astype(jnp.int32), axis=0)  # broadcast gather
            # lut.T is (256, M); gather rows by code per column
            return jnp.sum(d, axis=-1)

        # candidate list: keys = [rank_key, exact_dist]; flags = [expanded,
        # exact_known]
        cap = L + w_cap * (n_p if page_search else 0) + w_cap * page_nbrs.shape[2]
        e_pq = pq_dist(ent)
        ids0 = jnp.where(ent_ok, ent, SENTINEL)
        pad = cap - ids0.shape[0]
        ids = jnp.concatenate([ids0, jnp.full((pad,), SENTINEL, jnp.int32)])
        keys = jnp.stack([jnp.where(ent_ok, e_pq, INF),
                          jnp.full(ids0.shape, INF)], 1)
        keys = jnp.concatenate([keys, jnp.full((pad, 2), INF)], 0)
        flags = jnp.zeros((cap, 2), bool)
        ids, keys, flags = dedup_merge_topL(ids, keys, flags, L)

        zero = jnp.zeros((), jnp.float32)
        # visited[p] = page p was charged to the device at least once; slot
        # num_pages is the trash slot for "-1 / cached" entries. When the
        # caller doesn't track bitmaps the carry shrinks to one element and
        # the per-step scatter compiles out entirely (track_visited is
        # static).
        visited0 = jnp.zeros(((num_pages + 1) if track_visited else 1,), bool)
        # trace[h] = the distinct pages charged at hop h (-1 padded); shrinks
        # to (1, 1) and the row write compiles out when untracked
        trace0 = jnp.full((max_iters, w_cap) if track_trace else (1, 1),
                          -1, jnp.int32)
        # metrics: pages, cache_hits, nread, neff, fulle, pqe, hops
        met0 = (zero,) * 6
        st0 = (ids, keys, flags, jnp.int32(0), jnp.float32(dw_min),
               zero, visited0, trace0) + met0

        def cond(st):
            ids, keys, flags, it = st[0], st[1], st[2], st[3]
            open_ = jnp.any((ids < SENTINEL) & ~flags[:, 0]
                            & (keys[:, 0] < INF))
            return open_ & (it < max_iters)

        def body(st):
            (ids, keys, flags, it, w_dyn, stall, visited, trace,
             pages_m, cache_m, nread_m, neff_m, full_m, pq_m_) = st
            best_before = keys[0, 0]

            w_now = (jnp.minimum(jnp.float32(dw_max), w_dyn)
                     if dynamic_width else jnp.float32(width))
            w_sel = jnp.minimum(w_now, jnp.float32(width)).astype(jnp.int32)
            fidx, active = top_w_unexpanded(
                keys[:, 0], flags[:, 0], ids < SENTINEL, w_cap,
                w_dynamic=(w_sel + (spec if pipeline else 0)))
            # pipeline: the first w_sel are confirmed, the rest speculative
            fids = jnp.where(active, ids[fidx], SENTINEL)
            neff_m = neff_m + jnp.sum(
                active & (jnp.arange(w_cap) < w_sel))

            # --- page fetch accounting --------------------------------------
            safe_f = jnp.minimum(jnp.maximum(fids, 0), n - 1)
            fpages = jnp.where(fids < SENTINEL, vid2page[safe_f], -1)
            is_cached = (fids < SENTINEL) & cached[safe_f]
            # unique non-cached pages this step
            chargeable = jnp.where(is_cached, -1, fpages)
            srt = jnp.sort(chargeable)
            uniq = (srt >= 0) & jnp.concatenate(
                [jnp.ones((1,), bool), srt[1:] != srt[:-1]])
            pages_step = jnp.sum(uniq).astype(jnp.float32)
            pages_m = pages_m + pages_step
            cache_m = cache_m + jnp.sum(is_cached).astype(jnp.float32)
            nread_m = nread_m + pages_step * n_p
            if track_visited:
                visited = visited.at[
                    jnp.where(chargeable >= 0, chargeable, num_pages)].set(True)
            if track_trace:
                # the step's distinct charged pages, in one row of the trace
                trace = trace.at[it].set(jnp.where(uniq, srt, -1))

            # --- fetch records ----------------------------------------------
            pg = jnp.maximum(fpages, 0)
            rec_vids = page_vids[pg]                    # (w_cap, n_p)
            rec_vecs = page_vecs[pg]                    # (w_cap, n_p, d)
            rec_nbrs = page_nbrs[pg, vid2slot[safe_f]]  # (w_cap, R)
            page_ok = (fids < SENTINEL)

            # exact distance for every record on fetched pages
            rd = jax.vmap(lambda vs: sq_dists(qv, vs))(rec_vecs)  # (w_cap,n_p)
            rec_valid = (rec_vids >= 0) & page_ok[:, None]
            full_m = full_m + jnp.sum(rec_valid).astype(jnp.float32)

            # frontier's own exact distances (re-rank info, always used)
            own = rec_vids == jnp.where(fids < SENTINEL, fids, -2)[:, None]
            own_ids = jnp.where(page_ok, fids, SENTINEL)
            own_d = jnp.where(page_ok,
                              jnp.sum(jnp.where(own, rd, 0.0), 1), INF)

            # --- assemble merge inputs --------------------------------------
            parts_ids = [ids, own_ids]
            parts_rank = [keys[:, 0], own_d]
            parts_exact = [keys[:, 1], own_d]
            parts_exp = [flags[:, 0], page_ok]
            parts_exk = [flags[:, 1], page_ok]

            if page_search:
                pr_ids = jnp.where(rec_valid, rec_vids, SENTINEL).reshape(-1)
                pr_d = jnp.where(rec_valid, rd, INF).reshape(-1)
                parts_ids.append(pr_ids)
                parts_rank.append(pr_d)
                parts_exact.append(pr_d)
                parts_exp.append(jnp.zeros_like(pr_ids, bool))
                parts_exk.append(pr_ids < SENTINEL)

            nb = jnp.where(page_ok[:, None] & (rec_nbrs >= 0),
                           rec_nbrs, SENTINEL).reshape(-1)
            nb_pq = jnp.where(nb < SENTINEL, pq_dist(nb), INF)
            pq_m_ = pq_m_ + jnp.sum(nb < SENTINEL).astype(jnp.float32)
            parts_ids.append(nb)
            parts_rank.append(nb_pq)
            parts_exact.append(jnp.full_like(nb_pq, INF))
            parts_exp.append(jnp.zeros_like(nb, bool))
            parts_exk.append(jnp.zeros_like(nb, bool))

            all_ids = jnp.concatenate(parts_ids)
            all_keys = jnp.stack([jnp.concatenate(parts_rank),
                                  jnp.concatenate(parts_exact)], 1)
            all_flags = jnp.stack([jnp.concatenate(parts_exp),
                                   jnp.concatenate(parts_exk)], 1)
            ids, keys, flags = dedup_merge_topL(all_ids, all_keys, all_flags, L)
            # expanded entries keep exact distance as ranking key
            keys = keys.at[:, 0].set(
                jnp.where(flags[:, 1], keys[:, 1], keys[:, 0]))

            # dynamic width phase detection: no improvement => converge phase
            improved = keys[0, 0] < best_before
            stall = jnp.where(improved, 0.0, stall + 1.0)
            w_dyn = jnp.where(dynamic_width & (stall > 0),
                              jnp.minimum(w_dyn * 2.0, jnp.float32(dw_max)),
                              w_dyn)
            return (ids, keys, flags, it + 1, w_dyn, stall, visited, trace,
                    pages_m, cache_m, nread_m, neff_m, full_m, pq_m_)

        out = jax.lax.while_loop(cond, body, st0)
        ids, keys, flags, it = out[0], out[1], out[2], out[3]
        visited, trace = out[6], out[7]
        pages_m, cache_m, nread_m, neff_m, full_m, pq_m_ = out[8:14]

        # final top-k by exact distance (re-rank among exact-known)
        final_key = jnp.where(flags[:, 1], keys[:, 1], INF)
        order = jnp.argsort(final_key)[:k]
        topk = jnp.where(final_key[order] < INF, ids[order], -1)
        topd = final_key[order]
        out = {"ids": topk, "dists": topd, "hops": it,
               "page_reads": pages_m, "cache_hits": cache_m,
               "n_read": nread_m, "n_eff": neff_m,
               "full_evals": full_m, "pq_evals": pq_m_}
        if track_visited:
            out["visited_pages"] = visited[:num_pages]
        if track_trace:
            out["page_trace"] = trace
        return out

    return jax.vmap(one)(q, entries, entry_valid)


# ---------------------------------------------------------------------------
# Fused-pipeline measurement surface (SearchConfig.pipeline == "fused"):
# results still come from _search_batch above (bit-identical to
# pipeline=True — the golden facade test pins it); the traced page schedule
# is then RE-EXECUTED through the fused double-buffered Pallas kernel
# (kernels/fused_search.py) to produce a measured wall-clock step time the
# analytic prefetch_overlap rebate can be compared against.

# interpret-mode grid steps are Python-priced, so cap the measured slice of
# the schedule and extrapolate by the per-page rate
MEASURE_PAGES_CAP = int(os.environ.get("REPRO_FUSED_MEASURE_PAGES", 256))


def hop_major_schedule(page_trace: np.ndarray) -> np.ndarray:
    """The batch's page stream in hop-major order: hop t's distinct pages
    (the batch union — what one pipelined grid would stage for the whole
    dispatch), then hop t+1's, exactly the order the LAANN-style look-ahead
    issues them. page_trace (B, max_iters, w), -1 padded."""
    trace = np.asarray(page_trace)
    out = []
    for h in range(trace.shape[1]):
        pages = np.unique(trace[:, h, :])
        out.append(pages[pages >= 0])
    return (np.concatenate(out) if out else np.zeros(0, np.int64))


def query_luts(pq_centroids, queries):
    """Per-query ADC LUTs (Q, M, 256): squared subspace distances from each
    query's subvectors to every centroid — the fused kernel's stacked-LUT
    operand (one MXU matmul per subspace covers the whole query block)."""
    cent = jnp.asarray(pq_centroids)
    m, ksub, dsub = cent.shape
    qs = jnp.asarray(queries, jnp.float32).reshape(-1, m, 1, dsub)
    return jnp.sum(jnp.square(cent[None] - qs), axis=-1)


def _page_codes(store, pq):
    """(P, n_p, M) uint8 page-aligned PQ codes (the residents' codes laid
    out like the vector tiles, so the fused kernel's code DMA mirrors the
    page DMA). Memoized on the store next to its kernel arrays."""
    cached = getattr(store, "_device_page_codes", None)
    if cached is None or cached.shape[0] != store.layout.num_pages:
        vids = store.layout.page_vids
        safe = np.clip(vids, 0, pq.codes.shape[0] - 1)
        codes = np.ascontiguousarray(pq.codes[safe])
        codes[vids < 0] = 0
        cached = jnp.asarray(codes)
        store._device_page_codes = cached
    return cached


def measure_step_us(store, pq, queries, page_trace, *,
                    mode: str = "fused",
                    max_pages: int | None = None) -> dict:
    """Wall-clock one batch's page schedule through the kernel hot path.

    mode="fused": kernels.fused_page_rank — ONE pipelined grid, page DMA of
    step i+1 double-buffered behind the fused exact-scan + ADC compute of
    step i. mode="split": the two separately-jitted grids it replaces
    (kernels.page_scan, then kernels.page_adc), run back to back.

    Returns {"wall_us", "pages", "us_per_page"}; the schedule is capped at
    `max_pages` (default MEASURE_PAGES_CAP) and the per-page rate is what
    callers scale by a query's own page count. Compilation is excluded (one
    warm-up call per shape bucket; the bucketed wrappers in kernels/ops.py
    keep the bucket count small)."""
    from repro import kernels as ops
    sched = hop_major_schedule(page_trace)
    cap = MEASURE_PAGES_CAP if max_pages is None else max_pages
    if cap > 0:
        sched = sched[:cap]
    if len(sched) == 0:
        return {"wall_us": 0.0, "pages": 0, "us_per_page": 0.0}
    _, vecs, _, _, _ = store.kernel_arrays()
    codes = _page_codes(store, pq)
    qb = jnp.asarray(queries, jnp.float32)
    lut = query_luts(pq.centroids, qb)
    ids = jnp.asarray(sched, jnp.int32)
    if mode == "fused":
        def fn():
            return ops.fused_page_rank(vecs, codes, ids, qb, lut)
    elif mode == "split":
        def fn():
            return (ops.page_scan(vecs, ids, qb),
                    ops.page_adc(codes, ids, lut))
    else:
        raise ValueError(f"mode={mode!r} must be 'fused' or 'split'")
    jax.block_until_ready(fn())      # compile + warm the bucket
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    wall = (time.perf_counter() - t0) * 1e6
    return {"wall_us": wall, "pages": len(sched),
            "us_per_page": wall / len(sched)}


def search_batched(store, pq, cfg, queries: np.ndarray, *,
                   medoid: int, memgraph=None, batch: int = 256,
                   collect_visited: bool = True,
                   collect_trace: bool = False,
                   account_kernel_io: bool = True) -> QueryStats:
    """Python driver: feed query batches through the jitted kernel, with page
    data and the cache mask supplied by `store` (any repro.io PageStore).

    This is the single search path behind both `DiskIndex.search` (the
    compatibility facade) and the serving layer's batch executor.
    `collect_trace` adds the temporally ordered per-hop page trace the
    stateful cache subsystem replays (QueryStats.page_trace).

    With `cfg.pipeline == "fused"` the trace is collected regardless (it IS
    the fused kernel's page schedule), the search results stay bit-identical
    to `pipeline=True`, and each batch's schedule is re-executed through the
    fused pipelined kernel: QueryStats.measured_step_us carries each query's
    measured kernel wall clock (its page count x the batch's measured
    per-page rate) next to the modeled device time.
    """
    fused = cfg.pipeline == "fused"
    track_trace = collect_trace or fused
    vids, vecs, nbrs, v2p, v2s = store.kernel_arrays()
    # the device copy of the vertex cache mask is memoized on the store
    # (same rationale as kernel_arrays: the serving layer calls this once
    # per dispatched micro-batch)
    cached = getattr(store, "_device_cache_mask", None)
    if cached is None:
        cached = jnp.asarray(store.vertex_cache_mask())
        store._device_cache_mask = cached
    # device copies of the PQ tables are memoized on the PQ object — the
    # serving layer calls this once per dispatched micro-batch, and
    # re-uploading the (n, m) code matrix each time would dominate
    pq_dev = getattr(pq, "_device_arrays", None)
    if pq_dev is None:
        pq_dev = (jnp.asarray(pq.centroids), jnp.asarray(pq.codes))
        pq._device_arrays = pq_dev
    pq_cent, pq_codes = pq_dev
    parts = []
    for s in range(0, len(queries), batch):
        qb = np.asarray(queries[s:s + batch], np.float32)
        if memgraph is not None and cfg.memgraph_frac > 0:
            mg = memgraph.entry_points(
                qb, n_entries=cfg.memgraph_entries, L=cfg.memgraph_L)
            entries = mg["entries"]
            mem_hops, mem_evals = mg["hops"], mg["dist_evals"]
        else:
            entries = np.full((len(qb), 1), medoid, np.int32)
            mem_hops = np.zeros(len(qb), np.int32)
            mem_evals = np.zeros(len(qb), np.int32)
        valid = entries >= 0
        out = _search_batch(
            vids, vecs, nbrs, v2p, v2s,
            pq_cent, pq_codes, cached,
            jnp.asarray(qb), jnp.asarray(entries), jnp.asarray(valid),
            k=cfg.k, L=cfg.L, width=cfg.beam_width,
            max_iters=cfg.max_iters, n_p=store.layout.n_p,
            page_search=cfg.page_search,
            dynamic_width=cfg.dynamic_width, dw_min=cfg.dw_min,
            dw_max=cfg.dw_max, pipeline=cfg.pipeline,
            spec=cfg.pipeline_spec, track_visited=collect_visited,
            track_trace=track_trace)
        out = {k_: np.asarray(v) for k_, v in out.items()}
        out["mem_hops"] = mem_hops
        out["mem_evals"] = mem_evals
        st = QueryStats.from_kernel(out)
        if fused:
            m = measure_step_us(store, pq, qb, out["page_trace"])
            st.measured_step_us = (st.page_reads.astype(np.float64)
                                   * m["us_per_page"])
        if account_kernel_io:
            store.note_kernel_io(st)
        parts.append(st)
    return QueryStats.concat(parts)
