"""PageShuffle (§4.2.1) — Starling-style locality-aware page packing.

Greedy heuristic for the NP-hard packing problem: visit vertices in BFS order
from the medoid; each unassigned vertex opens a page, then the page is filled
greedily with the unassigned candidate having the most edges into the page
(ties broken by distance rank). Requires the forward AND reverse graph in
memory (the paper's Finding 6: PageShuffle is time- and memory-intensive —
we measure and report both).
"""
from __future__ import annotations

import time
from collections import defaultdict, deque

import numpy as np


def shuffle_order(graph: np.ndarray, medoid: int, n_p: int,
                  seed: int = 0) -> dict:
    """Returns dict(perm (n,) int32, stats). perm[i] = vid at slot i."""
    t0 = time.time()
    n, R = graph.shape
    # forward + reverse adjacency (peak-memory cost measured for Table 6)
    fwd = [set(int(v) for v in row if v >= 0) for row in graph]
    rev = defaultdict(set)
    for u in range(n):
        for v in fwd[u]:
            rev[v].add(u)
    adj = [fwd[u] | rev[u] for u in range(n)]
    approx_mem = graph.nbytes * 2 + n * 64  # fwd + rev + bookkeeping (approx)

    # BFS order from medoid (fall back to unvisited ids for other components)
    order = []
    seen = np.zeros(n, bool)
    dq = deque([medoid])
    seen[medoid] = True
    ptr = 0
    while len(order) < n:
        if not dq:
            while ptr < n and seen[ptr]:
                ptr += 1
            if ptr >= n:
                break
            dq.append(ptr)
            seen[ptr] = True
        u = dq.popleft()
        order.append(u)
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                dq.append(v)

    assigned = np.full(n, False)
    perm = np.empty(n, np.int32)
    out_ptr = 0
    for u in order:
        if assigned[u]:
            continue
        page = [u]
        assigned[u] = True
        # greedy fill: candidate with most links into current page
        scores = defaultdict(int)
        for v in adj[u]:
            if not assigned[v]:
                scores[v] += 1
        while len(page) < n_p and scores:
            best = max(scores.items(), key=lambda kv: (kv[1], -kv[0]))[0]
            del scores[best]
            if assigned[best]:
                continue
            page.append(best)
            assigned[best] = True
            for w in adj[best]:
                if not assigned[w]:
                    scores[w] += 1
        for v in page:
            perm[out_ptr] = v
            out_ptr += 1
    # leftover singletons (opened pages may be underfull — keep slot order)
    stats = {"shuffle_s": time.time() - t0, "approx_peak_bytes": int(approx_mem)}
    return {"perm": perm, "stats": stats}
