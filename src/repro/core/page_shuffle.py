"""PageShuffle (§4.2.1) — Starling-style locality-aware page packing.

Greedy heuristic for the NP-hard packing problem: visit vertices in BFS order
from the medoid; each unassigned vertex opens a page, then the page is filled
greedily with the unassigned candidate having the most edges into the page
(ties broken by distance rank). Requires the forward AND reverse graph in
memory (the paper's Finding 6: PageShuffle is time- and memory-intensive —
we measure and report both).

The packer is exposed in pieces (`undirected_adjacency`, `bfs_order`,
`greedy_pack`) so the streaming-mutation subsystem (repro/mutation/) can run
the SAME greedy heuristic on a dirty sub-neighborhood during background
compaction instead of re-shuffling the whole index.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from typing import List, Sequence

import numpy as np


def undirected_adjacency(graph: np.ndarray) -> List[set]:
    """fwd ∪ rev adjacency sets of a (n, R) -1-padded edge list — the
    symmetric locality signal the packer scores candidates by."""
    n = graph.shape[0]
    fwd = [set(int(v) for v in row if v >= 0) for row in graph]
    rev = defaultdict(set)
    for u in range(n):
        for v in fwd[u]:
            rev[v].add(u)
    return [fwd[u] | rev[u] for u in range(n)]


def bfs_order(adj: Sequence[set], entry: int) -> List[int]:
    """BFS visit order from `entry`, falling back to the smallest unvisited
    id whenever a connected component is exhausted — every vertex appears
    exactly once even on disconnected graphs."""
    n = len(adj)
    order: List[int] = []
    seen = np.zeros(n, bool)
    dq = deque([entry])
    seen[entry] = True
    ptr = 0
    while len(order) < n:
        if not dq:
            while ptr < n and seen[ptr]:
                ptr += 1
            if ptr >= n:
                break
            dq.append(ptr)
            seen[ptr] = True
        u = dq.popleft()
        order.append(u)
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                dq.append(v)
    return order


def greedy_pack(adj: Sequence[set], order: Sequence[int],
                n_p: int) -> np.ndarray:
    """The greedy page filler: walk `order`; each unassigned vertex opens a
    page, then the page greedily absorbs the unassigned candidate with the
    most links into it (ties to the smallest id). Returns perm (n,) int32
    with perm[i] = the vertex stored at slot i — consecutive runs of n_p
    slots are one page."""
    n = len(adj)
    assigned = np.full(n, False)
    perm = np.empty(n, np.int32)
    out_ptr = 0
    for u in order:
        if assigned[u]:
            continue
        page = [u]
        assigned[u] = True
        # greedy fill: candidate with most links into current page
        scores = defaultdict(int)
        for v in adj[u]:
            if not assigned[v]:
                scores[v] += 1
        while len(page) < n_p and scores:
            best = max(scores.items(), key=lambda kv: (kv[1], -kv[0]))[0]
            del scores[best]
            if assigned[best]:
                continue
            page.append(best)
            assigned[best] = True
            for w in adj[best]:
                if not assigned[w]:
                    scores[w] += 1
        for v in page:
            perm[out_ptr] = v
            out_ptr += 1
    return perm


def shuffle_order(graph: np.ndarray, medoid: int, n_p: int,
                  seed: int = 0) -> dict:
    """Returns dict(perm (n,) int32, stats). perm[i] = vid at slot i.
    Deterministic for a given (graph, medoid, n_p); `seed` is accepted for
    interface symmetry with the other builders but unused (the heuristic
    breaks ties by id, not by chance)."""
    t0 = time.time()
    n = graph.shape[0]
    # forward + reverse adjacency (peak-memory cost measured for Table 6)
    adj = undirected_adjacency(graph)
    approx_mem = graph.nbytes * 2 + n * 64  # fwd + rev + bookkeeping (approx)

    # BFS order from medoid (fall back to unvisited ids for other components)
    order = bfs_order(adj, medoid)
    perm = greedy_pack(adj, order, n_p)
    # leftover singletons (opened pages may be underfull — keep slot order)
    stats = {"shuffle_s": time.time() - t0, "approx_peak_bytes": int(approx_mem)}
    return {"perm": perm, "stats": stats}
