"""Cache policies (§4.1.2).

SSSP/BFS cache: pre-load vertices within k hops of the entry point —
DiskANN's static strategy (the one the paper evaluates).

Frequency cache: BEYOND-PAPER ablation — the paper lists frequency-based
caching (Starling-style) but only benchmarks SSSP; we implement it by
replaying a sample workload through the in-memory traversal and caching the
most-expanded vertices. See benchmarks/cache_policy.py.
"""
from __future__ import annotations

from collections import deque

import numpy as np


def sssp_cache(graph: np.ndarray, medoid: int, budget_frac: float) -> np.ndarray:
    """Returns boolean (n,) mask of cached vertices (BFS-closest from the
    entry point until the budget is exhausted)."""
    n = graph.shape[0]
    budget = int(max(0, round(budget_frac * n)))
    cached = np.zeros(n, bool)
    if budget == 0:
        return cached
    seen = np.zeros(n, bool)
    dq = deque([medoid])
    seen[medoid] = True
    count = 0
    while dq and count < budget:
        u = dq.popleft()
        cached[u] = True
        count += 1
        for v in graph[u]:
            v = int(v)
            if v >= 0 and not seen[v]:
                seen[v] = True
                dq.append(v)
    return cached


def frequency_cache(graph: np.ndarray, vectors: np.ndarray, medoid: int,
                    sample_queries: np.ndarray, budget_frac: float,
                    L: int = 48, width: int = 4) -> np.ndarray:
    """Workload-aware cache: replay a query sample through the traversal and
    cache the most-frequently-expanded vertices (beyond-paper ablation)."""
    from repro.core.vamana import beam_search_mem
    from repro.core.searchutils import SENTINEL

    n = graph.shape[0]
    budget = int(max(0, round(budget_frac * n)))
    cached = np.zeros(n, bool)
    if budget == 0 or len(sample_queries) == 0:
        return cached
    res = beam_search_mem(vectors, graph, medoid, sample_queries,
                          L=L, width=width)
    vis = np.asarray(res["visited_ids"]).reshape(-1)
    vis = vis[vis < int(SENTINEL)]
    counts = np.bincount(vis, minlength=n)
    top = np.argsort(-counts)[:budget]
    cached[top[counts[top] > 0]] = True
    # fill any remainder from the entry point's BFS neighborhood
    if cached.sum() < budget:
        extra = sssp_cache(graph, medoid, budget_frac)
        cached |= extra
    return cached
