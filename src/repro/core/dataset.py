"""Synthetic stand-ins for the paper's four datasets + exact ground truth.

The paper evaluates SIFT(128d)/DEEP(96d)/SPACEV(100d int8)/GIST(960d) at
10^8 scale; the engine here is scale-free, so we generate clustered mixtures
matching each dataset's dimensionality/dtype regime at a CPU-friendly scale
(default n=32768, override with REPRO_ANN_N). Ground truth is exact brute force.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Dataset:
    name: str
    vectors: np.ndarray   # (n, d) float32
    queries: np.ndarray   # (nq, d) float32
    gt: np.ndarray        # (nq, k_gt) int32 — exact nearest neighbors
    dtype_tag: str        # "float" | "uint8" | "int8" (paper's storage dtype)

    @property
    def n(self):
        return self.vectors.shape[0]

    @property
    def d(self):
        return self.vectors.shape[1]

    @property
    def record_bytes(self):
        per = {"float": 4, "uint8": 1, "int8": 1}[self.dtype_tag]
        return self.d * per


_SPECS = {
    # name: (dim, dtype_tag, n_clusters)
    "sift-like": (128, "uint8", 64),
    "deep-like": (96, "float", 64),
    "spacev-like": (100, "int8", 48),
    "gist-like": (960, "float", 32),
}

DATASET_NAMES = tuple(_SPECS)


def default_scale() -> int:
    return int(os.environ.get("REPRO_ANN_N", 32768))


def make_dataset(name: str, n: Optional[int] = None, nq: int = 256,
                 k_gt: int = 100, seed: int = 0) -> Dataset:
    dim, tag, n_clusters = _SPECS[name]
    n = n or default_scale()
    # zlib.crc32: stable across processes (hash() is salted per process,
    # which would silently invalidate disk-cached graphs between runs)
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 10000)
    # Clustered data on a low-dimensional nonlinear manifold: real SIFT/DEEP/
    # GIST embeddings have intrinsic dimensionality ~10-20, which is what
    # makes proximity-graph search effective. (I.i.d. high-dim Gaussian
    # blobs suffer distance concentration and disconnect kNN graphs —
    # unrepresentative of the paper's datasets.)
    k_lat = int(np.clip(dim // 12, 8, 16))
    centers = rng.normal(0, 1.0, (n_clusters, k_lat)).astype(np.float32)
    w1 = rng.normal(0, 1.0, (k_lat, 4 * k_lat)).astype(np.float32) / np.sqrt(k_lat)
    w2 = rng.normal(0, 1.0, (4 * k_lat, dim)).astype(np.float32) / np.sqrt(4 * k_lat)

    def lift(z):
        return (np.tanh(z @ w1) @ w2 + 0.05 * rng.normal(
            0, 1.0, (len(z), dim))).astype(np.float32)

    z = centers[rng.integers(0, n_clusters, n)] + 0.6 * rng.normal(
        0, 1.0, (n, k_lat)).astype(np.float32)
    x = lift(z)
    zq = centers[rng.integers(0, n_clusters, nq)] + 0.6 * rng.normal(
        0, 1.0, (nq, k_lat)).astype(np.float32)
    q = lift(zq)
    if tag in ("uint8", "int8"):
        # quantize into the integer range like SIFT/SPACEV storage
        lo, hi = (0, 255) if tag == "uint8" else (-128, 127)
        scale = 80.0 / max(np.abs(x).max(), 1e-6)
        x = np.clip(np.round(x * scale + (128 if tag == "uint8" else 0)),
                    lo, hi).astype(np.float32)
        q = np.clip(np.round(q * scale + (128 if tag == "uint8" else 0)),
                    lo, hi).astype(np.float32)
    gt = exact_ground_truth(x, q, k_gt)
    return Dataset(name, x, q, gt, tag)


def exact_ground_truth(x: np.ndarray, q: np.ndarray, k: int,
                       block: int = 1024) -> np.ndarray:
    """Chunked brute force (memory-safe for any n)."""
    xn = (x.astype(np.float32) ** 2).sum(1)
    out = np.empty((len(q), k), np.int32)
    for i in range(0, len(q), block):
        qb = q[i:i + block].astype(np.float32)
        d = xn[None, :] - 2.0 * qb @ x.T  # + ||q||² (constant per row)
        idx = np.argpartition(d, k, axis=1)[:, :k]
        row_d = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(row_d, axis=1)
        out[i:i + block] = np.take_along_axis(idx, order, axis=1)
    return out


def recall_at_k(result_ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Recall@k = |result ∩ gt_k| / k averaged over queries."""
    hits = 0
    for r, g in zip(result_ids[:, :k], gt[:, :k]):
        hits += len(set(int(v) for v in r if v >= 0) & set(int(v) for v in g))
    return hits / (len(gt) * k)
