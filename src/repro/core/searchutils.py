"""Fixed-size candidate-list primitives shared by the Vamana builder, the
MemGraph navigator and the disk-page search engine.

Everything is shape-static and jit/vmap-friendly. The candidate list is the
DiskANN search pool: ids sorted by ranking key, each entry carrying
(expanded?, exact-distance-known?) flags. Deduplication uses a segmented
min/or scan over id-sorted runs (exact for runs <= 64, far above anything the
engine produces).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

SENTINEL = jnp.int32(2 ** 30)  # padding id; sorts after every real id
INF = jnp.float32(3e38)


def sq_dists(q, X):
    """q (d,) or (B,d); X (..., d) -> squared L2."""
    diff = q[..., None, :] - X
    return jnp.sum(jnp.square(diff), axis=-1)


def _segmented_min_or(ids, keys, flags):
    """ids sorted ascending. Within equal-id runs: min over keys (per column)
    and OR over flags (per column). log-shift passes, exact for runs <= 64."""
    n = ids.shape[0]
    # suffix-scan within runs. ids are sorted, so ids[i]==ids[i+shift] implies
    # the whole window is one run — doubling shifts therefore implement an
    # exact segmented min/or for ANY run length in ceil(log2 n) passes. The
    # FIRST element of each run accumulates the run and is the one
    # dedup_merge_topL keeps.
    shift = 1
    while shift < n:
        same = jnp.concatenate(
            [ids[:-shift] == ids[shift:], jnp.zeros((shift,), bool)])
        sk = jnp.concatenate([keys[shift:],
                              jnp.full((shift,) + keys.shape[1:], INF)])
        keys = jnp.where(same[:, None], jnp.minimum(keys, sk), keys)
        sf = jnp.concatenate([flags[shift:],
                              jnp.zeros((shift,) + flags.shape[1:], bool)])
        flags = jnp.where(same[:, None], flags | sf, flags)
        shift *= 2
    return keys, flags


def dedup_merge_topL(ids, keys, flags, L):
    """ids (N,) int32 (SENTINEL padding); keys (N, K) f32 — column 0 is the
    ranking key; flags (N, F) bool. Returns (ids, keys, flags) of length L:
    unique ids, best (min) keys / OR'd flags per id, sorted by keys[:,0].
    """
    order = jnp.argsort(ids)
    ids, keys, flags = ids[order], keys[order], flags[order]
    keys, flags = _segmented_min_or(ids, keys, flags)
    first = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
    rank_key = jnp.where(first & (ids < SENTINEL), keys[:, 0], INF)
    order2 = jnp.argsort(rank_key)[:L]
    out_ids = jnp.where(rank_key[order2] < INF, ids[order2], SENTINEL)
    return out_ids, keys[order2], flags[order2]


def top_w_unexpanded(keys0, expanded, valid, w_static, w_dynamic=None):
    """Select indices of the best w unexpanded valid candidates.
    Returns (idx (w_static,), active (w_static,) bool). w_dynamic (traced
    scalar <= w_static) masks the selection width at runtime (DynamicWidth).
    """
    masked = jnp.where(valid & ~expanded, keys0, INF)
    idx = jnp.argsort(masked)[:w_static]
    active = masked[idx] < INF
    if w_dynamic is not None:
        active = active & (jnp.arange(w_static) < w_dynamic)
    return idx, active
