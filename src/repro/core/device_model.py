"""Device model: converts measured per-query I/O + compute counts into
latency/QPS, using the paper's own fio-measured constants (§5.1) — this
container has no NVMe SSD, so wall-clock timing is derived, not faked.

SSD (paper Table/§5): 4 KB random read: 819K IOPS, 3200 MB/s;
16 KB: 318K IOPS, 4962 MB/s; 48 search workers; DIRECT_IO (no page cache).

Sequential execution (baseline): per-step latency = t_issue + pages/step
service + compute. Pipeline search overlaps the two: max(io, compute) per
step (§4.3.2, Fig. 9) — while its speculative reads add pages (Finding 5).

Concurrency (serving layer): `concurrent_latency_us(queue_depth, ...)`
generalizes the fixed-48-worker model to an arbitrary number of in-flight
queries. Per-page service time inflates linearly with queue depth
(closed-loop queueing knee: latency flat until the device's internal
parallelism is covered, then ∝ depth, so throughput saturates at the
IOPS/bandwidth ceiling). At queue_depth == workers it reproduces
`query_latency_us` exactly.

Sharding (distributed serving): with `shard_pages`/`shard_depths` the same
model runs per shard device — each shard serves its slice of a batch at its
own queue depth, and a query's page service is the max over its shards'
completion times (shards are parallel devices; the slowest one gates).

The TPU variant of the same model (used by kernels/page_scan) books HBM
bytes at 819 GB/s with DMA/compute overlap — see benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np


@dataclasses.dataclass(frozen=True)
class TPUDevice:
    """Peak constants of one accelerator generation — the single pricing
    table shared by the model-side rooflines (benchmarks/roofline.py), the
    kernel microbenches (benchmarks/kernels.py) and the fused disk-path
    sweep (benchmarks/fused_pipeline.py), so kernel and model benchmarks
    price the same hardware instead of each hard-coding its own copy."""
    name: str
    peak_flops: float          # bf16 FLOP/s (MXU peak)
    hbm_bw: float              # bytes/s HBM
    link_bw: float             # bytes/s per ICI link
    vmem_bytes: int = 16 * 2**20   # per-core VMEM (double-buffer budget)

    def compute_s(self, flops: float) -> float:
        return flops / self.peak_flops

    def memory_s(self, nbytes: float) -> float:
        return nbytes / self.hbm_bw


TPU_DEVICES = {
    "v5e": TPUDevice("v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9),
    "v4": TPUDevice("v4", peak_flops=275e12, hbm_bw=1228e9, link_bw=100e9,
                    vmem_bytes=32 * 2**20),
    "v5p": TPUDevice("v5p", peak_flops=459e12, hbm_bw=2765e9, link_bw=100e9),
}


def tpu_device(name: str = "") -> TPUDevice:
    """Resolve a device table entry; `REPRO_TPU_DEVICE` overrides the
    default (v5e — the generation the paper-era kernels were sized for)."""
    name = name or os.environ.get("REPRO_TPU_DEVICE", "v5e")
    if name not in TPU_DEVICES:
        raise ValueError(f"unknown TPU device {name!r}; "
                         f"choose from {sorted(TPU_DEVICES)}")
    return TPU_DEVICES[name]


@dataclasses.dataclass(frozen=True)
class SSDModel:
    workers: int = 48
    issue_us: float = 12.0          # submission + completion overhead per batch
    # NVMe internal parallelism: queue depths below this complete at the
    # same per-read latency (flat region before the queueing knee)
    device_parallelism: int = 8
    # page-size dependent service rates (measured in the paper)
    iops_4k: float = 819e3
    bw_4k: float = 3.2e9
    iops_16k: float = 318e3
    bw_16k: float = 4.962e9
    # compute (per-worker core): ns per float op in distance kernels
    ns_per_dim_full: float = 0.8    # SIMD L2 per dimension
    ns_per_sub_adc: float = 1.2     # ADC table lookup per subspace
    # writes (streaming updates: flush/compaction rewrites): the paper only
    # measures the read path, so the write service time is modeled as a
    # multiple of the read service — NVMe steady-state random-write
    # throughput runs well below read throughput once the FTL is folding
    write_penalty: float = 2.0

    def _rates(self, page_bytes: int) -> tuple:
        """(IOPS, bandwidth) at this page size; 8K interpolates between the
        paper's two measured points."""
        if page_bytes <= 4096:
            return self.iops_4k, self.bw_4k
        if page_bytes <= 8192:
            return ((self.iops_4k + self.iops_16k) / 2,
                    (self.bw_4k + self.bw_16k) / 2)
        return self.iops_16k, self.bw_16k

    def read_service_us(self, page_bytes: int) -> float:
        """Raw device service time of ONE read — 1/IOPS or the byte time,
        whichever binds — before any queueing or worker amortization. This
        is the utilization unit: issued reads x this, over elapsed time, is
        the fraction of the device's saturation capacity actually used."""
        iops, bw = self._rates(page_bytes)
        return max(1.0 / iops, page_bytes / bw) * 1e6

    def write_service_us(self, page_bytes: int) -> float:
        """Raw device service time of ONE page rewrite (streaming updates:
        append flushes and compaction re-packs) — the read unit scaled by
        `write_penalty`. Background update I/O priced in this unit shares
        the device with query reads, so compaction visibly taxes serving."""
        return self.read_service_us(page_bytes) * self.write_penalty

    def page_service_us(self, page_bytes: int) -> float:
        """Mean device service time per page at saturation, amortized
        across workers (queue-theoretic throughput view) — exactly the
        pre-refactor fixed-concurrency model, independent of the
        device_parallelism floor below."""
        return self.read_service_us(page_bytes) * self.workers

    def concurrent_page_service_us(self, page_bytes: int,
                                   queue_depth: float) -> float:
        """Per-page service time with `queue_depth` in-flight queries: flat
        below `device_parallelism` (the device absorbs that much concurrency
        at the knee latency, device_parallelism x the raw per-read time),
        then grows ∝ depth (each page waits behind depth-1 peers), so
        throughput saturates at the IOPS/bandwidth ceiling."""
        per_read = self.read_service_us(page_bytes)
        return per_read * max(queue_depth, float(self.device_parallelism))

    def _compute_us(self, full_evals, pq_evals, mem_evals, d, pq_m):
        return (full_evals * d * self.ns_per_dim_full
                + pq_evals * pq_m * self.ns_per_sub_adc
                + mem_evals * d * self.ns_per_dim_full) / 1e3

    def query_latency_us(self, *, hops, pages, full_evals, pq_evals,
                         mem_evals, d, pq_m, page_bytes, pipeline=False):
        """All args per-query numpy arrays (B,). Returns (B,) microseconds.
        Fixed-concurrency view: the device is saturated by `workers`."""
        return self.concurrent_latency_us(
            self.workers, hops=hops, pages=pages, full_evals=full_evals,
            pq_evals=pq_evals, mem_evals=mem_evals, d=d, pq_m=pq_m,
            page_bytes=page_bytes, pipeline=pipeline)

    def concurrent_latency_us(self, queue_depth, *, hops, pages, full_evals,
                              pq_evals, mem_evals, d, pq_m, page_bytes,
                              pipeline=False, page_dedup: float = 1.0,
                              prefetch_overlap: float = 0.0,
                              shard_pages=None, shard_depths=None):
        """Per-query latency with `queue_depth` queries in flight on the
        device. `page_dedup` (<= 1) rebates the page volume when a batch
        scheduler coalesced duplicate reads (BatchedPageStore).
        `prefetch_overlap` (in [0, 1]) is the fraction of page service a
        look-ahead prefetcher issued during the previous hop's compute
        (PrefetchingPageStore): that I/O is hidden behind compute, but only
        up to the compute actually available. Pipeline search already
        overlaps I/O and compute wholesale, so the rebate is subsumed there.

        Sharded stores (ShardedPageStore) pass `shard_pages` ((B, S): reads
        each query charged on each of S shard devices) and `shard_depths`
        ((S,): queries with work on that shard, its device queue depth).
        Shards serve in parallel, so a query's page-service time is the MAX
        over its shards' completion times — the batch finishes when its
        slowest device does, and an imbalanced placement is visibly slower
        than a balanced one at equal total pages. `pages` is ignored on
        this path (the split already carries the volume); hop issue
        overhead and the dedup/prefetch rebates apply unchanged.

        Fleet serving (replica groups, repro/serving/fleet.py) adds one
        axis: `shard_pages` (B, R, S) with `shard_depths` (R, S) prices
        R full replicas of the shard set. Every (replica, shard) pair is
        its own device, so the completion time is the max over REPLICAS
        THEN SHARDS — flattening the grid to R*S parallel devices computes
        exactly that, and an imbalanced fleet (one replica overloaded at
        equal total pages) stays visibly slower than a balanced one."""
        if shard_pages is not None:
            sp = np.asarray(shard_pages, np.float64)
            if sp.ndim == 3:
                # (B, R, S) replica grid -> R*S parallel devices; max over
                # the flattened axis IS max-over-replicas-then-shards
                B, R, S = sp.shape
                sp = sp.reshape(B, R * S)
                if shard_depths is not None:
                    sd = np.asarray(shard_depths, np.float64)
                    if sd.shape != (R, S):
                        raise ValueError(
                            f"shard_depths must be ({R}, {S}) for "
                            f"shard_pages {(B, R, S)}; got {sd.shape}")
                    shard_depths = sd.reshape(R * S)
            elif sp.ndim != 2:
                raise ValueError(
                    f"shard_pages must be (B, shards) or (B, replicas, "
                    f"shards); got {sp.shape}")
            if shard_depths is None:
                depths = np.full(sp.shape[1], float(queue_depth))
            else:
                depths = np.asarray(shard_depths, np.float64).reshape(-1)
                if len(depths) != sp.shape[1]:
                    raise ValueError(
                        f"shard_depths has {len(depths)} entries for "
                        f"{sp.shape[1]} shards")
            t_shard = np.asarray([
                self.concurrent_page_service_us(page_bytes, qd)
                for qd in depths])
            page_service = (sp * page_dedup * t_shard).max(axis=1)
        else:
            t_page = self.concurrent_page_service_us(page_bytes, queue_depth)
            page_service = pages * page_dedup * t_page
        io = page_service + hops * self.issue_us
        comp = self._compute_us(full_evals, pq_evals, mem_evals, d, pq_m)
        if pipeline:
            # per-step overlap approximated at query granularity
            return np.maximum(io, comp) + np.minimum(io, comp) * 0.1
        hidden = np.minimum(io * np.clip(prefetch_overlap, 0.0, 1.0), comp)
        return io + comp - hidden

    def qps(self, latency_us: np.ndarray, *, pages, page_bytes) -> float:
        """Throughput under `workers` concurrent queries, capped by device
        IOPS/bandwidth saturation."""
        mean_lat = float(np.mean(latency_us))
        qps_workers = self.workers / (mean_lat * 1e-6)
        iops, bw = self._rates(page_bytes)
        mean_pages = float(np.mean(pages))
        qps_iops = iops / max(mean_pages, 1e-9)
        qps_bw = bw / max(mean_pages * page_bytes, 1e-9)
        return min(qps_workers, qps_iops, qps_bw)

    def device_counters(self, qps: float, *, pages, page_bytes):
        """Modeled IOPS / bandwidth at the achieved QPS (paper Table 5/7)."""
        mean_pages = float(np.mean(pages))
        iops = qps * mean_pages
        bw = iops * page_bytes
        return {"iops": iops, "bw_mbps": bw / 1e6}


def summarize(model: SSDModel, result, *, d, pq_m, page_bytes, pipeline=False):
    """Compatibility alias — the summary lives on QueryStats (one code path
    for tests, benchmarks and the serving layer)."""
    return result.summary(model, d=d, pq_m=pq_m, page_bytes=page_bytes,
                          pipeline=pipeline)
