"""Device model: converts measured per-query I/O + compute counts into
latency/QPS, using the paper's own fio-measured constants (§5.1) — this
container has no NVMe SSD, so wall-clock timing is derived, not faked.

SSD (paper Table/§5): 4 KB random read: 819K IOPS, 3200 MB/s;
16 KB: 318K IOPS, 4962 MB/s; 48 search workers; DIRECT_IO (no page cache).

Sequential execution (baseline): per-step latency = t_issue + pages/step
service + compute. Pipeline search overlaps the two: max(io, compute) per
step (§4.3.2, Fig. 9) — while its speculative reads add pages (Finding 5).

The TPU variant of the same model (used by kernels/page_scan) books HBM
bytes at 819 GB/s with DMA/compute overlap — see benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SSDModel:
    workers: int = 48
    issue_us: float = 12.0          # submission + completion overhead per batch
    # page-size dependent service rates (measured in the paper)
    iops_4k: float = 819e3
    bw_4k: float = 3.2e9
    iops_16k: float = 318e3
    bw_16k: float = 4.962e9
    # compute (per-worker core): ns per float op in distance kernels
    ns_per_dim_full: float = 0.8    # SIMD L2 per dimension
    ns_per_sub_adc: float = 1.2     # ADC table lookup per subspace

    def page_service_us(self, page_bytes: int) -> float:
        """Mean device service time per page at saturation, amortized
        across workers (queue-theoretic throughput view)."""
        if page_bytes <= 4096:
            iops, bw = self.iops_4k, self.bw_4k
        elif page_bytes <= 8192:
            # interpolate 8K between the two measured points
            iops = (self.iops_4k + self.iops_16k) / 2
            bw = (self.bw_4k + self.bw_16k) / 2
        else:
            iops, bw = self.iops_16k, self.bw_16k
        per_read = max(1.0 / iops, page_bytes / bw)
        return per_read * self.workers * 1e6  # per-worker effective service

    def query_latency_us(self, *, hops, pages, full_evals, pq_evals,
                         mem_evals, d, pq_m, page_bytes, pipeline=False):
        """All args per-query numpy arrays (B,). Returns (B,) microseconds."""
        t_page = self.page_service_us(page_bytes)
        io = pages * t_page + hops * self.issue_us
        comp = (full_evals * d * self.ns_per_dim_full
                + pq_evals * pq_m * self.ns_per_sub_adc
                + mem_evals * d * self.ns_per_dim_full) / 1e3
        if pipeline:
            # per-step overlap approximated at query granularity
            return np.maximum(io, comp) + np.minimum(io, comp) * 0.1
        return io + comp

    def qps(self, latency_us: np.ndarray, *, pages, page_bytes) -> float:
        """Throughput under `workers` concurrent queries, capped by device
        IOPS/bandwidth saturation."""
        mean_lat = float(np.mean(latency_us))
        qps_workers = self.workers / (mean_lat * 1e-6)
        if page_bytes <= 4096:
            iops, bw = self.iops_4k, self.bw_4k
        elif page_bytes <= 8192:
            iops = (self.iops_4k + self.iops_16k) / 2
            bw = (self.bw_4k + self.bw_16k) / 2
        else:
            iops, bw = self.iops_16k, self.bw_16k
        mean_pages = float(np.mean(pages))
        qps_iops = iops / max(mean_pages, 1e-9)
        qps_bw = bw / max(mean_pages * page_bytes, 1e-9)
        return min(qps_workers, qps_iops, qps_bw)

    def device_counters(self, qps: float, *, pages, page_bytes):
        """Modeled IOPS / bandwidth at the achieved QPS (paper Table 5/7)."""
        mean_pages = float(np.mean(pages))
        iops = qps * mean_pages
        bw = iops * page_bytes
        return {"iops": iops, "bw_mbps": bw / 1e6}


def summarize(model: SSDModel, result, *, d, pq_m, page_bytes, pipeline=False):
    lat = model.query_latency_us(
        hops=result.hops.astype(np.float64),
        pages=result.page_reads.astype(np.float64),
        full_evals=result.full_evals.astype(np.float64),
        pq_evals=result.pq_evals.astype(np.float64),
        mem_evals=result.mem_evals.astype(np.float64),
        d=d, pq_m=pq_m, page_bytes=page_bytes, pipeline=pipeline)
    qps = model.qps(lat, pages=result.page_reads, page_bytes=page_bytes)
    dev = model.device_counters(qps, pages=result.page_reads,
                                page_bytes=page_bytes)
    io_us = result.page_reads.astype(np.float64) * model.page_service_us(page_bytes)
    return {
        "mean_latency_us": float(np.mean(lat)),
        "p99_latency_us": float(np.percentile(lat, 99)),
        "qps": qps,
        "mean_pages_per_query": float(np.mean(result.page_reads)),
        "io_fraction": float(np.mean(io_us / np.maximum(lat, 1e-9))),
        "u_io": float(result.io_utilization()),
        **dev,
    }
