"""QueryStats — the one per-query accounting record shared by every layer.

The search kernel (core/search_kernel.py) produces raw per-query counters;
`QueryStats` carries them from the kernel to the device model, the serving
layer and the benchmark scripts through a single code path (previously each
benchmark hand-plumbed its own dict of fields out of `SearchResult`).

`visited_pages` is the per-query charged-page bitmap (B, num_pages). It is
what the I/O layer's `BatchedPageStore` consumes to coalesce duplicate page
requests across the queries of a batch — an accounting the scalar per-query
counters cannot express.

`page_trace` is the temporally ordered form of the same charges,
(B, max_iters, w) with -1 padding: row (b, h) names the distinct pages
query b charged at hop h. The stateful cache subsystem
(repro/io/page_cache.py) replays it against LRU/FIFO/2Q caches whose state
persists across batches — an accounting the order-free bitmap cannot
express.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class QueryStats:
    ids: np.ndarray            # (B, k)
    dists: np.ndarray          # (B, k)
    hops: np.ndarray           # (B,)
    page_reads: np.ndarray     # (B,) unique page fetches charged to SSD
    cache_hits: np.ndarray     # (B,)
    n_read_records: np.ndarray  # (B,) records fetched (N_read, Eq. 3)
    n_eff: np.ndarray          # (B,) records actually expanded (N_eff)
    full_evals: np.ndarray     # (B,) full-precision distance computations
    pq_evals: np.ndarray       # (B,) ADC distance computations
    mem_hops: np.ndarray       # (B,) MemGraph in-memory hops
    mem_evals: np.ndarray      # (B,) MemGraph distance evals
    # (B, num_pages) bool — pages each query charged to the device; feeds
    # BatchedPageStore's cross-query dedup. Optional: facade callers that
    # never batch across queries may drop it.
    visited_pages: Optional[np.ndarray] = None
    # (B, max_iters, w) int32, -1 padded — the same charged pages in hop
    # order; feeds the stateful cache subsystem's trace replay
    # (repro/io/page_cache.py). Optional: only trace-replaying callers
    # (dynamic cache policies, prefetch) pay for it.
    page_trace: Optional[np.ndarray] = None
    # (B,) int64 — per-query tenant ids, stamped by the SERVING layer (the
    # kernel is tenant-blind): routes trace replay to per-tenant cache
    # partitions and keys per-tenant report accounting. Optional: single-
    # tenant callers never carry it.
    tenants: Optional[np.ndarray] = None
    # (B,) float64 — MEASURED fused-kernel wall clock per query in us (the
    # query's page count x the batch's measured per-page step rate), set
    # only under SearchConfig.pipeline == "fused"
    # (core/search_kernel.measure_step_us). Sits NEXT TO the modeled device
    # time — never inside it: the device model stays the paper's analytic
    # account, and this column is what it is compared against.
    measured_step_us: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.hops)

    def io_utilization(self) -> float:
        return self.n_eff.sum() / max(self.n_read_records.sum(), 1)

    # -- construction -------------------------------------------------------

    _KERNEL_KEYS = {
        "ids": "ids", "dists": "dists", "hops": "hops",
        "page_reads": "page_reads", "cache_hits": "cache_hits",
        "n_read_records": "n_read", "n_eff": "n_eff",
        "full_evals": "full_evals", "pq_evals": "pq_evals",
        "mem_hops": "mem_hops", "mem_evals": "mem_evals",
        "visited_pages": "visited_pages", "page_trace": "page_trace",
        "tenants": "tenants", "measured_step_us": "measured_step_us",
    }

    @classmethod
    def from_kernel(cls, out: dict) -> "QueryStats":
        """Build from one kernel output dict (see search_kernel.KERNEL_KEYS)."""
        kw = {f: np.asarray(out[k]) for f, k in cls._KERNEL_KEYS.items()
              if k in out}
        kw.setdefault("visited_pages", None)
        kw.setdefault("page_trace", None)
        kw.setdefault("tenants", None)
        kw.setdefault("measured_step_us", None)
        return cls(**kw)

    @classmethod
    def concat(cls, parts: List["QueryStats"]) -> "QueryStats":
        """Concatenate per-batch stats along the query axis.

        `visited_pages` widths may differ across batches when the page
        space GROWS mid-run (streaming updates append pages): earlier
        bitmaps are padded with False — a page that did not exist cannot
        have been charged. `page_trace` rows are -1-padded likewise (its
        width follows the beam, which degrade levels shrink)."""
        if len(parts) == 1:
            return parts[0]
        kw = {}
        for f in cls._KERNEL_KEYS:
            vals = [getattr(p, f) for p in parts]
            if any(v is None for v in vals):
                kw[f] = None
                continue
            if f == "visited_pages":
                w = max(v.shape[1] for v in vals)
                vals = [v if v.shape[1] == w else
                        np.pad(v, ((0, 0), (0, w - v.shape[1])))
                        for v in vals]
            elif f == "page_trace":
                h = max(v.shape[1] for v in vals)
                w = max(v.shape[2] for v in vals)
                vals = [v if v.shape[1:] == (h, w) else
                        np.pad(v, ((0, 0), (0, h - v.shape[1]),
                                   (0, w - v.shape[2])),
                               constant_values=-1)
                        for v in vals]
            kw[f] = np.concatenate(vals)
        return cls(**kw)

    def take(self, n: int) -> "QueryStats":
        """First n queries (drops padding added by the batch scheduler)."""
        kw = {f: (getattr(self, f)[:n] if getattr(self, f) is not None
                  else None) for f in self._KERNEL_KEYS}
        return QueryStats(**kw)

    # -- metrics (the single summary code path) -----------------------------

    def batch_unique_pages(self) -> int:
        """Pages a cross-query coalescing fetcher would issue for this batch
        (union of per-query charged pages). Requires visited_pages."""
        if self.visited_pages is None:
            raise ValueError("visited_pages not collected for these stats")
        return int(self.visited_pages.any(axis=0).sum())

    def summary(self, model, *, d: int, pq_m: int, page_bytes: int,
                pipeline: bool = False) -> dict:
        """Latency/QPS/device counters via the SSD device model — the one
        code path every benchmark and test consumes (device_model.summarize
        is a thin alias kept for compatibility)."""
        lat = model.query_latency_us(
            hops=self.hops.astype(np.float64),
            pages=self.page_reads.astype(np.float64),
            full_evals=self.full_evals.astype(np.float64),
            pq_evals=self.pq_evals.astype(np.float64),
            mem_evals=self.mem_evals.astype(np.float64),
            d=d, pq_m=pq_m, page_bytes=page_bytes, pipeline=pipeline)
        qps = model.qps(lat, pages=self.page_reads, page_bytes=page_bytes)
        dev = model.device_counters(qps, pages=self.page_reads,
                                    page_bytes=page_bytes)
        io_us = (self.page_reads.astype(np.float64)
                 * model.page_service_us(page_bytes))
        return {
            "mean_latency_us": float(np.mean(lat)),
            "p99_latency_us": float(np.percentile(lat, 99)),
            "qps": qps,
            "mean_pages_per_query": float(np.mean(self.page_reads)),
            "mean_hops": float(np.mean(self.hops)),
            "io_fraction": float(np.mean(io_us / np.maximum(lat, 1e-9))),
            "u_io": float(self.io_utilization()),
            **dev,
        }


# Compatibility alias: the pre-refactor engine exposed the same record under
# this name; downstream code may keep using it.
SearchResult = QueryStats
