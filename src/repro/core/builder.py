"""Index construction pipeline: Vamana graph -> PQ -> page layout (+optional
page shuffle) -> cache -> MemGraph, per a SearchConfig. Build costs are
recorded for the Table-6 reproduction (Finding 6)."""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core import cache as cache_mod
from repro.core import memgraph as mg_mod
from repro.core import page_shuffle as ps_mod
from repro.core import pq as pq_mod
from repro.core import vamana
from repro.core.dataset import Dataset
from repro.core.engine import DiskIndex, SearchConfig
from repro.core.pages import build_layout, overlap_ratio, records_per_page


def build_index(ds: Dataset, cfg: SearchConfig, *, R: int = 64,
                L_build: int = 125, alpha: float = 1.2, seed: int = 0,
                graph: Optional[np.ndarray] = None,
                medoid_id: Optional[int] = None,
                log=lambda *a: None) -> DiskIndex:
    stats = {}
    t0 = time.time()
    if graph is None:
        graph, medoid_id, gstats = vamana.build_vamana(
            ds.vectors, R=R, L=L_build, alpha=alpha, seed=seed, log=log)
        stats.update(gstats)
    stats["graph_build_s"] = time.time() - t0

    t0 = time.time()
    pq = pq_mod.train_pq(ds.vectors, m=cfg.pq_m, seed=seed)
    stats["pq_build_s"] = time.time() - t0

    vec_bytes = 1 if ds.dtype_tag in ("uint8", "int8") else 4
    n_p, _ = records_per_page(cfg.page_bytes, ds.d, vec_bytes, R,
                              cfg.all_in_storage, cfg.pq_m)
    perm = None
    if cfg.page_shuffle:
        sh = ps_mod.shuffle_order(graph, medoid_id, n_p, seed=seed)
        perm = sh["perm"]
        stats.update(sh["stats"])
    t0 = time.time()
    layout = build_layout(ds.vectors, graph, page_bytes=cfg.page_bytes,
                          vec_bytes_per_dim=vec_bytes, perm=perm,
                          all_in_storage=cfg.all_in_storage, pq_m=cfg.pq_m)
    stats["layout_s"] = time.time() - t0
    stats["overlap_ratio"] = overlap_ratio(layout, graph)
    stats["n_p"] = layout.n_p
    stats["disk_bytes"] = layout.disk_bytes

    cached = None
    if cfg.cache_frac > 0:
        if cfg.cache_policy == "freq":
            rng = np.random.default_rng(seed)
            sample = ds.vectors[rng.choice(ds.n, min(256, ds.n),
                                           replace=False)]
            cached = cache_mod.frequency_cache(graph, ds.vectors, medoid_id,
                                               sample, cfg.cache_frac)
        else:
            cached = cache_mod.sssp_cache(graph, medoid_id, cfg.cache_frac)

    memgraph = None
    if cfg.memgraph_frac > 0:
        t0 = time.time()
        memgraph = mg_mod.build_memgraph(ds.vectors, frac=cfg.memgraph_frac,
                                         seed=seed)
        stats["memgraph_build_s"] = time.time() - t0

    idx = DiskIndex(layout, pq, graph, medoid_id, cfg, memgraph=memgraph,
                    cached=cached, build_stats=stats)
    stats["memory_bytes"] = idx.memory_bytes()
    return idx
