from repro.core.builder import build_index
from repro.core.dataset import (DATASET_NAMES, Dataset, make_dataset,
                                recall_at_k)
from repro.core.device_model import (SSDModel, TPU_DEVICES, TPUDevice,
                                     summarize, tpu_device)
from repro.core.engine import DiskIndex, SearchConfig, SearchResult
from repro.core.pages import overlap_ratio
from repro.core.presets import PRESETS, get_preset
from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats
