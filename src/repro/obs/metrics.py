"""Metrics primitives for the serving stack.

One implementation backs every report percentile: a log-bucketed
``Histogram`` with a documented multiplicative error bound, plus the
usual monotone ``Counter`` and last-write ``Gauge``, collected in a
``MetricsRegistry``.

Design notes
------------
The histogram stores sparse integer counts per geometric bucket.  With
growth factor ``g`` the bucket covering value ``v`` spans
``[lo * g**(i-1), lo * g**i)``; ``quantile`` returns the *geometric
midpoint* of the selected bucket, clipped to the observed ``[min, max]``
range.  The returned value is therefore within a relative factor of
``sqrt(g)`` of some observed order statistic at the requested rank —
the documented relative error bound is ``sqrt(g) - 1`` (about 0.1% at
the default ``growth=1.002``).

Quantiles of an *empty* histogram return ``float("nan")`` — the
``NaN``-safe, schema-stable convention the zero-admitted report path
relies on (no silently fabricated ``0.0`` latencies).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_GROWTH",
    "DEFAULT_LO",
]

# Default geometric growth per bucket.  error bound = sqrt(g) - 1 ~= 0.1%,
# fine enough that bucketed p50/p99 agree with np.percentile on every
# workload the benchmarks run (and too fine to collapse A/B deltas).
DEFAULT_GROWTH = 1.002
# Values at or below ``lo`` share bucket 0 (reported as the observed min).
DEFAULT_LO = 1e-3


@dataclass
class Counter:
    """Monotone event counter."""

    name: str
    value: float = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        self.value += n

    def snapshot(self) -> Dict[str, float]:
        return {"value": float(self.value)}


@dataclass
class Gauge:
    """Last-write-wins instantaneous value."""

    name: str
    value: float = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, float]:
        return {"value": float(self.value)}


class Histogram:
    """Sparse log-bucketed histogram with bounded-error quantiles.

    Non-negative samples only (it is a log histogram); the serving stack
    feeds it latencies and durations in microseconds.
    """

    def __init__(self, name: str = "", growth: float = DEFAULT_GROWTH,
                 lo: float = DEFAULT_LO) -> None:
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if lo <= 0.0:
            raise ValueError(f"lo must be > 0, got {lo}")
        self.name = name
        self.growth = float(growth)
        self.lo = float(lo)
        self._log_g = math.log(self.growth)
        self._counts: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf

    # -- recording ---------------------------------------------------------

    @property
    def error_bound(self) -> float:
        """Documented relative quantile error: ``sqrt(growth) - 1``."""
        return math.sqrt(self.growth) - 1.0

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        return 1 + int(math.floor(math.log(v / self.lo) / self._log_g))

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            raise ValueError(f"histogram {self.name!r} got NaN sample")
        if v < 0.0:
            raise ValueError(
                f"histogram {self.name!r} is log-bucketed; got {v} < 0")
        b = self._bucket(v)
        self._counts[b] = self._counts.get(b, 0) + 1
        self.count += 1
        self.total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def observe_many(self, values: Union[np.ndarray, Iterable[float]]) -> None:
        arr = np.asarray(list(values) if not isinstance(values, np.ndarray)
                         else values, dtype=np.float64).ravel()
        if arr.size == 0:
            return
        if np.isnan(arr).any():
            raise ValueError(f"histogram {self.name!r} got NaN sample")
        if (arr < 0.0).any():
            raise ValueError(
                f"histogram {self.name!r} is log-bucketed; got negatives")
        idx = np.where(
            arr <= self.lo, 0,
            1 + np.floor(np.log(np.maximum(arr, self.lo) / self.lo)
                         / self._log_g).astype(np.int64))
        buckets, counts = np.unique(idx, return_counts=True)
        for b, c in zip(buckets.tolist(), counts.tolist()):
            self._counts[int(b)] = self._counts.get(int(b), 0) + int(c)
        self.count += int(arr.size)
        self.total += float(arr.sum())
        self._min = min(self._min, float(arr.min()))
        self._max = max(self._max, float(arr.max()))

    @classmethod
    def from_values(cls, values: Union[np.ndarray, Iterable[float]],
                    name: str = "", growth: float = DEFAULT_GROWTH,
                    lo: float = DEFAULT_LO) -> "Histogram":
        h = cls(name=name, growth=growth, lo=lo)
        h.observe_many(values)
        return h

    def merge(self, other: "Histogram") -> None:
        if (other.growth, other.lo) != (self.growth, self.lo):
            raise ValueError("cannot merge histograms with different buckets")
        for b, c in other._counts.items():
            self._counts[b] = self._counts.get(b, 0) + c
        self.count += other.count
        self.total += other.total
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)

    # -- reading -----------------------------------------------------------

    @property
    def mean(self) -> float:
        if self.count == 0:
            return float("nan")
        return self.total / self.count

    @property
    def min(self) -> float:
        return self._min if self.count else float("nan")

    @property
    def max(self) -> float:
        return self._max if self.count else float("nan")

    def quantile(self, q: float, default: float = float("nan")) -> float:
        """Value at quantile ``q`` in [0, 1]; ``default`` when empty.

        The result is the geometric midpoint of the bucket holding the
        order statistic at rank ``q * (count - 1)``, clipped to the
        observed range — within ``error_bound`` (relative) of an actual
        sample at that rank.  The empty case is explicit (``default``,
        NaN unless overridden) where ``np.percentile`` would raise: the
        zero-admitted report path leans on this.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return default
        rank = q * (self.count - 1)
        cum = 0
        chosen = None
        for b in sorted(self._counts):
            cum += self._counts[b]
            if cum - 1 >= rank:
                chosen = b
                break
        if chosen is None:        # numerically unreachable; defend anyway
            chosen = max(self._counts)
        if chosen == 0:
            v = self._min
        else:
            edge_lo = self.lo * self.growth ** (chosen - 1)
            v = edge_lo * math.sqrt(self.growth)
        return float(min(max(v, self._min), self._max))

    def percentile(self, p: float, default: float = float("nan")) -> float:
        """np.percentile-style entry point (``p`` in [0, 100])."""
        return self.quantile(p / 100.0, default=default)

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "total": float(self.total),
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p99": self.quantile(0.99),
            "max": self.max,
        }


@dataclass
class MetricsRegistry:
    """Name-keyed get-or-create store for Counters, Gauges, Histograms."""

    _metrics: Dict[str, Union[Counter, Gauge, Histogram]] = field(
        default_factory=dict)

    def _get(self, name: str, kind: type,
             factory) -> Union[Counter, Gauge, Histogram]:
        m = self._metrics.get(name)
        if m is None:
            m = factory()
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        m = self._get(name, Counter, lambda: Counter(name))
        assert isinstance(m, Counter)
        return m

    def gauge(self, name: str) -> Gauge:
        m = self._get(name, Gauge, lambda: Gauge(name))
        assert isinstance(m, Gauge)
        return m

    def histogram(self, name: str, growth: float = DEFAULT_GROWTH,
                  lo: float = DEFAULT_LO) -> Histogram:
        m = self._get(name, Histogram,
                      lambda: Histogram(name, growth=growth, lo=lo))
        assert isinstance(m, Histogram)
        return m

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        return {name: self._metrics[name].snapshot()
                for name in self.names()}
