"""Span-based tracer for the virtual-time serving loops.

Every timestamp recorded here is *virtual-time microseconds* from the
serving clocks (arrival process, device windows, background clocks) —
never host wall clock.  The tracer is a plain append-only list of
``Span`` records; exporting to Chrome trace-event JSON is a separate,
offline step (``repro.obs.export``).

Zero-cost disabled path: serving code holds ``tracer=None`` (or a
``Tracer(enabled=False)``) and guards every emission with a single
truthiness check — no span objects, no list appends, no arithmetic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "TraceSummary", "PHASE_CATS"]

# Per-query latency phases; their durations obey the conservation
# contract  queue_us + interference_us + service_us == latency_us.
PHASE_CATS = ("queue", "interference", "service")


@dataclass
class Span:
    """One timed (or instantaneous) event on a (pid, track) lane.

    ``pid`` is the replica group (0 for a single server / control
    plane); ``track`` names the lane within the group ("executor",
    "shard<N>", "background", "migration", "admission", "query").
    ``qid`` ties per-query spans and flow events together.
    """

    name: str
    cat: str
    t0_us: float
    dur_us: float = 0.0
    pid: int = 0
    track: str = "executor"
    qid: Optional[int] = None
    args: Optional[Dict[str, Any]] = None
    ph: str = "X"


@dataclass
class TraceSummary:
    """Compact in-memory rollup of a trace."""

    spans: int
    queries: int
    batches: int
    by_cat: Dict[str, float]      # cat   -> total duration (us)
    by_track: Dict[str, float]    # "pid/track" -> busy duration (us)
    max_residual_us: float        # worst per-query conservation residual


class Tracer:
    """Append-only span collector threaded through the serving loops."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = bool(enabled)
        self.spans: List[Span] = []

    def __bool__(self) -> bool:
        return self.enabled

    def __len__(self) -> int:
        return len(self.spans)

    # -- recording ---------------------------------------------------------

    def span(self, name: str, cat: str, t0_us: float, dur_us: float, *,
             pid: int = 0, track: str = "executor",
             qid: Optional[int] = None,
             args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(name=name, cat=cat, t0_us=float(t0_us),
                               dur_us=float(dur_us), pid=pid, track=track,
                               qid=qid, args=args))

    def instant(self, name: str, cat: str, t_us: float, *,
                pid: int = 0, track: str = "admission",
                qid: Optional[int] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(name=name, cat=cat, t0_us=float(t_us),
                               dur_us=0.0, pid=pid, track=track, qid=qid,
                               args=args, ph="i"))

    # -- reading -----------------------------------------------------------

    def summary(self) -> TraceSummary:
        by_cat: Dict[str, float] = {}
        by_track: Dict[str, float] = {}
        qids = set()
        batches = 0
        worst_us = 0.0
        for s in self.spans:
            if s.ph == "i":
                continue
            by_cat[s.cat] = by_cat.get(s.cat, 0.0) + s.dur_us
            lane = f"{s.pid}/{s.track}"
            by_track[lane] = by_track.get(lane, 0.0) + s.dur_us
            if s.cat == "batch":
                batches += 1
            elif s.cat == "service":
                if s.qid is not None:
                    qids.add(s.qid)
                if s.args and "latency_us" in s.args:
                    parts_us = (s.args.get("queue_us", 0.0)
                                + s.args.get("interference_us", 0.0)
                                + s.args.get("service_us", 0.0))
                    resid_us = abs(parts_us - s.args["latency_us"])
                    if resid_us > worst_us:
                        worst_us = resid_us
        return TraceSummary(spans=len(self.spans), queries=len(qids),
                            batches=batches, by_cat=by_cat,
                            by_track=by_track, max_residual_us=worst_us)

    def to_chrome(self) -> Dict[str, Any]:
        from repro.obs.export import to_chrome_trace
        return to_chrome_trace(self.spans)

    def export(self, path: str) -> Dict[str, Any]:
        import json
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc
