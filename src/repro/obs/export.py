"""Chrome trace-event JSON exporter (Perfetto-loadable).

Mapping (see docs/observability.md for the full schema):

- ``pid``  = replica group (0 for a single server / the control plane)
- ``tid``  = lane within the group: admission, executor, background,
  migration, the per-query async lane, and one lane per shard
- batch / device / background / migration spans -> complete ``"X"``
  events with ``ts``/``dur`` in virtual microseconds
- per-query latency phases (queue / interference / service) -> nestable
  async ``"b"``/``"e"`` pairs keyed by the query id, so overlapping
  queries each get their own row in the UI
- per-hop device markers -> nestable async instants (``"n"``) under the
  same query id, with per-shard page counts in ``args``
- one flow per query (``"s"`` at arrival, ``"t"`` at dispatch, ``"f"``
  at completion) visually links admission -> executor batch -> done
- ``"M"`` metadata names every process and thread

The ``service`` phase's ``args`` carry the attribution tuple
(``latency_us``/``queue_us``/``interference_us``/``service_us``) so a
trace file is self-validating (``repro.obs.validate``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

from repro.obs.tracer import PHASE_CATS, Span

__all__ = ["to_chrome_trace", "tid_for_track"]

_TRACK_TIDS = {
    "admission": 0,
    "executor": 1,
    "query": 2,
    "background": 3,
    "migration": 4,
}
_SHARD_TID_BASE = 10
_FALLBACK_TID = 9


def tid_for_track(track: str) -> int:
    if track.startswith("shard"):
        suffix = track[len("shard"):]
        return _SHARD_TID_BASE + (int(suffix) if suffix.isdigit() else 0)
    return _TRACK_TIDS.get(track, _FALLBACK_TID)


def _meta(pid: int, name: str, tid: int = 0, *,
          kind: str = "process_name") -> Dict[str, Any]:
    return {"ph": "M", "pid": pid, "tid": tid, "name": kind,
            "args": {"name": name}}


def to_chrome_trace(spans: Sequence[Span]) -> Dict[str, Any]:
    events: List[Dict[str, Any]] = []
    lanes: Dict[Tuple[int, str], int] = {}
    for s in spans:
        lanes.setdefault((s.pid, s.track), tid_for_track(s.track))

    for pid in sorted({p for p, _ in lanes}):
        events.append(_meta(pid, f"replica_group_{pid}"))
    for (pid, track), tid in sorted(lanes.items()):
        events.append(_meta(pid, track, tid, kind="thread_name"))
        events.append({"ph": "M", "pid": pid, "tid": tid,
                       "name": "thread_sort_index",
                       "args": {"sort_index": tid}})

    body: List[Dict[str, Any]] = []
    service: Dict[int, Span] = {}
    queue_t0: Dict[int, float] = {}
    for s in spans:
        tid = lanes[(s.pid, s.track)]
        base = {"name": s.name, "cat": s.cat, "pid": s.pid, "tid": tid,
                "ts": s.t0_us}
        if s.args:
            base["args"] = dict(s.args)
        if s.qid is not None:
            base.setdefault("args", {})["qid"] = s.qid
        if s.cat in PHASE_CATS:
            qid = str(s.qid)
            body.append({**base, "ph": "b", "id": qid})
            end = dict(base)
            end.pop("args", None)
            body.append({**end, "ph": "e", "id": qid,
                         "ts": s.t0_us + s.dur_us})
            if s.cat == "service" and s.qid is not None:
                service[s.qid] = s
            elif s.cat == "queue" and s.qid is not None:
                queue_t0[s.qid] = s.t0_us
        elif s.cat == "hop":
            body.append({**base, "ph": "n", "id": str(s.qid)})
        elif s.ph == "i":
            body.append({**base, "ph": "i", "s": "t"})
        else:
            body.append({**base, "ph": "X", "dur": s.dur_us})

    # one flow per completed query: arrival -> dispatch -> completion
    exec_lane = dict(lanes)
    for qid, s in sorted(service.items()):
        tid_exec = exec_lane.get((s.pid, "executor"),
                                 tid_for_track("executor"))
        tid_adm = exec_lane.get((s.pid, "admission"),
                                tid_for_track("admission"))
        flow = {"cat": "qflow", "id": str(qid), "name": f"q{qid}",
                "pid": s.pid}
        body.append({**flow, "ph": "s", "tid": tid_adm,
                     "ts": queue_t0.get(qid, s.t0_us)})
        body.append({**flow, "ph": "t", "tid": tid_exec, "ts": s.t0_us})
        body.append({**flow, "ph": "f", "bp": "e", "tid": tid_exec,
                     "ts": s.t0_us + s.dur_us})

    body.sort(key=lambda e: e["ts"])
    return {"traceEvents": events + body, "displayTimeUnit": "ms",
            "otherData": {"clock": "virtual_us",
                          "source": "repro.obs"}}
