"""Observability layer: span tracing, metrics, Chrome trace export.

See docs/observability.md for the span taxonomy, the trace-event
schema, and the histogram error-bound derivation.
"""
from repro.obs.export import to_chrome_trace
from repro.obs.metrics import (DEFAULT_GROWTH, DEFAULT_LO, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.tracer import PHASE_CATS, Span, Tracer, TraceSummary
from repro.obs.validate import CONSERVATION_TOL_US, validate_chrome_trace

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_GROWTH",
    "DEFAULT_LO",
    "Span",
    "Tracer",
    "TraceSummary",
    "PHASE_CATS",
    "to_chrome_trace",
    "validate_chrome_trace",
    "CONSERVATION_TOL_US",
]
