"""Structural + semantic validation of exported Chrome trace JSON.

Checks the three properties the CI bench-smoke job gates on:

1. events are well-formed (known phase, numeric non-negative ``ts``,
   ``dur`` on complete events, ids on async/flow events);
2. flows resolve (every flow id has a start, steps/finish never move
   backwards in time, and every finish has a start);
3. conservation holds (each ``service`` phase's args satisfy
   ``queue_us + interference_us + service_us == latency_us`` within
   ``CONSERVATION_TOL_US``).

Usable as a library (``validate_chrome_trace(doc) -> [problems]``) or a
CLI: ``python -m repro.obs.validate trace.json``.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

__all__ = ["validate_chrome_trace", "CONSERVATION_TOL_US"]

# "within rounding": the loop computes the split exactly in float64, so a
# nanosecond of absolute slack is generous.
CONSERVATION_TOL_US = 1e-3

_KNOWN_PHASES = {"X", "i", "b", "e", "n", "s", "t", "f", "M"}
_ATTRIB_KEYS = ("latency_us", "queue_us", "interference_us", "service_us")


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_chrome_trace(doc: Any) -> List[str]:
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["document is not an object with a 'traceEvents' list"]

    async_open: Dict[tuple, int] = {}
    flows: Dict[str, Dict[str, Any]] = {}
    n_service = 0
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _KNOWN_PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph == "M":
            continue
        if not _is_num(ev.get("ts")) or ev["ts"] < 0:
            problems.append(f"{where}: ph={ph} missing numeric ts >= 0")
            continue
        if "pid" not in ev or "tid" not in ev:
            problems.append(f"{where}: ph={ph} missing pid/tid")
        if ph == "X":
            if not _is_num(ev.get("dur")) or ev["dur"] < 0:
                problems.append(f"{where}: X event missing dur >= 0")
        elif ph in ("b", "e", "n"):
            if "id" not in ev:
                problems.append(f"{where}: async {ph} event missing id")
                continue
            key = (ev.get("cat"), str(ev["id"]), ev.get("name"))
            if ph == "b":
                async_open[key] = async_open.get(key, 0) + 1
            elif ph == "e":
                if async_open.get(key, 0) <= 0:
                    problems.append(
                        f"{where}: async end with no open begin {key}")
                else:
                    async_open[key] -= 1
            if (ph == "b" and ev.get("cat") == "service"):
                n_service += 1
                args = ev.get("args", {})
                missing = [k for k in _ATTRIB_KEYS
                           if not _is_num(args.get(k))]
                if missing:
                    problems.append(
                        f"{where}: service span missing args {missing}")
                else:
                    resid = abs(args["queue_us"] + args["interference_us"]
                                + args["service_us"] - args["latency_us"])
                    if resid > CONSERVATION_TOL_US:
                        problems.append(
                            f"{where}: conservation violated for qid="
                            f"{args.get('qid')}: residual {resid:.6f}us")
        elif ph in ("s", "t", "f"):
            fid = ev.get("id")
            if fid is None:
                problems.append(f"{where}: flow event missing id")
                continue
            st = flows.setdefault(str(fid), {"s": None, "last": None,
                                             "f": False})
            if ph == "s":
                if st["s"] is not None:
                    problems.append(f"{where}: duplicate flow start {fid}")
                st["s"] = ev["ts"]
                st["last"] = ev["ts"]
            else:
                if st["s"] is None:
                    problems.append(
                        f"{where}: flow {ph} before start for id {fid}")
                elif ev["ts"] < st["last"]:
                    problems.append(
                        f"{where}: flow {fid} moves backwards in time")
                else:
                    st["last"] = ev["ts"]
                if ph == "f":
                    st["f"] = True

    for key, n in async_open.items():
        if n != 0:
            problems.append(f"async begin without end: {key} (x{n})")
    for fid, st in flows.items():
        if st["s"] is None or not st["f"]:
            problems.append(f"flow {fid} does not resolve (s..f)")
    if n_service == 0:
        problems.append("trace has no service spans (nothing to attribute)")
    return problems


def main(argv: List[str]) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate <trace.json>",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    problems = validate_chrome_trace(doc)
    if problems:
        for p in problems[:40]:
            print(f"TRACE-INVALID: {p}")
        if len(problems) > 40:
            print(f"... and {len(problems) - 40} more")
        return 1
    n = len(doc["traceEvents"])
    print(f"trace OK: {n} events, flows resolve, conservation holds")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
