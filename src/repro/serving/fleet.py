"""Fleet layer: replica groups over the sharded store — load-aware
routing, per-replica admission budgets, online hot-page migration, and
hysteresis autoscaling.

One `AnnServer` serves from ONE copy of the shard set; past its saturation
point the only remaining axis is more COPIES. `FleetServer` runs N replica
groups, each a full `build_store` stack over the same index (its own
per-shard caches, counters and device clocks — replicas share bytes, never
state), and routes every dispatched batch to one group:

  least-work routing   the batch goes to the group whose devices free up
                       earliest (min over groups of max(exec_free,
                       bg_free)) — least-outstanding-work, the load signal
                       the per-replica `_ShardWindow` busy clocks carry.
  round-robin          the degenerate baseline (blind rotation).

Groups serve concurrently in virtual time, so saturation goodput scales
with the group count; the device model prices each batch on the fleet's
(B, R, S) grid (`SSDModel.concurrent_latency_us` 3-D path), so completion
is the max over REPLICAS THEN SHARDS and an imbalanced fleet stays visibly
slower than a balanced one.

Per-replica admission budgets (`FleetConfig.replica_budget_qps`): the fleet
admits at most budget x routable-groups QPS through a token bucket whose
rate tracks the live group count — adding a group buys admission capacity,
draining one takes it away. Budget sheds land in the report's `shed`
column next to the AdmissionController's own.

Online hot-page migration (`MigrationConfig`): every `every_us` of virtual
time a background rebalancer diffs each group's live per-page read
counters against the last window (`profile_from_counters` deltas), ranks
the window's hottest pages, and swaps the replicated hot set in place
(`ShardedPageStore.set_replicated`). Promotions are real I/O: each
promoted page is read once from its home shard and written to the other
S-1 shards. Unlike flush/compaction — which rewrite pages the very next
query needs and therefore block dispatch — migration copies run THROTTLED
on spare device bandwidth: they land on the group's dedicated migration
clock (`_Replica.mig_free`), which gates only the NEXT rebalance (one copy
wave in flight at a time) and the run's end time, and they bill device
busy time (utilization, shard windows) without stalling foreground
dispatch. A promoted page's HOME copy never moves, so its cached bytes
stay valid; a DEMOTED page's replica copies cease to exist, so its stale
residency is dropped through `MutablePageStore.invalidate` (the
store-version half of the streaming-update subsystem, reused here) —
otherwise demotions are metadata-only. This is the replicated placement's
cold-start story at fleet scale: start from ANY base placement and let the
serving window itself discover the hot set.

Autoscaling (`AutoscaleConfig`): every `check_every_us` the fleet's window
utilization (busy device time over elapsed, averaged over routable groups)
is compared against a hysteresis band — above `util_high` one group is
added (up to `max_groups`), below `util_low` the least-loaded group starts
DRAINING: it receives no new batches, finishes what it holds, and only
then counts as dropped (drain-before-drop; never below `min_groups`). The
decision timeline is recorded for the traffic-replay acceptance check.

Mutations compose: the fleet attaches every group's store to the shared
`MutableIndex`, so a flush or compaction invalidates every group's caches,
and its device I/O is billed on EVERY group's background clock (each group
owns a full copy of the pages being rewritten).

`FleetReport` extends `OpenLoopReport` — the same schema-stable row
columns (per-tenant, per-shard, measured-step) plus the fleet outcome:
group counts, scale events, migration volume, and per-group r<N>_*
columns. `per_shard` is keyed by (group, shard) cell, so the flattened
`shards`/`shard_imbalance` columns measure imbalance across the WHOLE
fleet's devices.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro import sanitize
from repro.core.stats import QueryStats
from repro.io import profile_from_counters
from repro.mutation import Compactor, MutationMix
from repro.obs import Tracer
from repro.serving.admission import AdmissionController
from repro.serving.ann_server import (AnnServer, OpenLoopReport,
                                      _latency_summary, _measured_step)

#: FleetConfig.routing policy names.
ROUTING_POLICIES = ("least-work", "round-robin")


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """Online hot-page migration knobs (None on FleetConfig = off)."""

    every_us: float = 10_000.0   # profile window / rebalance period
    hot_frac: float = 0.25       # page-space fraction eligible for the
    #                              replicated hot set
    max_moves: int = 64          # promotion cap per run (demotions follow
    #                              the ranking and are metadata-only)
    min_reads: int = 2           # window reads a page needs to be ranked
    #                              hot (one read is noise, not heat)

    def __post_init__(self):
        if self.every_us <= 0:
            raise ValueError(f"every_us={self.every_us} must be positive")
        if not 0.0 < self.hot_frac <= 1.0:
            raise ValueError(
                f"hot_frac={self.hot_frac} must be in (0, 1]")
        if self.max_moves < 1:
            raise ValueError(f"max_moves={self.max_moves} must be >= 1")
        if self.min_reads < 1:
            raise ValueError(f"min_reads={self.min_reads} must be >= 1")


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Hysteresis autoscaling knobs (None on FleetConfig = off). `util`
    is mean group OCCUPANCY over the check window: executor service time
    plus background device time, over elapsed — ~1.0 means the routable
    groups are serving back to back."""

    check_every_us: float = 10_000.0  # occupancy sampling period
    util_high: float = 0.75      # add a group above this...
    util_low: float = 0.30       # ...drain one below this
    min_groups: int = 1
    max_groups: int = 8

    def __post_init__(self):
        if self.check_every_us <= 0:
            raise ValueError(
                f"check_every_us={self.check_every_us} must be positive")
        if not 0.0 <= self.util_low < self.util_high:
            raise ValueError(
                f"hysteresis band needs 0 <= util_low < util_high; got "
                f"[{self.util_low}, {self.util_high}]")
        if self.min_groups < 1:
            raise ValueError(
                f"min_groups={self.min_groups} must be >= 1")
        if self.max_groups < self.min_groups:
            raise ValueError(
                f"max_groups={self.max_groups} < min_groups="
                f"{self.min_groups}")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Replica-group layer config (ServerConfig still describes ONE
    group's store: shards, placement, caches, tenants, prefetch)."""

    replica_groups: int = 2      # groups at start (autoscale moves it
    #                              inside [min_groups, max_groups])
    routing: str = "least-work"  # ROUTING_POLICIES
    replica_budget_qps: float = 0.0   # admission budget PER GROUP (0 =
    #                              unbudgeted); fleet admission rate =
    #                              budget x routable groups
    migration: Optional[MigrationConfig] = None
    autoscale: Optional[AutoscaleConfig] = None

    def __post_init__(self):
        if self.replica_groups < 1:
            raise ValueError(
                f"replica_groups={self.replica_groups} must be >= 1")
        if self.routing not in ROUTING_POLICIES:
            raise ValueError(
                f"routing={self.routing!r} must be one of "
                f"{ROUTING_POLICIES}")
        if self.replica_budget_qps < 0:
            raise ValueError(
                f"replica_budget_qps={self.replica_budget_qps} must be "
                f">= 0 (0 = no budget)")
        if self.migration is not None \
                and not isinstance(self.migration, MigrationConfig):
            raise ValueError(
                f"migration={self.migration!r} must be a MigrationConfig "
                f"(or None for a static placement)")
        if self.autoscale is not None \
                and not isinstance(self.autoscale, AutoscaleConfig):
            raise ValueError(
                f"autoscale={self.autoscale!r} must be an AutoscaleConfig "
                f"(or None for a fixed fleet)")
        if self.autoscale is not None \
                and self.replica_groups > self.autoscale.max_groups:
            raise ValueError(
                f"replica_groups={self.replica_groups} starts above "
                f"autoscale.max_groups={self.autoscale.max_groups}")


class _Replica:
    """One replica group: a full store stack plus its own device clocks
    and window accounting. `exec_free` is when its executor next frees
    up; `bg_free` is its background device clock (flush / compaction /
    migration I/O); `busy_us` accumulates OCCUPANCY — executor service
    time plus background device time — the signal autoscaling reads. (Not
    raw issued-read units: a fully cache-resident group can be saturated
    on compute/issue overhead while its device sits idle, and the scaler
    must still see that. Per-DEVICE busy fractions live on the shard
    window.)"""

    def __init__(self, rid: int, store, window, born_us: float = 0.0):
        self.rid = rid
        self.store = store
        self.window = window
        self.exec_free = born_us
        self.bg_free = born_us
        self.mig_free = born_us     # throttled migration-copy clock: gates
        #                             the next rebalance, never dispatch
        self.busy_us = 0.0
        self.busy_mark = 0.0        # busy_us at the last autoscale check
        self.active = True
        self.draining = False
        self.batches = 0
        self.completed = 0
        self.requested = 0
        self.issued = 0
        self.hits = 0
        self.mig_base: Optional[np.ndarray] = None

    @property
    def routable(self) -> bool:
        return self.active and not self.draining

    def free_at(self) -> float:
        # mig_free is deliberately absent: throttled background copies
        # never block a dispatch (see the module docstring)
        return max(self.exec_free, self.bg_free)

    def row(self, elapsed_us: float) -> dict:
        return {
            "batches": self.batches, "completed": self.completed,
            "issued": self.issued,
            "hit_rate": (round(self.hits / self.requested, 4)
                         if self.requested else 0.0),
            "utilization": (round(self.busy_us / elapsed_us, 4)
                            if elapsed_us > 0 else 0.0),
            "state": ("active" if self.routable else
                      "draining" if self.active else "dropped")}


@dataclasses.dataclass
class FleetReport(OpenLoopReport):
    """OpenLoopReport plus the fleet outcome. `per_shard` is keyed by
    "r<g>.s<s>" cells, so the inherited shard columns aggregate across
    every device in the fleet."""

    groups: int = 0              # groups configured at start
    groups_final: int = 0        # routable groups at the end of the run
    groups_added: int = 0        # autoscale activations
    groups_dropped: int = 0      # drained-and-dropped groups
    migrations: int = 0          # rebalancer runs that moved pages
    promoted_pages: int = 0      # pages gaining replication (summed over
    #                              groups — each group copies its own)
    demoted_pages: int = 0
    mig_pages_read: int = 0      # migration copy I/O (read home copy...)
    mig_pages_written: int = 0   # ...write S-1 replicas
    mig_io_us: float = 0.0       # background device time it consumed
    shed_budget: int = 0         # arrivals shed by the per-replica
    #                              admission budget (within `shed`)
    per_replica: Optional[dict] = None  # {rid: _Replica.row()}
    timeline: Optional[list] = None     # autoscale samples: (t_us,
    #                              routable_groups, window_util, event)

    def row(self) -> dict:
        row = super().row()
        row.update({
            "groups": self.groups,
            "groups_final": self.groups_final,
            "groups_added": self.groups_added,
            "groups_dropped": self.groups_dropped,
            "migrations": self.migrations,
            "promoted_pages": self.promoted_pages,
            "mig_pages_written": self.mig_pages_written,
            "shed_budget": self.shed_budget,
        })
        if self.per_replica:
            for rid, r in sorted(self.per_replica.items()):
                row[f"r{rid}_completed"] = r["completed"]
                row[f"r{rid}_util"] = r["utilization"]
        return row


class FleetServer(AnnServer):
    """N replica groups over one index. The inherited `self.store` is the
    KERNEL-side store (search arrays only — every group shares the same
    bytes); each group's I/O replays against its OWN store stack, so cache
    state, counters and device clocks never leak between groups."""

    def __init__(self, index, cfg=None, model=None, server_cfg=None,
                 fleet_cfg: Optional[FleetConfig] = None,
                 page_profile: Optional[np.ndarray] = None):
        super().__init__(index, cfg, model, server_cfg,
                         page_profile=page_profile)
        self.fleet_cfg = fleet_cfg or FleetConfig()
        self._page_profile = page_profile
        # the placement AnnServer actually built (it may have fallen back
        # from "replicated" to "round-robin" when no profile was given —
        # with migration on, that IS the cold start the rebalancer fixes)
        self._eff_placement = (self.store.placement.name if self._sharded
                              else "round-robin")
        self._use_vertex_cache = (self.cfg.cache_frac > 0
                                  and index.cached.any())
        self._mig_mask: Optional[np.ndarray] = None
        self.replicas: List[_Replica] = []
        self._rr_next = 0           # round-robin routing cursor
        for _ in range(self.fleet_cfg.replica_groups):
            self._activate_group(0.0)

    # -- group lifecycle -----------------------------------------------------

    def _activate_group(self, now_us: float) -> _Replica:
        """Build one replica group's store stack and put it in rotation.
        The store is mutable-wrapped whenever the index mutates OR
        migration is on (migration invalidates through MutablePageStore).
        A group added mid-run starts at the current hot-set mask — its
        image is provisioned with the replicas in place, so only FUTURE
        migrations bill copy I/O to it."""
        from repro.io import build_store
        scfg = self.server_cfg
        store = build_store(
            self.index.layout,
            cached_vertices=(self.index.cached
                             if self._use_vertex_cache else None),
            batched=True,
            cache_policy=scfg.cache_policy if self._stateful else "none",
            cache_bytes=scfg.cache_bytes,
            prefetch=scfg.prefetch,
            tenants=scfg.tenants if self._stateful else 1,
            tenant_shares=scfg.tenant_shares,
            rebalance_every=scfg.cache_rebalance_every,
            shards=scfg.shards,
            placement=self._eff_placement if self._sharded
            else "round-robin",
            page_profile=self._page_profile,
            placement_hot_frac=scfg.placement_hot_frac,
            mutable=self._mutable or self.fleet_cfg.migration is not None)
        if self._mutable:
            self.index.attach_store(store)
        if self._sharded and self._mig_mask is not None:
            store.set_replicated(self._mig_mask)
        r = _Replica(len(self.replicas), store,
                     self._shard_window(store), born_us=now_us)
        self.replicas.append(r)
        return r

    def _routable(self) -> List[_Replica]:
        return [r for r in self.replicas if r.routable]

    def _route(self, routable: List[_Replica]) -> _Replica:
        """Pick the serving group: least outstanding work (the group whose
        devices free up earliest), or blind rotation."""
        if self.fleet_cfg.routing == "round-robin":
            r = routable[self._rr_next % len(routable)]
            self._rr_next += 1
            return r
        return min(routable, key=lambda r: (r.free_at(), r.rid))

    # -- the fleet open loop -------------------------------------------------

    def serve_fleet(self, queries: np.ndarray, rate_qps: float,
                    duration_us: float, seed: int = 0,
                    tenants: Optional[np.ndarray] = None,
                    arrivals: Optional[np.ndarray] = None,
                    mutation_mix: Optional[MutationMix] = None,
                    insert_pool: Optional[np.ndarray] = None,
                    rng: Optional[np.random.Generator] = None,
                    tracer: Optional[Tracer] = None) -> FleetReport:
        """The open-loop contract of `AnnServer.serve_open_loop` (same
        arrival/admission/batcher semantics, one seeded rng end to end)
        run against the replica groups: every dispatched batch routes to
        one group, groups serve concurrently in virtual time, and the
        migration / autoscale hooks run on the virtual clock between
        dispatches. Returns a `FleetReport`.

        Latency attribution follows the single-server contract — every
        completed query satisfies ``queue_us + service_us +
        interference_us == latency_us`` — with the fleet's queue phase
        defined against the *background-free counterfactual*: queue is
        the wait until the fleet would have dispatched with every
        group's background/migration clock idle, and interference is
        the extra wait the bg/migration work actually caused on the
        routed group.

        Pass a `repro.obs.Tracer` to record spans (pid = replica group
        id; admission instants land on pid 0's admission track, device
        and query spans on the routed group's tracks, background and
        migration spans on each billed group's own tracks)."""
        if rate_qps <= 0:
            raise ValueError(f"rate_qps={rate_qps} must be positive")
        if duration_us <= 0:
            raise ValueError(
                f"duration_us={duration_us} must be positive")
        fcfg = self.fleet_cfg
        mm = mutation_mix if (mutation_mix is not None
                              and mutation_mix.mutating) else None
        if mm is not None:
            if not self._mutable:
                raise ValueError(
                    "mutation_mix with insert/delete arrivals needs a "
                    "FleetServer over a MutableIndex")
            if mm.insert_frac > 0 and (insert_pool is None
                                       or len(insert_pool) == 0):
                raise ValueError(
                    "insert_frac > 0 needs a non-empty insert_pool")
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        tenant_of = self._tenant_map(queries, tenants)
        multi_tenant = tenants is not None or scfg.tenants > 1

        gen = rng if rng is not None else np.random.default_rng(seed)
        run_seed = None if rng is not None else int(seed)
        if arrivals is None:
            mean_gap = 1e6 / rate_qps
            times: List[float] = []
            t = float(gen.exponential(mean_gap))
            while t < duration_us:
                times.append(t)
                t += float(gen.exponential(mean_gap))
            arr = np.asarray(times)
        else:
            arr = np.asarray(arrivals, np.float64).reshape(-1)
            if len(arr) and (np.any(arr < 0) or np.any(np.diff(arr) < 0)):
                raise ValueError(
                    "explicit arrivals must be non-negative and sorted")
        n = len(arr)
        ac = AdmissionController(scfg.admission)
        if mm is not None:
            kinds = gen.choice(
                3, size=n,
                p=[mm.read_frac, mm.insert_frac, mm.delete_frac])
        else:
            kinds = np.zeros(n, np.int64)
        reads = kinds == 0
        n_reads = int(reads.sum())
        qidx = (np.where(reads, np.cumsum(reads) - 1, 0)) % len(queries)
        arr_tenant = tenant_of[qidx]

        rd_us = self.model.read_service_us(self.cfg.page_bytes)
        wr_us = self.model.write_service_us(self.cfg.page_bytes)
        compactor = Compactor(self.index, mm) if mm is not None else None
        mu = {"inserts": 0, "deletes": 0, "flushes": 0, "compactions": 0,
              "reads": 0, "writes": 0, "io_us": 0.0, "ins_i": 0}
        mig = {"runs": 0, "promoted": 0, "demoted": 0, "reads": 0,
               "writes": 0, "io_us": 0.0,
               "next": (fcfg.migration.every_us
                        if fcfg.migration is not None else np.inf)}
        asc = fcfg.autoscale
        scale = {"added": 0, "dropped": 0, "last_t": 0.0,
                 "next": asc.check_every_us if asc is not None else np.inf}
        timeline: List[tuple] = []
        # per-replica admission budget: one bucket whose rate tracks the
        # ROUTABLE group count (10 ms of burst at the current rate)
        budget_on = fcfg.replica_budget_qps > 0
        bud = {"tokens": 0.0, "t": 0.0, "shed": 0}
        if budget_on:
            bud["tokens"] = max(
                1.0, fcfg.replica_budget_qps * len(self._routable()) * 0.01)

        def budget_rate() -> float:
            return fcfg.replica_budget_qps * max(1, len(self._routable()))

        def budget_take(t: float) -> bool:
            """Refill to `t` at the live fleet rate, then take one token;
            False = shed by budget (the arrival never reaches the
            AdmissionController)."""
            if not budget_on:
                return True
            rate = budget_rate()
            burst = max(1.0, rate * 0.01)
            bud["tokens"] = min(
                burst, bud["tokens"] + (t - bud["t"]) * rate / 1e6)
            bud["t"] = t
            if bud["tokens"] >= 1.0:
                bud["tokens"] -= 1.0
                return True
            bud["shed"] += 1
            return False

        def bg_run(acct, t: float, kind: str) -> None:
            """Flush/compaction I/O: every ACTIVE group owns a full copy
            of the rewritten pages, so the same device work lands on each
            group's background clock and shard window."""
            if not acct:
                return
            us = (acct["pages_read"] * rd_us
                  + acct["pages_written"] * wr_us)
            mu[kind] += 1
            mu["reads"] += acct["pages_read"]
            mu["writes"] += acct["pages_written"]
            for r in self.replicas:
                if not r.active:
                    continue
                bg_start = max(r.bg_free, t)
                r.bg_free = bg_start + us
                r.busy_us += us
                mu["io_us"] += us
                r.window.add_background(acct["read_pages"], rd_us)
                r.window.add_background(acct["written_pages"], wr_us)
                if tracer:
                    tracer.span(kind, "bg", bg_start, us, pid=r.rid,
                                track="background",
                                args={"pages_read": int(acct["pages_read"]),
                                      "pages_written":
                                          int(acct["pages_written"])})

        def maybe_migrate(now: float) -> None:
            mcfg = fcfg.migration
            if mcfg is None or now < mig["next"] or not self._sharded:
                return
            if any(r.active and r.mig_free > now for r in self.replicas):
                return      # one copy wave in flight at a time; retry
            mig["next"] = now + mcfg.every_us
            num_pages = self.index.layout.num_pages
            window = np.zeros(num_pages, np.int64)
            for r in self.replicas:
                if not r.active:
                    continue
                counts = profile_from_counters(r.store)[:num_pages]
                base = (r.mig_base if r.mig_base is not None
                        else np.zeros(0, np.int64))
                delta = counts.copy()
                delta[:len(base)] -= base[:len(delta)]
                window[:len(delta)] += np.maximum(delta, 0)
                r.mig_base = counts
            hot_ids = np.flatnonzero(window >= mcfg.min_reads)
            if len(hot_ids) == 0:
                return
            k = max(1, int(round(mcfg.hot_frac * num_pages)))
            order = hot_ids[np.argsort(window[hot_ids],
                                       kind="stable")[::-1]]
            target = np.zeros(num_pages, bool)
            target[order[:k]] = True
            S = scfg.shards
            moved = False
            for r in self.replicas:
                if not r.active:
                    continue
                cur = r.store.placement.replicated
                promote = np.flatnonzero(target & ~cur[:num_pages])
                if len(promote) > mcfg.max_moves:
                    # cap the copy volume per run: hottest first, the rest
                    # keep their current (non-replicated) routing
                    ranked = promote[np.argsort(window[promote],
                                                kind="stable")[::-1]]
                    keep = np.zeros(num_pages, bool)
                    keep[ranked[:mcfg.max_moves]] = True
                    mask = (cur[:num_pages] & target) | keep
                else:
                    mask = target
                delta = r.store.set_replicated(mask)
                promoted, demoted = delta["promoted"], delta["demoted"]
                if len(promoted) == 0 and len(demoted) == 0:
                    continue
                moved = True
                mig["promoted"] += len(promoted)
                mig["demoted"] += len(demoted)
                if len(promoted):
                    # copy I/O: read the home copy once, write S-1 replicas
                    io = len(promoted) * (rd_us + (S - 1) * wr_us)
                    mig["reads"] += len(promoted)
                    mig["writes"] += len(promoted) * (S - 1)
                    mig["io_us"] += io
                    mig_start = max(r.mig_free, now)
                    r.mig_free = mig_start + io
                    r.busy_us += io
                    if tracer:
                        tracer.span("migration", "bg", mig_start, io,
                                    pid=r.rid, track="migration",
                                    args={"promoted": len(promoted),
                                          "demoted": len(demoted)})
                    r.window.add_background(promoted, rd_us)
                    r.window.add_broadcast_writes(promoted, wr_us)
                    # the copy pulled the page's bytes through memory onto
                    # every shard — leave them RESIDENT there (non-demand
                    # admit, the prefetch path's API), so promotion warms
                    # the new shards' caches instead of starting them cold
                    caches = getattr(r.store, "caches", None)
                    if caches is not None:
                        for shard_cache in caches:
                            for p in promoted:
                                shard_cache.admit(int(p))
                # only DEMOTED pages have stale residency (their replica
                # copies cease to exist; a cached entry filled from one
                # points at a dead copy) — dropped through the mutable
                # store's versioned invalidate. A promoted page's home
                # copy never moved: its cached bytes stay valid, and the
                # new replica shards warm up organically.
                if len(demoted):
                    r.store.invalidate(demoted)
            if moved:
                mig["runs"] += 1
            self._mig_mask = target

        def maybe_autoscale(now: float) -> None:
            if asc is None or now < scale["next"]:
                return
            dt = now - scale["last_t"]
            scale["next"] = now + asc.check_every_us
            scale["last_t"] = now
            routable = self._routable()
            if dt <= 0 or not routable:
                return
            util = float(np.mean([
                (r.busy_us - r.busy_mark) / dt for r in routable]))
            for r in self.replicas:
                r.busy_mark = r.busy_us
            event = ""
            if util > asc.util_high and len(routable) < asc.max_groups:
                self._activate_group(now)
                scale["added"] += 1
                event = "add"
            elif util < asc.util_low and len(routable) > asc.min_groups:
                victim = min(routable, key=lambda r: r.free_at())
                victim.draining = True
                event = "drain"
            timeline.append((round(now, 1), len(self._routable()),
                             round(util, 4), event))

        def reap_drained(now: float) -> None:
            for r in self.replicas:
                if r.active and r.draining and r.free_at() <= now:
                    r.active = False       # drained: nothing in flight
                    scale["dropped"] += 1

        def ingest(j: int, executor_idle: bool = False) -> None:
            t = float(arr[j])
            if tracer:
                tracer.instant("arrival", "admission", t, pid=0, qid=j,
                               args={"kind": int(kinds[j])})
            if kinds[j] == 0:
                if budget_take(t):
                    ac.offer(t, j, int(arr_tenant[j]),
                             executor_idle=executor_idle)
                return
            if kinds[j] == 1:
                self.index.insert(
                    insert_pool[mu["ins_i"] % len(insert_pool)])
                mu["ins_i"] += 1
                mu["inserts"] += 1
                bg_run(self.index.maybe_flush(), t, "flushes")
            else:
                vid = self.index.random_live_vid(gen)
                if vid is not None and self.index.delete(vid):
                    mu["deletes"] += 1
            bg_run(compactor.after_mutation(), t, "compactions")

        est_service: Optional[float] = None
        lat_out, stats_out, batch_sizes = [], [], []
        que_out: List[float] = []
        svc_out: List[float] = []
        int_out: List[float] = []
        qidx_out, tenant_out = [], []
        requested_total = issued_total = hits_total = 0
        overlap_w = 0.0
        degraded_n = 0
        t_end = 0.0

        i = 0
        mb = scfg.max_batch
        pend = ac.pending
        while i < n or pend:
            if not pend:
                idle = min(r.free_at() for r in self._routable()) \
                    <= float(arr[i])
                ingest(i, executor_idle=idle)
                i += 1
                continue
            t0 = pend[0][0]
            deadline = t0 + scfg.max_wait_us
            if scfg.slo_p99_us is not None:
                budget = scfg.slo_p99_us - (est_service or 0.0)
                deadline = min(deadline, t0 + max(budget, 0.0))
            while i < n and len(pend) < mb and arr[i] <= deadline:
                ingest(i)
                i += 1
            t_fill = pend[mb - 1][0] if len(pend) >= mb else np.inf
            routable = self._routable()
            earliest = min(r.free_at() for r in routable)
            exec_earliest = min(r.exec_free for r in routable)
            dispatch = max(earliest, min(deadline, t_fill), t0)
            # background-free counterfactual: when would this batch have
            # dispatched if every group's bg/migration clock were idle?
            # The gap between it and the real dispatch is the batch's
            # attributed interference (exec_earliest <= earliest and
            # rep.exec_free <= rep.free_at(), so nobg <= dispatch).
            nobg = max(exec_earliest, min(deadline, t_fill), t0)
            while i < n and arr[i] <= dispatch:
                ingest(i)
                i += 1
            # virtual-clock hooks run before the batch starts: migration
            # and scaling decisions are made on the state at dispatch time
            maybe_migrate(dispatch)
            maybe_autoscale(dispatch)
            reap_drained(dispatch)
            routable = self._routable()
            rep = self._route(routable)
            dispatch = max(dispatch, rep.free_at())
            nobg = max(nobg, rep.exec_free)
            level = ac.pressure_level()
            batch = ac.take_batch(mb)
            b_times = np.asarray([t for t, _, _ in batch])
            b_items = [it for _, it, _ in batch]
            b_tenants = np.asarray([tn for _, _, tn in batch], np.int64)
            stats = self._execute(queries[qidx[b_items]],
                                  self._level_cfg(level),
                                  collect=bool(tracer))
            stats.tenants = b_tenants
            lat, acct = self._batch_times_us(
                stats, len(batch), d, store=rep.store,
                lift=(rep.rid, len(self.replicas)))
            requested_total += acct["requested"]
            issued_total += acct["issued"]
            hits_total += acct["hits"]
            overlap_w += acct["overlap_frac"] * acct["issued"]
            rep.window.add(acct)
            rep.requested += acct["requested"]
            rep.issued += acct["issued"]
            rep.hits += acct["hits"]
            rep.busy_us += float(lat.max())     # executor occupancy
            rep.batches += 1
            rep.completed += len(batch)
            if level > 0:
                degraded_n += len(batch)
            done = dispatch + lat
            rep.exec_free = dispatch + float(lat.max())
            t_end = max(t_end, rep.exec_free)
            lat_out.extend((done - b_times).tolist())
            queue_b = np.maximum(nobg - b_times, 0.0)
            inter_b = (dispatch - b_times) - queue_b
            que_out.extend(queue_b.tolist())
            int_out.extend(inter_b.tolist())
            svc_out.extend(lat.tolist())
            if tracer:
                self._trace_batch(tracer, rep.rid, dispatch, lat, acct,
                                  stats, b_times, b_items, queue_b,
                                  inter_b, level, rd_us, d,
                                  store=rep.store)
            qidx_out.extend(qidx[b_items].tolist())
            tenant_out.extend(b_tenants.tolist())
            batch_sizes.append(len(batch))
            stats_out.append(stats)
            mean_lat = float(lat.mean())
            est_service = (mean_lat if est_service is None
                           else 0.5 * est_service + 0.5 * mean_lat)
            if compactor is not None:
                bg_run(compactor.after_batch(), rep.exec_free,
                       "compactions")

        reap_drained(np.inf)        # drain-before-drop bookkeeping only
        for r in self.replicas:
            # the run ends when the last device is quiet — background
            # migration/compaction I/O counts (same contract as the
            # single-server loop's mu["free"])
            t_end = max(t_end, r.bg_free, r.mig_free)
        completed = len(lat_out)
        shed_budget = bud["shed"]
        lat_arr = np.asarray(lat_out)
        per_tenant = (self._per_tenant_report(tenant_out, lat_arr, ac)
                      if multi_tenant else None)
        per_shard = {}
        for r in self.replicas:
            rows = r.window.report(t_end)
            if rows:
                for s, row in rows.items():
                    per_shard[f"r{r.rid}.s{s}"] = row
        per_replica = {r.rid: r.row(t_end) for r in self.replicas}
        mut_kw = {}
        if mm is not None:
            mut_kw = dict(
                inserts=mu["inserts"], deletes=mu["deletes"],
                flushes=mu["flushes"], compactions=mu["compactions"],
                bg_pages_read=mu["reads"], bg_pages_written=mu["writes"],
                bg_io_us=mu["io_us"],
                bg_util=mu["io_us"] / t_end if t_end > 0 else 0.0,
                overlap_ratio=self.index.overlap_ratio())
        que_arr = np.asarray(que_out, np.float64)
        svc_arr = np.asarray(svc_out, np.float64)
        int_arr = np.asarray(int_out, np.float64)
        # both report paths price latency columns off the same histogram
        # (empty histograms report the finite 0.0 default, schema intact)
        _, mean_lat_us, p50, p99 = _latency_summary(lat_arr)
        if completed == 0:
            all_stats = self._empty_open_report(
                rate_qps, duration_us, ac, per_tenant).stats
            mean_batch = pages_q = issued_q = 0.0
        else:
            all_stats = QueryStats.concat(stats_out)
            mean_batch = float(np.mean(batch_sizes))
            pages_q = float(all_stats.page_reads.mean())
            issued_q = issued_total / completed
        # REPRO_SANITIZE=1: every completed query's phases must sum back
        # to its reported latency (the fleet conservation contract)
        sanitize.check_attribution(que_arr, svc_arr, int_arr, lat_arr)
        slo = scfg.slo_p99_us
        report = FleetReport(
            rate_qps=rate_qps, duration_us=duration_us, offered=n_reads,
            completed=completed, elapsed_us=t_end,
            qps=completed / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=mean_lat_us, p50_latency_us=p50,
            p99_latency_us=p99,
            mean_queue_us=float(que_arr.mean()) if completed else 0.0,
            mean_service_us=float(svc_arr.mean()) if completed else 0.0,
            mean_interference_us=(float(int_arr.mean())
                                  if completed else 0.0),
            attribution={"queue_us": que_arr, "service_us": svc_arr,
                         "interference_us": int_arr,
                         "latency_us": lat_arr.astype(np.float64)},
            mean_batch_size=mean_batch, pages_per_query=pages_q,
            issued_pages_per_query=issued_q,
            cache_hit_rate=(hits_total / requested_total
                            if requested_total else 0.0),
            overlap_frac=(overlap_w / issued_total
                          if issued_total else 0.0),
            slo_p99_us=slo,
            slo_violation_frac=(float(np.mean(lat_arr > slo))
                                if slo is not None and completed
                                else 0.0),
            measured_step_us=_measured_step(all_stats),
            stats=all_stats,
            query_indices=np.asarray(qidx_out, np.int64),
            offered_qps=n_reads / (duration_us * 1e-6),
            admitted=ac.admitted, shed=ac.shed + shed_budget,
            degraded=degraded_n,
            per_tenant=per_tenant,
            per_shard=per_shard or None,
            seed=run_seed,
            groups=self.fleet_cfg.replica_groups,
            groups_final=len(self._routable()),
            groups_added=scale["added"],
            groups_dropped=scale["dropped"],
            migrations=mig["runs"],
            promoted_pages=mig["promoted"],
            demoted_pages=mig["demoted"],
            mig_pages_read=mig["reads"],
            mig_pages_written=mig["writes"],
            mig_io_us=mig["io_us"],
            shed_budget=shed_budget,
            per_replica=per_replica,
            timeline=timeline or None,
            **mut_kw)
        # REPRO_SANITIZE=1: the fleet keeps the same admission conservation
        # as the single server (budget drops count as shed)
        sanitize.check_open_report(report)
        return report
