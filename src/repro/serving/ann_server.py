"""Serving layer: concurrent ANN query serving, closed- and open-loop.

Closed loop (`serve_closed_loop`): W clients each keep one query in flight —
submit, wait, resubmit (the paper's concurrency axis, §8; queue depth is set
by the client count). Open loop (`serve_open_loop`): queries arrive by a
Poisson process at `rate_qps` regardless of completions — the arrival-rate
axis the §8 storage-centric/hybrid guideline actually turns on, since an
open queue can grow without bound when the device saturates.

Both loops share the dynamic batch scheduler: drain the queue at `max_batch`
or `max_wait_us`, whichever binds first. With an SLO configured
(`slo_p99_us`) the batcher is deadline-aware: it dispatches early when the
oldest enqueued query's latency budget, less the estimated service time,
would otherwise be at risk.

I/O state is per-server and SHARED ACROSS BATCHES: the store stack is built
once (`build_store`), so a stateful cache policy (`cache_policy` = "lru" |
"fifo" | "2q", byte-budgeted by `cache_bytes`) keeps its pages warm from one
batch to the next, and `prefetch` adds LAANN-style look-ahead whose device
service overlaps compute (the device model's `prefetch_overlap` rebate).
With the default policy the batch accounting is the order-free cross-query
union (BatchedPageStore), exactly the pre-refactor behaviour.

Search execution is REAL (the jitted kernel runs every query; hops, pages,
distance evals and result ids are measured; stateful policies replay the
kernel's temporally ordered `page_trace`). Time is VIRTUAL: the container
has no NVMe, so the clock advances by the paper-measured device model —
`SSDModel.concurrent_latency_us(queue_depth, ...)`. Latency includes queue
wait + device service; QPS is completed queries over elapsed virtual time.

Batches are padded to `max_batch` with duplicates of the batch's first query
so the kernel compiles exactly once per (config, max_batch); padding rows
are dropped from all accounting before any cache replay (a padded duplicate
must not warm the cache twice).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

import numpy as np

from repro.core.device_model import SSDModel
from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats
from repro.io import DYNAMIC_POLICIES, build_store


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    max_batch: int = 16          # dynamic batcher: dispatch when this full...
    max_wait_us: float = 200.0   # ...or this long after the first enqueue
    pad_batches: bool = True     # pad to max_batch (one kernel compilation)
    # --- stateful I/O (repro/io/page_cache.py) ---
    cache_policy: str = "none"   # "none" | "lru" | "fifo" | "2q"
    cache_bytes: int = 0         # shared page-cache budget (0 = policy off)
    prefetch: int = 0            # look-ahead hops (needs a cache policy)
    # --- SLO-aware batching ---
    slo_p99_us: Optional[float] = None   # dispatch early when the oldest
    #                                      query's p99 budget is at risk

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"max_batch={self.max_batch} must be >= 1 "
                f"(the batcher must be able to dispatch something)")
        if self.max_wait_us < 0:
            raise ValueError(
                f"max_wait_us={self.max_wait_us} must be >= 0 "
                f"(a negative wait deadline can never be reached)")
        if self.cache_policy != "none" and \
                self.cache_policy not in DYNAMIC_POLICIES:
            raise ValueError(
                f"cache_policy={self.cache_policy!r} must be 'none' or one "
                f"of {DYNAMIC_POLICIES} (the static vertex mask is driven "
                f"by SearchConfig.cache_frac, not the server)")
        if self.cache_policy != "none" and self.cache_bytes <= 0:
            raise ValueError(
                f"cache_policy={self.cache_policy!r} needs cache_bytes > 0")
        if self.prefetch < 0:
            raise ValueError(f"prefetch={self.prefetch} must be >= 0")
        if self.prefetch > 0 and self.cache_policy == "none":
            raise ValueError(
                "prefetch needs a cache_policy to hold looked-ahead pages")
        if self.slo_p99_us is not None and self.slo_p99_us <= 0:
            raise ValueError(
                f"slo_p99_us={self.slo_p99_us} must be positive")


@dataclasses.dataclass
class ServingReport:
    workers: int
    queries: int
    elapsed_us: float
    qps: float
    mean_latency_us: float       # submit -> complete, queue wait included
    p99_latency_us: float
    mean_service_us: float       # dispatch -> complete (no queue wait)
    mean_batch_size: float
    pages_per_query: float           # per-query kernel accounting
    batched_pages_per_query: float   # after coalescing / cache replay
    dedup_saved_frac: float          # 1 - issued/requested
    stats: QueryStats            # per-query search stats, dispatch order
    query_indices: np.ndarray    # (queries,) index into the submitted pool
    cache_hit_rate: float = 0.0  # stateful-policy hits / requested
    overlap_frac: float = 0.0    # prefetched fraction of issued reads

    def row(self) -> dict:
        return {
            "workers": self.workers, "queries": self.queries,
            "qps": round(self.qps, 1),
            "mean_latency_us": round(self.mean_latency_us, 1),
            "p99_latency_us": round(self.p99_latency_us, 1),
            "mean_batch": round(self.mean_batch_size, 2),
            "pages_per_query": round(self.pages_per_query, 2),
            "batched_pages_per_query": round(self.batched_pages_per_query, 2),
            "dedup_saved_frac": round(self.dedup_saved_frac, 4),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
        }


@dataclasses.dataclass
class OpenLoopReport:
    rate_qps: float              # offered Poisson arrival rate
    duration_us: float           # arrival window (service may run past it)
    offered: int                 # arrivals in the window
    completed: int
    elapsed_us: float            # last completion time
    qps: float                   # goodput: completed / elapsed
    mean_latency_us: float
    p99_latency_us: float
    mean_batch_size: float
    pages_per_query: float
    issued_pages_per_query: float
    cache_hit_rate: float
    overlap_frac: float
    slo_p99_us: Optional[float]
    slo_violation_frac: float    # fraction of queries past slo_p99_us
    stats: QueryStats
    query_indices: np.ndarray

    def row(self) -> dict:
        return {
            "rate_qps": round(self.rate_qps, 1),
            "offered": self.offered,
            "qps": round(self.qps, 1),
            "mean_latency_us": round(self.mean_latency_us, 1),
            "p99_latency_us": round(self.p99_latency_us, 1),
            "mean_batch": round(self.mean_batch_size, 2),
            "pages_per_query": round(self.pages_per_query, 2),
            "issued_pages_per_query": round(self.issued_pages_per_query, 2),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "overlap_frac": round(self.overlap_frac, 4),
            "slo_violation_frac": round(self.slo_violation_frac, 4),
        }


class AnnServer:
    """Concurrent query server over a DiskIndex (closed- or open-loop)."""

    def __init__(self, index, cfg=None, model: Optional[SSDModel] = None,
                 server_cfg: Optional[ServerConfig] = None):
        self.index = index
        self.cfg = cfg or index.cfg
        self.model = model or SSDModel()
        self.server_cfg = server_cfg or ServerConfig()
        scfg = self.server_cfg
        # a fresh store stack with batch coalescing (and, per config, a
        # stateful shared cache + prefetcher) on top — the server's I/O
        # counters and cache state must not leak into the facade's stores
        use_cache = self.cfg.cache_frac > 0 and index.cached.any()
        self._stateful = scfg.cache_policy in DYNAMIC_POLICIES
        self.store = build_store(
            index.layout,
            cached_vertices=index.cached if use_cache else None,
            batched=True,
            cache_policy=scfg.cache_policy if self._stateful else "none",
            cache_bytes=scfg.cache_bytes, prefetch=scfg.prefetch)

    # -- batch executor ------------------------------------------------------

    def _execute(self, qvecs: np.ndarray) -> QueryStats:
        """Run one batch through the kernel, padded to max_batch so the jit
        cache holds exactly one entry per (config, max_batch). Stateful
        cache policies additionally collect the temporally ordered page
        trace their replay consumes."""
        b = len(qvecs)
        mb = self.server_cfg.max_batch
        if self.server_cfg.pad_batches and b < mb:
            qvecs = np.concatenate(
                [qvecs, np.repeat(qvecs[:1], mb - b, axis=0)])
        stats = search_batched(
            self.store, self.index.pq, self.cfg, qvecs,
            medoid=self.index.medoid, memgraph=self.index.memgraph,
            batch=len(qvecs), collect_trace=self._stateful,
            account_kernel_io=False)
        return stats.take(b)

    def _batch_times_us(self, stats: QueryStats, depth: int, d: int):
        """Per-query service latencies for one batch at the given device
        queue depth, plus the batch's I/O accounting dict. With a stateful
        policy the accounting is a trace replay against the shared cache
        (misses charged, hits free, prefetches overlapped); otherwise it is
        the order-free cross-query union of BatchedPageStore."""
        if self._stateful:
            acct = self.store.replay_batch(stats.page_trace)
            pages = acct["per_query_issued"]
            dedup, overlap = 1.0, acct["overlap_frac"]
        else:
            acct = self.store.coalesce(stats.visited_pages)
            acct.setdefault("hits", 0)
            acct["overlap_frac"] = overlap = 0.0
            requested, issued = acct["requested"], acct["issued"]
            dedup = issued / requested if requested else 1.0
            # the batch store holds a page for the whole batch, so each query
            # is charged its DISTINCT pages (step revisits are buffer hits),
            # scaled by the coalescing rebate: charges sum to the union
            pages = stats.visited_pages.sum(axis=1).astype(np.float64)
        lat = self.model.concurrent_latency_us(
            depth,
            hops=stats.hops.astype(np.float64),
            pages=pages,
            full_evals=stats.full_evals.astype(np.float64),
            pq_evals=stats.pq_evals.astype(np.float64),
            mem_evals=stats.mem_evals.astype(np.float64),
            d=d, pq_m=self.cfg.pq_m, page_bytes=self.cfg.page_bytes,
            pipeline=self.cfg.pipeline, page_dedup=dedup,
            prefetch_overlap=overlap)
        return np.asarray(lat, np.float64), acct

    # -- closed loop ---------------------------------------------------------

    def serve_closed_loop(self, queries: np.ndarray, workers: int,
                          rounds: int = 1) -> ServingReport:
        """W clients, one outstanding query each, `rounds` queries per
        client, query vectors drawn round-robin from `queries`."""
        if workers <= 0:
            raise ValueError(
                f"workers={workers} must be >= 1: a closed loop with no "
                f"client submits nothing")
        if rounds <= 0:
            raise ValueError(
                f"rounds={rounds} must be >= 1: each client must submit at "
                f"least one query")
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        total = workers * rounds
        # (submit_time, client, query_index); heap orders by time
        events: List[tuple] = [(0.0, c, c % len(queries))
                               for c in range(workers)]
        heapq.heapify(events)
        issued = [1] * workers      # queries issued per client so far
        exec_free = 0.0
        lat_out, qidx_out, stats_out = [], [], []
        service_out, batch_sizes = [], []
        requested_total = issued_total = hits_total = 0
        overlap_w = 0.0
        t_end = 0.0

        while events:
            t0, c0, q0 = heapq.heappop(events)
            batch = [(t0, c0, q0)]
            deadline = t0 + scfg.max_wait_us
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= deadline:
                batch.append(heapq.heappop(events))
            # dispatch when full, at the wait deadline, or when the executor
            # frees up — whichever binds. Closed loop: if no submission is
            # outstanding, nothing can arrive before this batch completes,
            # so there is no point waiting out max_wait
            if len(batch) == scfg.max_batch or not events:
                t_fill = batch[-1][0]
            else:
                t_fill = deadline
            dispatch = max(exec_free, t_fill)
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= dispatch:
                batch.append(heapq.heappop(events))

            qvecs = queries[[q for _, _, q in batch]]
            stats = self._execute(qvecs)
            # device queue depth = queries in flight in this batch
            lat, acct = self._batch_times_us(stats, len(batch), d)
            requested_total += acct["requested"]
            issued_total += acct["issued"]
            hits_total += acct["hits"]
            overlap_w += acct["overlap_frac"] * acct["issued"]
            done = dispatch + lat
            exec_free = dispatch + float(lat.max())
            t_end = max(t_end, exec_free)
            batch_sizes.append(len(batch))
            for (t_sub, c, q), t_done in zip(batch, done):
                lat_out.append(t_done - t_sub)
                service_out.append(t_done - dispatch)
                qidx_out.append(q)
                if issued[c] < rounds:
                    nxt = (c + issued[c] * workers) % len(queries)
                    heapq.heappush(events, (float(t_done), c, nxt))
                    issued[c] += 1
            stats_out.append(stats)

        all_stats = QueryStats.concat(stats_out)
        lat_arr = np.asarray(lat_out)
        return ServingReport(
            workers=workers, queries=total, elapsed_us=t_end,
            qps=total / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=float(lat_arr.mean()),
            p99_latency_us=float(np.percentile(lat_arr, 99)),
            mean_service_us=float(np.mean(service_out)),
            mean_batch_size=float(np.mean(batch_sizes)),
            pages_per_query=float(all_stats.page_reads.mean()),
            batched_pages_per_query=issued_total / total,
            dedup_saved_frac=(1.0 - issued_total / requested_total
                              if requested_total else 0.0),
            stats=all_stats,
            query_indices=np.asarray(qidx_out, np.int64),
            cache_hit_rate=(hits_total / requested_total
                            if requested_total else 0.0),
            overlap_frac=(overlap_w / issued_total if issued_total else 0.0))

    # -- open loop -----------------------------------------------------------

    def serve_open_loop(self, queries: np.ndarray, rate_qps: float,
                        duration_us: float, seed: int = 0) -> OpenLoopReport:
        """Poisson arrivals at `rate_qps` for `duration_us` of virtual time,
        query vectors drawn round-robin. Arrivals do not wait for
        completions (open loop), so past the device's saturation point the
        queue — and the latency — grows with the backlog; every admitted
        arrival is served to completion, even past the window's end.

        The batcher dispatches at `max_batch` / `max_wait_us` as in the
        closed loop; with `slo_p99_us` set it also dispatches as soon as the
        oldest enqueued query's remaining budget (SLO minus the estimated
        batch service time) runs out — trading batch-size efficiency for
        tail latency exactly when the SLO is at risk."""
        if rate_qps <= 0:
            raise ValueError(f"rate_qps={rate_qps} must be positive")
        if duration_us <= 0:
            raise ValueError(f"duration_us={duration_us} must be positive")
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        rng = np.random.default_rng(seed)

        mean_gap = 1e6 / rate_qps
        arrivals: List[float] = []
        t = float(rng.exponential(mean_gap))
        while t < duration_us:
            arrivals.append(t)
            t += float(rng.exponential(mean_gap))
        arr = np.asarray(arrivals)
        n = len(arr)
        if n == 0:
            # nothing arrived: report without paying a kernel compile
            zi = np.zeros(0, np.int64)
            zf = np.zeros(0, np.float64)
            empty = QueryStats(
                ids=np.zeros((0, self.cfg.k), np.int64),
                dists=np.zeros((0, self.cfg.k), np.float64),
                hops=zi, page_reads=zf, cache_hits=zf, n_read_records=zf,
                n_eff=zf, full_evals=zf, pq_evals=zf, mem_hops=zi,
                mem_evals=zi)
            return OpenLoopReport(
                rate_qps=rate_qps, duration_us=duration_us, offered=0,
                completed=0, elapsed_us=0.0, qps=0.0, mean_latency_us=0.0,
                p99_latency_us=0.0, mean_batch_size=0.0, pages_per_query=0.0,
                issued_pages_per_query=0.0, cache_hit_rate=0.0,
                overlap_frac=0.0, slo_p99_us=scfg.slo_p99_us,
                slo_violation_frac=0.0, stats=empty,
                query_indices=np.zeros(0, np.int64))
        qidx = np.arange(n) % len(queries)

        exec_free = 0.0
        est_service: Optional[float] = None
        lat_out, stats_out, batch_sizes = [], [], []
        requested_total = issued_total = hits_total = 0
        overlap_w = 0.0
        t_end = 0.0
        i = 0
        while i < n:
            t0 = arr[i]
            deadline = t0 + scfg.max_wait_us
            if scfg.slo_p99_us is not None:
                # the oldest query must still fit its p99 budget after the
                # (estimated) service time — dispatch before it cannot
                budget = scfg.slo_p99_us - (est_service or 0.0)
                deadline = min(deadline, t0 + max(budget, 0.0))
            t_full = (arr[i + scfg.max_batch - 1]
                      if i + scfg.max_batch <= n else np.inf)
            dispatch = max(exec_free, min(deadline, t_full), t0)
            j = i + 1
            while j < n and j - i < scfg.max_batch and arr[j] <= dispatch:
                j += 1
            stats = self._execute(queries[qidx[i:j]])
            lat, acct = self._batch_times_us(stats, j - i, d)
            requested_total += acct["requested"]
            issued_total += acct["issued"]
            hits_total += acct["hits"]
            overlap_w += acct["overlap_frac"] * acct["issued"]
            done = dispatch + lat
            exec_free = dispatch + float(lat.max())
            t_end = max(t_end, exec_free)
            lat_out.extend((done - arr[i:j]).tolist())
            batch_sizes.append(j - i)
            stats_out.append(stats)
            mean_lat = float(lat.mean())
            est_service = (mean_lat if est_service is None
                           else 0.5 * est_service + 0.5 * mean_lat)
            i = j

        all_stats = QueryStats.concat(stats_out)
        lat_arr = np.asarray(lat_out)
        slo = scfg.slo_p99_us
        return OpenLoopReport(
            rate_qps=rate_qps, duration_us=duration_us, offered=n,
            completed=n, elapsed_us=t_end,
            qps=n / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=float(lat_arr.mean()),
            p99_latency_us=float(np.percentile(lat_arr, 99)),
            mean_batch_size=float(np.mean(batch_sizes)),
            pages_per_query=float(all_stats.page_reads.mean()),
            issued_pages_per_query=issued_total / n,
            cache_hit_rate=(hits_total / requested_total
                            if requested_total else 0.0),
            overlap_frac=(overlap_w / issued_total if issued_total else 0.0),
            slo_p99_us=slo,
            slo_violation_frac=(float(np.mean(lat_arr > slo))
                                if slo is not None else 0.0),
            stats=all_stats, query_indices=qidx)
