"""Serving layer: a closed-loop concurrent ANN query server.

W closed-loop clients each keep one query in flight: submit, wait for the
result, immediately submit the next (the paper's concurrency axis, §8 —
queue depth is set by the client count, not an open arrival rate). Queries
land in a queue; a dynamic batch scheduler (max-batch / max-wait) drains it;
each batch executes on the shared search kernel with page data served
through a `BatchedPageStore`, so duplicate page requests across the batch's
queries are coalesced into one device read.

Search execution is REAL (the jitted kernel runs every query; hops, pages,
distance evals and result ids are measured). Time is VIRTUAL: the container
has no NVMe, so the clock advances by the paper-measured device model —
`SSDModel.concurrent_latency_us(queue_depth, ...)` with queue depth equal to
the number of in-flight queries, and the batch coalescing rebate applied to
the page volume. Latency therefore includes queue wait + device service; QPS
is completed queries over elapsed virtual time.

Batches are padded to `max_batch` with duplicates of the batch's first query
so the kernel compiles exactly once per (config, max_batch); padding rows
are dropped from all accounting (and add nothing to the page union — the
duplicate query visits the same pages).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

import numpy as np

from repro.core.device_model import SSDModel
from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats
from repro.io import build_store


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    max_batch: int = 16          # dynamic batcher: dispatch when this full...
    max_wait_us: float = 200.0   # ...or this long after the first enqueue
    pad_batches: bool = True     # pad to max_batch (one kernel compilation)


@dataclasses.dataclass
class ServingReport:
    workers: int
    queries: int
    elapsed_us: float
    qps: float
    mean_latency_us: float       # submit -> complete, queue wait included
    p99_latency_us: float
    mean_service_us: float       # dispatch -> complete (no queue wait)
    mean_batch_size: float
    pages_per_query: float           # per-query kernel accounting
    batched_pages_per_query: float   # after cross-query coalescing
    dedup_saved_frac: float          # 1 - issued/requested
    stats: QueryStats            # per-query search stats, dispatch order
    query_indices: np.ndarray    # (queries,) index into the submitted pool

    def row(self) -> dict:
        return {
            "workers": self.workers, "queries": self.queries,
            "qps": round(self.qps, 1),
            "mean_latency_us": round(self.mean_latency_us, 1),
            "p99_latency_us": round(self.p99_latency_us, 1),
            "mean_batch": round(self.mean_batch_size, 2),
            "pages_per_query": round(self.pages_per_query, 2),
            "batched_pages_per_query": round(self.batched_pages_per_query, 2),
            "dedup_saved_frac": round(self.dedup_saved_frac, 4),
        }


class AnnServer:
    """Closed-loop concurrent query server over a DiskIndex."""

    def __init__(self, index, cfg=None, model: Optional[SSDModel] = None,
                 server_cfg: Optional[ServerConfig] = None):
        self.index = index
        self.cfg = cfg or index.cfg
        self.model = model or SSDModel()
        self.server_cfg = server_cfg or ServerConfig()
        # a fresh store stack with batch coalescing on top — the server's
        # I/O counters must not leak into the facade's memoized stores
        use_cache = self.cfg.cache_frac > 0 and index.cached.any()
        self.store = build_store(
            index.layout,
            cached_vertices=index.cached if use_cache else None,
            batched=True)

    # -- batch executor ------------------------------------------------------

    def _execute(self, qvecs: np.ndarray) -> QueryStats:
        """Run one batch through the kernel, padded to max_batch so the jit
        cache holds exactly one entry per (config, max_batch)."""
        b = len(qvecs)
        mb = self.server_cfg.max_batch
        if self.server_cfg.pad_batches and b < mb:
            qvecs = np.concatenate(
                [qvecs, np.repeat(qvecs[:1], mb - b, axis=0)])
        stats = search_batched(
            self.store, self.index.pq, self.cfg, qvecs,
            medoid=self.index.medoid, memgraph=self.index.memgraph,
            batch=len(qvecs), account_kernel_io=False)
        return stats.take(b)

    def _batch_times_us(self, stats: QueryStats, depth: int, d: int):
        """Per-query service latencies for one batch at the given device
        queue depth, plus (requested, issued) page counts after the batch
        store coalesced duplicate reads across the batch's queries."""
        acct = self.store.coalesce(stats.visited_pages)
        requested, issued = acct["requested"], acct["issued"]
        dedup = issued / requested if requested else 1.0
        # the batch store holds a page for the whole batch, so each query is
        # charged its DISTINCT pages (step revisits are buffer hits), scaled
        # by the cross-query coalescing rebate: charges sum to the union
        distinct = stats.visited_pages.sum(axis=1).astype(np.float64)
        lat = self.model.concurrent_latency_us(
            depth,
            hops=stats.hops.astype(np.float64),
            pages=distinct,
            full_evals=stats.full_evals.astype(np.float64),
            pq_evals=stats.pq_evals.astype(np.float64),
            mem_evals=stats.mem_evals.astype(np.float64),
            d=d, pq_m=self.cfg.pq_m, page_bytes=self.cfg.page_bytes,
            pipeline=self.cfg.pipeline, page_dedup=dedup)
        return np.asarray(lat, np.float64), requested, issued

    # -- closed loop ---------------------------------------------------------

    def serve_closed_loop(self, queries: np.ndarray, workers: int,
                          rounds: int = 1) -> ServingReport:
        """W clients, one outstanding query each, `rounds` queries per
        client, query vectors drawn round-robin from `queries`."""
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        total = workers * rounds
        # (submit_time, client, query_index); heap orders by time
        events: List[tuple] = [(0.0, c, c % len(queries))
                               for c in range(workers)]
        heapq.heapify(events)
        issued = [1] * workers      # queries issued per client so far
        exec_free = 0.0
        lat_out, qidx_out, stats_out = [], [], []
        service_out, batch_sizes = [], []
        requested_total = issued_total = 0
        t_end = 0.0

        while events:
            t0, c0, q0 = heapq.heappop(events)
            batch = [(t0, c0, q0)]
            deadline = t0 + scfg.max_wait_us
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= deadline:
                batch.append(heapq.heappop(events))
            # dispatch when full, at the wait deadline, or when the executor
            # frees up — whichever binds. Closed loop: if no submission is
            # outstanding, nothing can arrive before this batch completes,
            # so there is no point waiting out max_wait
            if len(batch) == scfg.max_batch or not events:
                t_fill = batch[-1][0]
            else:
                t_fill = deadline
            dispatch = max(exec_free, t_fill)
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= dispatch:
                batch.append(heapq.heappop(events))

            qvecs = queries[[q for _, _, q in batch]]
            stats = self._execute(qvecs)
            # device queue depth = queries in flight in this batch
            lat, req_pages, uniq_pages = self._batch_times_us(
                stats, len(batch), d)
            requested_total += req_pages
            issued_total += uniq_pages
            done = dispatch + lat
            exec_free = dispatch + float(lat.max())
            t_end = max(t_end, exec_free)
            batch_sizes.append(len(batch))
            for (t_sub, c, q), t_done in zip(batch, done):
                lat_out.append(t_done - t_sub)
                service_out.append(t_done - dispatch)
                qidx_out.append(q)
                if issued[c] < rounds:
                    nxt = (c + issued[c] * workers) % len(queries)
                    heapq.heappush(events, (float(t_done), c, nxt))
                    issued[c] += 1
            stats_out.append(stats)

        all_stats = QueryStats.concat(stats_out)
        lat_arr = np.asarray(lat_out)
        return ServingReport(
            workers=workers, queries=total, elapsed_us=t_end,
            qps=total / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=float(lat_arr.mean()),
            p99_latency_us=float(np.percentile(lat_arr, 99)),
            mean_service_us=float(np.mean(service_out)),
            mean_batch_size=float(np.mean(batch_sizes)),
            pages_per_query=float(all_stats.page_reads.mean()),
            batched_pages_per_query=issued_total / total,
            dedup_saved_frac=(1.0 - issued_total / requested_total
                              if requested_total else 0.0),
            stats=all_stats,
            query_indices=np.asarray(qidx_out, np.int64))
