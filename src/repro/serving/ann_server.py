"""Serving layer: concurrent ANN query serving — closed-loop, open-loop,
admission-controlled, and multi-tenant.

The two measurement contracts
-----------------------------
Closed loop (`serve_closed_loop`): W clients each keep exactly ONE query in
flight — submit, wait, resubmit (the paper's concurrency axis, §8; device
queue depth is set by the client count). The loop is self-throttling:
offered load automatically equals served load, so every submission
completes, latency is bounded by construction, and the interesting axis is
how latency and QPS move with W. The report (`ServingReport`) therefore
covers the ENTIRE workload of workers x rounds queries.

Open loop (`serve_open_loop`): queries arrive by a Poisson process at
`rate_qps` for `duration_us`, INDEPENDENT of completions — the arrival-rate
axis the §8 storage-centric/hybrid guideline actually turns on. Nothing
throttles arrivals, so past device saturation the backlog and every latency
percentile grow with the window length: an uncontrolled open-loop p99 is a
statement about the measurement duration, not about the system. The report
(`OpenLoopReport`) is therefore split by admission outcome: `offered`
arrivals, `admitted` (= `completed`: every admitted query is served to
completion, even past the window's end), `shed`, `degraded`; latency
percentiles are over the ADMITTED work only, and throughput appears twice —
`offered_qps` (arrivals / window) vs `qps` (goodput: completions / elapsed).

Admission control (`ServerConfig.admission`, repro/serving/admission.py)
decides at arrival time what enters the queue: a token bucket sheds above a
configured rate; a bounded queue sheds by policy — "reject" (drop newest),
"shed-oldest" (drop the query whose SLO is already lost), or "degrade"
(admit everything but serve under pressure with a shrunken beam:
`degrade_levels` multiply `L`/`beam_width`/`dw_max` by queue-pressure
level, trading recall for service rate).

Both loops share the dynamic batch scheduler: drain the queue at `max_batch`
or `max_wait_us`, whichever binds first. With an SLO configured
(`slo_p99_us`) the batcher is deadline-aware: it dispatches early when the
oldest enqueued query's latency budget, less the estimated service time,
would otherwise be at risk.

I/O state is per-server and SHARED ACROSS BATCHES: the store stack is built
once (`build_store`), so a stateful cache policy (`cache_policy` = "lru" |
"fifo" | "2q", byte-budgeted by `cache_bytes`) keeps its pages warm from one
batch to the next, and `prefetch` adds LAANN-style look-ahead whose device
service overlaps compute (the device model's `prefetch_overlap` rebate).
With the default policy the batch accounting is the order-free cross-query
union (BatchedPageStore), exactly the pre-refactor behaviour.

Distributed serving: `ServerConfig.shards > 1` splits the page space across
S simulated devices (repro/io/sharded_store.py: ShardedPageStore behind
`ServerConfig.placement` = "round-robin" | "contiguous" | "replicated" —
the last needs a `page_profile` on the AnnServer constructor). Each batch's
charged pages are split by shard, the device time is the max over per-shard
completion times at per-shard queue depths
(`SSDModel.concurrent_latency_us(shard_pages=, shard_depths=)`), and the
reports carry `per_shard` rows (load share, mean queue depth, utilization,
hit rate) plus the flattened `shards`/`shard_imbalance`/`max_shard_util`
row columns. With a dynamic cache policy configured the same `cache_bytes`
budget is split into per-shard caches; shards compose with `tenants` (each
shard's slice is tenant-partitioned) and with `prefetch` (look-ahead issued
against the owning shard's queue), so one ServerConfig can describe a full
production store. Replica groups — N complete copies of the shard set with
load-aware routing, hot-page migration and autoscaling — live one layer up,
in repro/serving/fleet.py (FleetServer extends this class).

Multi-tenancy: `ServerConfig.tenants > 1` splits the SAME `cache_bytes`
budget into per-tenant partitions (repro/io/page_cache.py:
PartitionedPageCache — static `tenant_shares` + optional utility
rebalance), and both loops accept a `tenants=` array mapping each query-
pool vector to its tenant. Per-query tenant ids travel on
`QueryStats.tenants` (stamped here — the kernel is tenant-blind), route
trace replay to the right partition, and come back as the `per_tenant`
report column (admission counts, latency, per-tenant hit rates).

Search execution is REAL (the jitted kernel runs every query; hops, pages,
distance evals and result ids are measured; stateful policies replay the
kernel's temporally ordered `page_trace` — format documented in
repro/io/page_cache.py). Time is VIRTUAL: the container has no NVMe, so
the clock advances by the paper-measured device model —
`SSDModel.concurrent_latency_us(queue_depth, ...)`. Latency includes queue
wait + device service; QPS is completed queries over elapsed virtual time.

Batches are padded to `max_batch` with duplicates of the batch's first query
so the kernel compiles exactly once per (config, max_batch); padding rows
are dropped from all accounting before any cache replay (a padded duplicate
must not warm the cache twice). Degrade levels are the one exception to
"exactly once": each distinct level is one more (config, max_batch) entry,
which is why the level ladder is short.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import List, Optional, Tuple

import numpy as np

from repro import sanitize
from repro.core.device_model import SSDModel
from repro.core.search_kernel import search_batched
from repro.core.stats import QueryStats
from repro.io import DYNAMIC_POLICIES, PLACEMENTS, build_store
from repro.mutation import Compactor, MutableIndex, MutationMix
from repro.obs import Histogram, Tracer
from repro.serving.admission import AdmissionConfig, AdmissionController


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    max_batch: int = 16          # dynamic batcher: dispatch when this full...
    max_wait_us: float = 200.0   # ...or this long after the first enqueue
    pad_batches: bool = True     # pad to max_batch (one kernel compilation)
    # --- stateful I/O (repro/io/page_cache.py) ---
    cache_policy: str = "none"   # "none" | "lru" | "fifo" | "2q"
    cache_bytes: int = 0         # shared page-cache budget (0 = policy off)
    prefetch: int = 0            # look-ahead hops (needs a cache policy)
    # --- SLO-aware batching ---
    slo_p99_us: Optional[float] = None   # dispatch early when the oldest
    #                                      query's p99 budget is at risk
    # --- overload control (repro/serving/admission.py) ---
    admission: Optional[AdmissionConfig] = None   # None = admit everything
    # --- multi-tenant cache partitioning (repro/io/page_cache.py) ---
    tenants: int = 1                     # >1 partitions cache_bytes
    tenant_shares: Optional[Tuple[float, ...]] = None  # default: equal
    cache_rebalance_every: int = 0       # utility rebalance period (0 = off)
    # --- distributed serving (repro/io/sharded_store.py) ---
    shards: int = 1                      # >1 splits the page space across
    #                                      S simulated devices
    placement: str = "round-robin"       # "round-robin" | "contiguous" |
    #                                      "replicated" (needs page_profile=
    #                                      on the AnnServer constructor)
    placement_hot_frac: float = 0.25     # replicated: page-space fraction
    #                                      eligible for the replica hot set

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"max_batch={self.max_batch} must be >= 1 "
                f"(the batcher must be able to dispatch something)")
        if self.max_wait_us < 0:
            raise ValueError(
                f"max_wait_us={self.max_wait_us} must be >= 0 "
                f"(a negative wait deadline can never be reached)")
        if self.cache_policy != "none" and \
                self.cache_policy not in DYNAMIC_POLICIES:
            raise ValueError(
                f"cache_policy={self.cache_policy!r} must be 'none' or one "
                f"of {DYNAMIC_POLICIES} (the static vertex mask is driven "
                f"by SearchConfig.cache_frac, not the server)")
        if self.cache_policy != "none" and self.cache_bytes <= 0:
            raise ValueError(
                f"cache_policy={self.cache_policy!r} needs cache_bytes > 0")
        if self.cache_policy == "none" and self.cache_bytes > 0:
            raise ValueError(
                f"cache_bytes={self.cache_bytes} with cache_policy='none' "
                f"configures no cache — set cache_policy to one of "
                f"{DYNAMIC_POLICIES}, or drop cache_bytes")
        if self.prefetch < 0:
            raise ValueError(f"prefetch={self.prefetch} must be >= 0")
        if self.prefetch > 0 and self.cache_policy == "none":
            raise ValueError(
                "prefetch needs a cache_policy to hold looked-ahead pages")
        if self.slo_p99_us is not None and self.slo_p99_us <= 0:
            raise ValueError(
                f"slo_p99_us={self.slo_p99_us} must be positive")
        if self.admission is not None \
                and not isinstance(self.admission, AdmissionConfig):
            raise ValueError(
                f"admission={self.admission!r} must be an AdmissionConfig "
                f"(or None to admit everything)")
        if self.tenants < 1:
            raise ValueError(f"tenants={self.tenants} must be >= 1")
        if self.tenants > 1 and self.cache_policy not in DYNAMIC_POLICIES:
            raise ValueError(
                f"tenants={self.tenants} partitions the stateful page "
                f"cache — set cache_policy to one of {DYNAMIC_POLICIES}")
        if self.tenant_shares is not None and self.tenants == 1:
            raise ValueError(
                "tenant_shares needs tenants > 1 (one tenant owns the "
                "whole budget)")
        if self.cache_rebalance_every < 0:
            raise ValueError(
                f"cache_rebalance_every={self.cache_rebalance_every} "
                f"must be >= 0 (0 = static shares)")
        if self.cache_rebalance_every > 0 and self.tenants == 1:
            raise ValueError(
                f"cache_rebalance_every={self.cache_rebalance_every} with "
                f"tenants=1 has no partitions to rebalance — set tenants "
                f"> 1 or drop cache_rebalance_every")
        if self.shards < 1:
            raise ValueError(f"shards={self.shards} must be >= 1")
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"placement={self.placement!r} must be one of {PLACEMENTS}")
        if self.shards == 1 and self.placement != "round-robin":
            raise ValueError(
                f"placement={self.placement!r} with shards=1 places "
                f"nothing — a single device has no placement decision; "
                f"set shards > 1 or leave placement at its default")
        if not 0.0 < self.placement_hot_frac <= 1.0:
            raise ValueError(
                f"placement_hot_frac={self.placement_hot_frac} must be in "
                f"(0, 1] (the replica-eligible fraction of the page space)")


def _measured_step(stats: QueryStats) -> float:
    """Mean MEASURED fused-kernel wall clock per query (us), 0.0 unless the
    search config ran `pipeline="fused"` — reported next to the modeled
    device latency, never folded into it (the virtual clock stays the
    paper's analytic device model; this column is its measured check)."""
    if stats.measured_step_us is None or len(stats) == 0:
        return 0.0
    return float(np.mean(stats.measured_step_us))


def _latency_summary(lat_arr) -> Tuple[Histogram, float, float, float]:
    """(histogram, mean, p50, p99) for a latency sample — the ONE
    implementation behind every report percentile (repro.obs.Histogram,
    quantiles within `Histogram.error_bound` ~0.1% of the exact order
    statistic). The empty case degrades to finite zeros with the same
    schema, where np.percentile would raise on a zero-length array —
    the zero-admitted open-loop path reports through here too."""
    h = Histogram.from_values(lat_arr, name="latency_us")
    mean = h.mean if h.count else 0.0
    return (h, mean, h.quantile(0.5, default=0.0),
            h.quantile(0.99, default=0.0))


def _tenant_columns(per_tenant: Optional[dict]) -> dict:
    """Flatten the per-tenant report rows into t<N>_* columns so `row()`
    carries the multi-tenant outcome into the benchmark tables (previously
    the dict was dropped on the way to print_table)."""
    if not per_tenant:
        return {}
    out = {}
    for t, r in sorted(per_tenant.items()):
        for key in ("completed", "shed", "p99_latency_us",
                    "cache_hit_rate"):
            if key in r:
                out[f"t{t}_{key}"] = r[key]
    return out


def _shard_columns(per_shard: Optional[dict]) -> dict:
    """Per-shard summary columns: how many devices, the max/mean issued-read
    imbalance (1.0 = perfectly balanced placement), and the peak device
    utilization — the one-line answer to \"did the placement spread the
    load\"."""
    if not per_shard:
        return {}
    issued = [r["issued"] for r in per_shard.values()]
    mean = sum(issued) / len(issued)
    util = [r["utilization"] for r in per_shard.values()]
    return {"shards": len(per_shard),
            "shard_imbalance": round(max(issued) / mean, 4) if mean else 1.0,
            "max_shard_util": round(max(util), 4)}


@dataclasses.dataclass
class ServingReport:
    workers: int
    queries: int
    elapsed_us: float
    qps: float
    mean_latency_us: float       # submit -> complete, queue wait included
    p99_latency_us: float
    mean_service_us: float       # dispatch -> complete (no queue wait)
    mean_batch_size: float
    pages_per_query: float           # per-query kernel accounting
    batched_pages_per_query: float   # after coalescing / cache replay
    dedup_saved_frac: float          # 1 - issued/requested
    stats: QueryStats            # per-query search stats, dispatch order
    query_indices: np.ndarray    # (queries,) index into the submitted pool
    cache_hit_rate: float = 0.0  # stateful-policy hits / requested
    overlap_frac: float = 0.0    # prefetched fraction of issued reads
    p50_latency_us: float = 0.0  # histogram median (repro.obs.Histogram)
    measured_step_us: float = 0.0    # mean MEASURED fused-kernel wall clock
    #                                  per query (pipeline="fused" only) —
    #                                  sits next to mean_latency_us (modeled)
    per_tenant: Optional[dict] = None   # {tenant: {completed, latency,
    #                                     cache_hit_rate, ...}} when the
    #                                     workload is multi-tenant
    per_shard: Optional[dict] = None    # {shard: {issued, load_frac,
    #                                     mean_queue_depth, utilization,
    #                                     hit_rate}} when shards > 1

    def row(self) -> dict:
        row = {
            "workers": self.workers, "queries": self.queries,
            "qps": round(self.qps, 1),
            "mean_latency_us": round(self.mean_latency_us, 1),
            "p50_latency_us": round(self.p50_latency_us, 1),
            "p99_latency_us": round(self.p99_latency_us, 1),
            "mean_batch": round(self.mean_batch_size, 2),
            "pages_per_query": round(self.pages_per_query, 2),
            "batched_pages_per_query": round(self.batched_pages_per_query, 2),
            "dedup_saved_frac": round(self.dedup_saved_frac, 4),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "overlap_frac": round(self.overlap_frac, 4),
        }
        if self.measured_step_us:
            row["measured_step_us"] = round(self.measured_step_us, 1)
        row.update(_tenant_columns(self.per_tenant))
        row.update(_shard_columns(self.per_shard))
        return row


@dataclasses.dataclass
class OpenLoopReport:
    rate_qps: float              # offered Poisson arrival rate
    duration_us: float           # arrival window (service may run past it)
    offered: int                 # arrivals in the window
    completed: int               # == admitted (admitted work always runs)
    elapsed_us: float            # last completion time
    qps: float                   # GOODPUT: completed / elapsed
    mean_latency_us: float       # over ADMITTED queries only
    p99_latency_us: float        # p99-of-admitted (shed work has no latency)
    mean_batch_size: float
    pages_per_query: float
    issued_pages_per_query: float
    cache_hit_rate: float
    overlap_frac: float
    slo_p99_us: Optional[float]
    slo_violation_frac: float    # fraction of ADMITTED queries past the SLO
    measured_step_us: float      # mean MEASURED fused-kernel wall clock per
    #                              query (pipeline="fused" only; 0.0 else)
    stats: QueryStats
    query_indices: np.ndarray    # pool index per COMPLETED query
    # --- admission outcome (ServerConfig.admission) ---
    offered_qps: float = 0.0     # arrivals / duration (vs `qps` = goodput)
    admitted: int = 0            # offered == admitted + shed
    shed: int = 0                # token-bucket + queue-policy drops
    degraded: int = 0            # queries served at a degraded level
    # --- latency attribution (repro.obs; REPRO_SANITIZE-checked) ---
    p50_latency_us: float = 0.0  # histogram median (repro.obs.Histogram)
    mean_queue_us: float = 0.0   # arrival -> earliest batcher dispatch
    mean_service_us: float = 0.0  # dispatch -> completion (device + compute)
    mean_interference_us: float = 0.0   # extra wait attributed to background
    #                              work holding the device (journal drain,
    #                              flush/compaction; fleet: bg clocks)
    attribution: Optional[dict] = None  # per-query float64 arrays, completion
    #                              order: {queue_us, service_us,
    #                              interference_us, latency_us} — each row
    #                              sums exactly (queue + service +
    #                              interference == latency)
    per_tenant: Optional[dict] = None   # {tenant: {offered, admitted, shed,
    #                                     completed, latency, hit rates}}
    per_shard: Optional[dict] = None    # {shard: {issued, load_frac,
    #                                     mean_queue_depth, utilization,
    #                                     hit_rate}} when shards > 1
    # --- streaming-mutation outcome (serve_open_loop(mutation_mix=)) ---
    inserts: int = 0             # insert arrivals applied (delta staging)
    deletes: int = 0             # delete arrivals applied (tombstones)
    flushes: int = 0             # delta -> append-zone flushes
    compactions: int = 0         # background compaction runs
    bg_pages_read: int = 0       # background device reads (flush RMW +
    #                              compaction page reads)
    bg_pages_written: int = 0    # background page rewrites
    bg_io_us: float = 0.0        # device time consumed by background I/O
    bg_util: float = 0.0         # bg_io_us / elapsed — the goodput tax
    overlap_ratio: float = 0.0   # live-vertex OR(G) after the run (0.0 on
    #                              non-mutating runs: frozen indexes report
    #                              it at build time instead)
    journal_writes: int = 0      # write-ahead journal pages committed (only
    #                              nonzero over a durable MutableIndex —
    #                              billed at the write unit on the same
    #                              background clock as flush/compaction)
    recovery_us: float = 0.0     # device time the preceding recover() cost
    #                              (journal replay reads + redo I/O) —
    #                              reported once by the first run after a
    #                              recovery, NOT folded into the window's
    #                              clock (recovery completes before serving)
    seed: Optional[int] = None   # the ONE rng seed that reproduces the run
    #                              (arrivals + mutation kinds + delete
    #                              victims); None when the caller supplied
    #                              its own generator

    def row(self) -> dict:
        row = {
            "rate_qps": round(self.rate_qps, 1),
            "offered": self.offered,
            "offered_qps": round(self.offered_qps, 1),
            "qps": round(self.qps, 1),
            "admitted": self.admitted,
            "shed": self.shed,
            "degraded": self.degraded,
            "mean_latency_us": round(self.mean_latency_us, 1),
            "p50_latency_us": round(self.p50_latency_us, 1),
            "p99_latency_us": round(self.p99_latency_us, 1),
            "mean_queue_us": round(self.mean_queue_us, 1),
            "mean_service_us": round(self.mean_service_us, 1),
            "mean_interference_us": round(self.mean_interference_us, 1),
            "mean_batch": round(self.mean_batch_size, 2),
            "pages_per_query": round(self.pages_per_query, 2),
            "issued_pages_per_query": round(self.issued_pages_per_query, 2),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "overlap_frac": round(self.overlap_frac, 4),
            "slo_violation_frac": round(self.slo_violation_frac, 4),
        }
        if self.seed is not None:
            row["seed"] = self.seed
        if self.measured_step_us:
            row["measured_step_us"] = round(self.measured_step_us, 1)
        if self.inserts or self.deletes or self.flushes or self.compactions:
            row.update({
                "inserts": self.inserts, "deletes": self.deletes,
                "flushes": self.flushes, "compactions": self.compactions,
                "bg_pages_read": self.bg_pages_read,
                "bg_pages_written": self.bg_pages_written,
                "bg_util": round(self.bg_util, 4),
                "overlap_ratio": round(self.overlap_ratio, 4),
            })
        if self.journal_writes:
            row["journal_writes"] = self.journal_writes
        if self.recovery_us:
            row["recovery_us"] = round(self.recovery_us, 1)
        row.update(_tenant_columns(self.per_tenant))
        row.update(_shard_columns(self.per_shard))
        return row


class _ShardWindow:
    """Per-run per-shard aggregation: each dispatched batch adds its
    shard-split accounting (`shard_issued`/`shard_depths` from the sharded
    store), and `report(elapsed_us)` turns the window into the per-shard
    rows the serving reports expose — issued-read load share, mean device
    queue depth, and busy-time utilization (shard service time over the
    run's elapsed virtual time)."""

    def __init__(self, store, shards: int, model: SSDModel,
                 page_bytes: int):
        # explicit (store, shards, model, page_bytes) rather than a server
        # handle: a fleet replica owns one window per replica STORE, while
        # the single-server loops pass their own store — same aggregation
        # either way
        self.store = store
        self.model = model
        self.page_bytes = page_bytes
        self.on = shards > 1
        if self.on:
            self.req = np.zeros(shards, np.int64)
            self.hits = np.zeros(shards, np.int64)
            self.issued = np.zeros(shards, np.int64)
            self.depth_sum = np.zeros(shards, np.float64)
            self.busy_us = np.zeros(shards, np.float64)
            self.batches = 0

    def add(self, acct: dict) -> None:
        if not self.on:
            return
        self.req += acct["shard_requested"]
        self.hits += acct["shard_hits"]
        self.issued += acct["shard_issued"]
        self.depth_sum += np.asarray(acct["shard_depths"], np.float64)
        # busy time in raw service units: issued x read_service_us is the
        # device-capacity fraction consumed, independent of queueing
        self.busy_us += acct["shard_issued"] * self.model.\
            read_service_us(self.page_bytes)
        self.batches += 1

    def add_background(self, page_ids, service_us_each: float) -> None:
        """Background update I/O (flush/compaction) lands on the owning
        shards' busy time: each page is billed to its placement HOME at
        `service_us_each` (read or write unit), so a compaction run is
        visible in the very same per-shard utilization column query I/O
        fills."""
        if not self.on or len(page_ids) == 0:
            return
        homes = self.store.placement.page_to_shard[
            np.asarray(page_ids, np.int64)]
        counts = np.bincount(homes, minlength=len(self.busy_us))
        self.busy_us += counts * service_us_each

    def add_broadcast_writes(self, page_ids, service_us_each: float) -> None:
        """Hot-page migration copy I/O: a promoted page is WRITTEN to every
        shard except its home (the home already holds it), each copy billed
        at the write unit — the migration tax lands on the same per-shard
        utilization column query and compaction I/O fill."""
        if not self.on or len(page_ids) == 0:
            return
        homes = self.store.placement.page_to_shard[
            np.asarray(page_ids, np.int64)]
        counts = np.full(len(self.busy_us), len(page_ids), np.int64)
        counts -= np.bincount(homes, minlength=len(self.busy_us))
        self.busy_us += counts * service_us_each

    def report(self, elapsed_us: float) -> Optional[dict]:
        if not self.on or self.batches == 0:
            return None
        total = int(self.issued.sum())
        return {s: {
            "requested": int(self.req[s]),
            "issued": int(self.issued[s]),
            "hit_rate": (round(self.hits[s] / self.req[s], 4)
                         if self.req[s] else 0.0),
            "load_frac": (round(self.issued[s] / total, 4)
                          if total else 0.0),
            "mean_queue_depth": round(self.depth_sum[s] / self.batches, 2),
            "utilization": (round(float(self.busy_us[s]) / elapsed_us, 4)
                            if elapsed_us > 0 else 0.0),
        } for s in range(len(self.issued))}


class AnnServer:
    """Concurrent query server over a DiskIndex (closed- or open-loop)."""

    def __init__(self, index, cfg=None, model: Optional[SSDModel] = None,
                 server_cfg: Optional[ServerConfig] = None,
                 page_profile: Optional[np.ndarray] = None):
        self.index = index
        self.cfg = cfg or index.cfg
        self.model = model or SSDModel()
        self.server_cfg = server_cfg or ServerConfig()
        scfg = self.server_cfg
        # a fresh store stack with batch coalescing (and, per config, a
        # stateful shared cache + prefetcher, or a sharded store) on top —
        # the server's I/O counters and cache state must not leak into the
        # facade's stores. `page_profile` (per-page access counts, see
        # repro.io.profile_from_trace) feeds the "replicated" placement's
        # hot-set ranking.
        use_cache = self.cfg.cache_frac > 0 and index.cached.any()
        self._stateful = scfg.cache_policy in DYNAMIC_POLICIES
        self._sharded = scfg.shards > 1
        self._mutable = isinstance(index, MutableIndex)
        placement = scfg.placement
        if self._sharded and placement == "replicated" \
                and page_profile is None:
            # the hot-set ranking needs a page profile; a server without
            # one can still run — fall back LOUDLY instead of crashing in
            # the store build (`make_placement` stays strict for callers
            # who configured replicated deliberately with data in hand)
            warnings.warn(
                "placement='replicated' without a page_profile: no hot set "
                "can be ranked — falling back to 'round-robin'. Pass "
                "AnnServer(page_profile=profile_from_trace(...)) to seed "
                "from an offline trace, or serve a warm-up window and call "
                "reseed_placement() to rank the hot set from the store's "
                "live read counters (profile_from_counters).", stacklevel=2)
            placement = "round-robin"
        self.store = build_store(
            index.layout,
            cached_vertices=index.cached if use_cache else None,
            batched=True,
            cache_policy=scfg.cache_policy if self._stateful else "none",
            cache_bytes=scfg.cache_bytes,
            prefetch=scfg.prefetch,
            tenants=scfg.tenants if self._stateful else 1,
            tenant_shares=scfg.tenant_shares,
            rebalance_every=scfg.cache_rebalance_every,
            shards=scfg.shards,
            placement=placement if self._sharded else "round-robin",
            page_profile=page_profile,
            placement_hot_frac=scfg.placement_hot_frac,
            mutable=self._mutable)
        if self._mutable:
            # flushes/compactions must invalidate THIS server's caches and
            # charge its books, not just the facade's
            index.attach_store(self.store)
        self._degraded_cfgs = {}    # degrade level -> SearchConfig

    # -- batch executor ------------------------------------------------------

    def _execute(self, qvecs: np.ndarray, cfg=None,
                 collect: bool = False) -> QueryStats:
        """Run one batch through the kernel, padded to max_batch so the jit
        cache holds exactly one entry per (config, max_batch) — `cfg`
        overrides the server's config for degraded dispatches (one more jit
        entry per degrade level). Stateful cache policies additionally
        collect the temporally ordered page trace their replay consumes;
        `collect=True` forces that trace on any store so a Tracer can emit
        per-hop device spans (one extra jit entry while tracing).

        Over a MutableIndex with pending mutations the disk side runs the
        tombstone-overfetch config and the delta's exact results are merged
        into the result heap (MutableIndex.merge_mutations) — with zero
        mutations both are identity and the frozen path is bit-identical."""
        cfg = cfg or self.cfg
        orig = qvecs
        b = len(qvecs)
        mb = self.server_cfg.max_batch
        if self.server_cfg.pad_batches and b < mb:
            qvecs = np.concatenate(
                [qvecs, np.repeat(qvecs[:1], mb - b, axis=0)])
        kcfg = (self.index.disk_cfg(cfg)
                if self._mutable and self.index.mutated else cfg)
        stats = search_batched(
            self.store, self.index.pq, kcfg, qvecs,
            medoid=self.index.medoid, memgraph=self.index.memgraph,
            batch=len(qvecs), collect_trace=self._stateful or collect,
            account_kernel_io=False)
        stats = stats.take(b)
        if self._mutable and self.index.mutated:
            stats = self.index.merge_mutations(stats, orig, cfg)
        return stats

    def _level_cfg(self, level: int):
        """SearchConfig for a degrade level: the configured beam knobs
        (`L`, `beam_width`, `dw_max`) scaled by the level's multiplier,
        floored at the smallest legal values (`k`, 1, `dw_min`). Level 0 is
        the undegraded config; levels are memoized so each compiles its
        kernel exactly once."""
        if level == 0:
            return self.cfg
        if level not in self._degraded_cfgs:
            mult = self.server_cfg.admission.degrade_levels[level]
            cfg = self.cfg
            self._degraded_cfgs[level] = cfg.replace(
                L=max(cfg.k, int(round(cfg.L * mult))),
                beam_width=max(1, int(round(cfg.beam_width * mult))),
                dw_max=max(cfg.dw_min, int(round(cfg.dw_max * mult))))
        return self._degraded_cfgs[level]

    def reseed_placement(self, hot_frac: Optional[float] = None) -> dict:
        """Re-rank the replicated hot set from the store's LIVE per-page
        read counters (repro.io.profile_from_counters) — the online escape
        from the replicated-placement cold start: construct the server with
        no page_profile (it warns and serves round-robin), run a warm-up
        window, then call this to promote the top `hot_frac` (default:
        ServerConfig.placement_hot_frac) pages the devices actually read.
        Only pages with at least one observed read are promoted (an unseen
        page has no evidence it is hot). Returns the swap delta
        ({"promoted", "demoted"} page-id arrays, plus "hot_pages"). The
        fleet's migration rebalancer applies the same ranking continuously
        on windowed deltas (repro/serving/fleet.py)."""
        if not self._sharded:
            raise ValueError(
                "reseed_placement needs a sharded server (shards > 1) — a "
                "single device has no placement to re-rank")
        from repro.io import profile_from_counters
        profile = profile_from_counters(self.store)
        frac = (hot_frac if hot_frac is not None
                else self.server_cfg.placement_hot_frac)
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"hot_frac={frac} must be in (0, 1]")
        k = max(1, int(round(frac * len(profile))))
        hot = np.argsort(profile, kind="stable")[::-1][:k]
        mask = np.zeros(len(profile), bool)
        mask[hot[profile[hot] > 0]] = True
        delta = self.store.set_replicated(mask)
        delta["hot_pages"] = int(mask.sum())
        return delta

    def _tenant_map(self, queries: np.ndarray,
                    tenants: Optional[np.ndarray]) -> np.ndarray:
        """Validate and normalize the query-pool -> tenant mapping. Ids must
        stay below ServerConfig.tenants whenever the cache is partitioned
        (each id names a partition); with an unpartitioned cache any ids are
        accepted and drive accounting only."""
        if tenants is None:
            return np.zeros(len(queries), np.int64)
        t = np.asarray(tenants, np.int64).reshape(-1)
        if len(t) != len(queries):
            raise ValueError(
                f"tenants has {len(t)} entries for {len(queries)} queries")
        if len(t) and t.min() < 0:
            raise ValueError("tenant ids must be >= 0")
        scfg = self.server_cfg
        if scfg.tenants > 1 and len(t) and t.max() >= scfg.tenants:
            raise ValueError(
                f"tenant id {t.max()} out of range for "
                f"tenants={scfg.tenants} cache partitions")
        return t

    def _cache_tenant_rows(self) -> dict:
        """Per-tenant cache-side accounting: replay hit rates from the
        store (any stateful cache) plus current partition capacities when
        the cache is partitioned."""
        if not self._stateful:
            return {}
        rows = {t: {"cache_hit_rate": round(r, 4)}
                for t, r in self.store.tenant_hit_rates().items()}
        cache = getattr(self.store, "cache", None)
        if getattr(cache, "tenant_aware", False):
            for t, cap in enumerate(cache.capacities()):
                rows.setdefault(t, {})["cache_pages"] = cap
        else:
            # sharded stores keep per-shard caches; when those are tenant-
            # partitioned, report each tenant's capacity summed over shards
            caps = getattr(self.store, "tenant_capacities", lambda: None)()
            if caps is not None:
                for t, cap in enumerate(caps):
                    rows.setdefault(t, {})["cache_pages"] = cap
        return rows

    def _per_tenant_report(self, tenant_ids, lat_arr,
                           ac: Optional[AdmissionController] = None) -> dict:
        """Merge completion-side latency stats, admission counts and cache
        accounting into one {tenant: row} dict."""
        ids = np.asarray(tenant_ids, np.int64)
        out = {}
        for t in np.unique(ids):
            m = ids == t
            _, t_mean, _, t_p99 = _latency_summary(lat_arr[m])
            out[int(t)] = {
                "completed": int(m.sum()),
                "mean_latency_us": round(t_mean, 1),
                "p99_latency_us": round(t_p99, 1)}
        if ac is not None:
            for t, row in ac.per_tenant_rows().items():
                out.setdefault(t, {"completed": 0}).update(row)
        for t, row in self._cache_tenant_rows().items():
            out.setdefault(t, {"completed": 0}).update(row)
        return out

    def _shard_window(self, store=None) -> _ShardWindow:
        """A fresh per-run shard aggregation window over `store` (default:
        the server's own store) — fleet replicas pass their own."""
        return _ShardWindow(store or self.store, self.server_cfg.shards,
                            self.model, self.cfg.page_bytes)

    def _batch_times_us(self, stats: QueryStats, depth: int, d: int,
                        store=None, lift: Optional[Tuple[int, int]] = None):
        """Per-query service latencies for one batch at the given device
        queue depth, plus the batch's I/O accounting dict. With a stateful
        policy the accounting is a trace replay against the shared cache
        (misses charged, hits free, prefetches overlapped); otherwise it is
        the order-free cross-query union of BatchedPageStore. A sharded
        store additionally splits each query's charged pages by shard
        (trace replay against the per-shard caches, or the per-shard
        union), and the device time becomes the max over per-shard
        completion times at per-shard queue depths.

        `store` overrides the server's own store (a fleet replica replays
        against ITS copy); `lift=(r, R)` lifts the shard split onto the
        fleet's (B, R, S) replica grid — this batch's pages on replica r's
        row, zero elsewhere — so the device time is priced by the model's
        max-over-replicas-then-shards path."""
        store = store if store is not None else self.store
        if self._stateful:
            acct = store.replay_batch(stats.page_trace,
                                      tenants=stats.tenants)
            pages = acct["per_query_issued"]
            dedup, overlap = 1.0, acct["overlap_frac"]
        else:
            acct = store.coalesce(stats.visited_pages)
            acct.setdefault("hits", 0)
            acct["overlap_frac"] = overlap = 0.0
            requested, issued = acct["requested"], acct["issued"]
            dedup = issued / requested if requested else 1.0
            # the batch store holds a page for the whole batch, so each query
            # is charged its DISTINCT pages (step revisits are buffer hits),
            # scaled by the coalescing rebate: charges sum to the union
            pages = stats.visited_pages.sum(axis=1).astype(np.float64)
        sp = acct.get("per_query_shard_pages")
        sd = acct.get("shard_depths")
        if lift is not None:
            r, R = lift
            if sp is None:
                # unsharded replica: its whole device is one (r, s) cell
                sp = np.asarray(pages, np.float64)[:, None]
                sd = np.asarray([depth], np.float64)
            S = sp.shape[1]
            grid = np.zeros((len(sp), R, S), np.float64)
            grid[:, r, :] = sp
            depths = np.zeros((R, S), np.float64)
            depths[r] = np.asarray(sd, np.float64)
            sp, sd = grid, depths
        lat = self.model.concurrent_latency_us(
            depth,
            hops=stats.hops.astype(np.float64),
            pages=pages,
            full_evals=stats.full_evals.astype(np.float64),
            pq_evals=stats.pq_evals.astype(np.float64),
            mem_evals=stats.mem_evals.astype(np.float64),
            d=d, pq_m=self.cfg.pq_m, page_bytes=self.cfg.page_bytes,
            pipeline=self.cfg.pipeline, page_dedup=dedup,
            prefetch_overlap=overlap,
            shard_pages=sp, shard_depths=sd)
        return np.asarray(lat, np.float64), acct

    def _trace_batch(self, tracer: Tracer, pid: int, dispatch: float,
                     lat: np.ndarray, acct: dict, stats: QueryStats,
                     b_times: np.ndarray, b_items, queue_b: np.ndarray,
                     inter_b: np.ndarray, level: int, rd_us: float,
                     d: int, store=None) -> None:
        """Emit one dispatched batch's spans: the batch slice and the
        model-priced kernel-compute rollup on the executor track, per-shard
        device busy time (issued reads x read unit — summing these per
        shard reproduces `_ShardWindow.busy_us` exactly on a non-mutating
        run), the per-query latency phases (queue / interference / service,
        whose durations sum to the query's reported latency), and — when
        the kernel collected a page trace — per-hop markers carrying each
        hop's page count and per-shard split."""
        store = store if store is not None else self.store
        tracer.span("batch", "batch", dispatch, float(lat.max()), pid=pid,
                    track="executor",
                    args={"size": len(b_items), "level": level})
        comp = self.model._compute_us(
            stats.full_evals.astype(np.float64),
            stats.pq_evals.astype(np.float64),
            stats.mem_evals.astype(np.float64), d, self.cfg.pq_m)
        tracer.span("kernel", "kernel", dispatch, float(np.sum(comp)),
                    pid=pid, track="executor",
                    args={"full_evals": float(np.sum(stats.full_evals)),
                          "pq_evals": float(np.sum(stats.pq_evals))})
        shard_issued = acct.get("shard_issued")
        if shard_issued is not None:
            for s, cnt in enumerate(np.asarray(shard_issued).tolist()):
                if cnt:
                    tracer.span("device", "device", dispatch, cnt * rd_us,
                                pid=pid, track=f"shard{s}",
                                args={"issued": int(cnt)})
        elif acct["issued"]:
            tracer.span("device", "device", dispatch,
                        acct["issued"] * rd_us, pid=pid, track="shard0",
                        args={"issued": int(acct["issued"])})
        page_to_shard = (store.placement.page_to_shard
                         if shard_issued is not None
                         and getattr(store, "placement", None) is not None
                         else None)
        for bi, item in enumerate(b_items):
            t_arr_us = float(b_times[bi])
            q_us, i_us, s_us = (float(queue_b[bi]), float(inter_b[bi]),
                                float(lat[bi]))
            tracer.span("queue", "queue", t_arr_us, q_us, pid=pid,
                        track="query", qid=item)
            if i_us > 0.0:
                tracer.span("interference", "interference",
                            t_arr_us + q_us, i_us, pid=pid, track="query",
                            qid=item)
            tracer.span("service", "service", dispatch, s_us, pid=pid,
                        track="query", qid=item,
                        args={"latency_us": q_us + i_us + s_us,
                              "queue_us": q_us, "interference_us": i_us,
                              "service_us": s_us})
            if stats.page_trace is None:
                continue
            t_hop_us = dispatch
            for h, hop_pages in enumerate(stats.page_trace[bi]):
                pages = hop_pages[hop_pages >= 0]
                if len(pages) == 0:
                    continue
                hop_args = {"hop": h, "pages": int(len(pages))}
                if page_to_shard is not None:
                    homes = np.bincount(page_to_shard[pages])
                    for s in np.flatnonzero(homes):
                        hop_args[f"s{s}_pages"] = int(homes[s])
                dur_us = len(pages) * rd_us
                tracer.span(f"hop{h}", "hop", t_hop_us, dur_us, pid=pid,
                            track="query", qid=item, args=hop_args)
                t_hop_us += dur_us

    # -- closed loop ---------------------------------------------------------

    def serve_closed_loop(self, queries: np.ndarray, workers: int,
                          rounds: int = 1,
                          tenants: Optional[np.ndarray] = None
                          ) -> ServingReport:
        """W clients, one outstanding query each, `rounds` queries per
        client, query vectors drawn round-robin from `queries`. `tenants`
        optionally maps each query-pool vector to a tenant id (see the
        module doc): closed loops need no admission control (they self-
        throttle), but the cache partition a query charges — and the
        per-tenant report — still follow the mapping."""
        if workers <= 0:
            raise ValueError(
                f"workers={workers} must be >= 1: a closed loop with no "
                f"client submits nothing")
        if rounds <= 0:
            raise ValueError(
                f"rounds={rounds} must be >= 1: each client must submit at "
                f"least one query")
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        tenant_of = self._tenant_map(queries, tenants)
        multi_tenant = tenants is not None or scfg.tenants > 1
        total = workers * rounds
        # (submit_time, client, query_index); heap orders by time
        events: List[tuple] = [(0.0, c, c % len(queries))
                               for c in range(workers)]
        heapq.heapify(events)
        issued = [1] * workers      # queries issued per client so far
        exec_free = 0.0
        lat_out, qidx_out, stats_out = [], [], []
        service_out, batch_sizes, tenant_out = [], [], []
        requested_total = issued_total = hits_total = 0
        overlap_w = 0.0
        shard_win = self._shard_window()
        t_end = 0.0

        while events:
            t0, c0, q0 = heapq.heappop(events)
            batch = [(t0, c0, q0)]
            deadline = t0 + scfg.max_wait_us
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= deadline:
                batch.append(heapq.heappop(events))
            # dispatch when full, at the wait deadline, or when the executor
            # frees up — whichever binds. Closed loop: if no submission is
            # outstanding, nothing can arrive before this batch completes,
            # so there is no point waiting out max_wait
            if len(batch) == scfg.max_batch or not events:
                t_fill = batch[-1][0]
            else:
                t_fill = deadline
            dispatch = max(exec_free, t_fill)
            while events and len(batch) < scfg.max_batch \
                    and events[0][0] <= dispatch:
                batch.append(heapq.heappop(events))

            qvecs = queries[[q for _, _, q in batch]]
            stats = self._execute(qvecs)
            stats.tenants = tenant_of[[q for _, _, q in batch]]
            # device queue depth = queries in flight in this batch
            lat, acct = self._batch_times_us(stats, len(batch), d)
            requested_total += acct["requested"]
            issued_total += acct["issued"]
            hits_total += acct["hits"]
            overlap_w += acct["overlap_frac"] * acct["issued"]
            shard_win.add(acct)
            done = dispatch + lat
            exec_free = dispatch + float(lat.max())
            t_end = max(t_end, exec_free)
            batch_sizes.append(len(batch))
            for (t_sub, c, q), t_done in zip(batch, done):
                lat_out.append(t_done - t_sub)
                service_out.append(t_done - dispatch)
                qidx_out.append(q)
                tenant_out.append(int(tenant_of[q]))
                if issued[c] < rounds:
                    nxt = (c + issued[c] * workers) % len(queries)
                    heapq.heappush(events, (float(t_done), c, nxt))
                    issued[c] += 1
            stats_out.append(stats)

        all_stats = QueryStats.concat(stats_out)
        lat_arr = np.asarray(lat_out)
        _, lat_mean, lat_p50, lat_p99 = _latency_summary(lat_arr)
        return ServingReport(
            workers=workers, queries=total, elapsed_us=t_end,
            qps=total / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=lat_mean,
            p50_latency_us=lat_p50,
            p99_latency_us=lat_p99,
            mean_service_us=float(np.mean(service_out)),
            mean_batch_size=float(np.mean(batch_sizes)),
            pages_per_query=float(all_stats.page_reads.mean()),
            batched_pages_per_query=issued_total / total,
            dedup_saved_frac=(1.0 - issued_total / requested_total
                              if requested_total else 0.0),
            stats=all_stats,
            query_indices=np.asarray(qidx_out, np.int64),
            cache_hit_rate=(hits_total / requested_total
                            if requested_total else 0.0),
            overlap_frac=(overlap_w / issued_total if issued_total else 0.0),
            measured_step_us=_measured_step(all_stats),
            per_tenant=(self._per_tenant_report(tenant_out, lat_arr)
                        if multi_tenant else None),
            per_shard=shard_win.report(t_end))

    # -- open loop -----------------------------------------------------------

    def _empty_open_report(self, rate_qps: float, duration_us: float,
                           ac: AdmissionController,
                           per_tenant: Optional[dict],
                           extra: Optional[dict] = None,
                           seed: Optional[int] = None) -> OpenLoopReport:
        """Report for a run that completed nothing (no arrivals, or every
        arrival shed) — no kernel compile is paid. `extra` carries the
        mutation-outcome fields of an all-mutation window. Latency
        columns route through the SAME histogram as the populated path
        (`_latency_summary` on a zero-length sample): finite zeros with
        identical formatting and schema, where the old path hardcoded an
        unrounded `p99_latency_us=0.0` next to the normal path's rounded
        value and np.percentile would have raised outright."""
        zi = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float64)
        empty = QueryStats(
            ids=np.zeros((0, self.cfg.k), np.int64),
            dists=np.zeros((0, self.cfg.k), np.float64),
            hops=zi, page_reads=zf, cache_hits=zf, n_read_records=zf,
            n_eff=zf, full_evals=zf, pq_evals=zf, mem_hops=zi,
            mem_evals=zi)
        _, lat_mean, lat_p50, lat_p99 = _latency_summary(zf)
        return OpenLoopReport(
            rate_qps=rate_qps, duration_us=duration_us, offered=ac.offered,
            completed=0, elapsed_us=0.0, qps=0.0, mean_latency_us=lat_mean,
            p50_latency_us=lat_p50, p99_latency_us=lat_p99,
            mean_batch_size=0.0, pages_per_query=0.0,
            issued_pages_per_query=0.0, cache_hit_rate=0.0,
            overlap_frac=0.0, slo_p99_us=self.server_cfg.slo_p99_us,
            slo_violation_frac=0.0, measured_step_us=0.0, stats=empty,
            query_indices=np.zeros(0, np.int64),
            offered_qps=ac.offered / (duration_us * 1e-6),
            admitted=ac.admitted, shed=ac.shed, degraded=0,
            attribution={"queue_us": zf, "service_us": zf,
                         "interference_us": zf, "latency_us": zf},
            per_tenant=per_tenant, seed=seed, **(extra or {}))

    def serve_open_loop(self, queries: np.ndarray, rate_qps: float,
                        duration_us: float, seed: int = 0,
                        tenants: Optional[np.ndarray] = None,
                        arrivals: Optional[np.ndarray] = None,
                        mutation_mix: Optional[MutationMix] = None,
                        insert_pool: Optional[np.ndarray] = None,
                        rng: Optional[np.random.Generator] = None,
                        tracer: Optional[Tracer] = None,
                        trace_pid: int = 0) -> OpenLoopReport:
        """Poisson arrivals at `rate_qps` for `duration_us` of virtual time,
        query vectors drawn round-robin. Arrivals do not wait for
        completions (open loop), so past the device's saturation point the
        queue — and the latency — grows with the backlog; every ADMITTED
        arrival is served to completion, even past the window's end.

        With `ServerConfig.admission` set, each arrival first passes the
        `AdmissionController` (token bucket, then the bounded queue's
        reject / shed-oldest / degrade policy — see the module doc): shed
        arrivals never execute and carry no latency, so the report's
        percentiles are p99-of-admitted, and `qps` is goodput against
        `offered_qps`. Under "degrade", dispatches map queue pressure to a
        shrunken-beam SearchConfig (`_level_cfg`) instead of dropping.

        `tenants` optionally maps each query-pool vector to a tenant id
        (routes cache-partition charging and keys the `per_tenant` report).
        `arrivals` replaces the Poisson process with explicit sorted
        arrival times in us (deterministic admission tests: bursts at t=0,
        etc.); `rate_qps` then only scales the report's offered-load column.

        The batcher dispatches at `max_batch` / `max_wait_us` as in the
        closed loop; with `slo_p99_us` set it also dispatches as soon as the
        oldest enqueued query's remaining budget (SLO minus the estimated
        batch service time) runs out — trading batch-size efficiency for
        tail latency exactly when the SLO is at risk.

        ONE seeded rng drives the whole run: the Poisson arrivals, the
        mutation-mix arrival kinds AND the delete-victim draws all come
        from `np.random.default_rng(seed)` (`MutationMix.seed` is ignored),
        so a single seed reproduces a streaming run end to end and is
        stamped into `OpenLoopReport.row()`. Pass `rng=` to share a
        generator across calls (e.g. a multi-epoch trace replay); the
        stamped seed is then the caller's to report.

        `mutation_mix` (repro/mutation/compactor.py: MutationMix) opens the
        STREAMING workload: each arrival is independently a read (served as
        above), an insert (staged in the MutableIndex's delta — requires an
        AnnServer over a MutableIndex and an `insert_pool` of vectors), or
        a delete (tombstones a random live vid). Inserts flush to the
        append zone when the delta crosses the index's `flush_threshold`,
        and the mix's compaction policy (none | threshold | continuous)
        schedules the background re-pack. ALL background I/O — flush
        read-modify-writes and compaction reads + rewrites — occupies the
        same device: it pushes the next dispatch out (`bg_free`), lands on
        the owning shards' busy time, and is reported per outcome
        (`inserts`/`deletes`/`flushes`/`compactions`/`bg_*` on the
        report), so compaction visibly competes with query I/O.

        Every reported latency is attributed exactly: per query,
        `queue_us` (arrival to the dispatch instant the batcher would
        have picked with an idle background device) + `interference_us`
        (the extra wait while journal/flush/compaction I/O holds the
        device) + `service_us` (dispatch to completion) sums to
        `latency_us` to the float — REPRO_SANITIZE re-checks the sum on
        every run, and `OpenLoopReport.attribution` carries the arrays.
        Pass `tracer=` (repro.obs.Tracer) to additionally record the run
        as spans — arrivals, per-query phases, batches, per-shard device
        busy time, per-hop page reads, background interference — on
        replica-group `trace_pid` (fleet replicas trace side by side);
        `tracer=None` (the default) costs one falsy check per batch."""
        if rate_qps <= 0:
            raise ValueError(f"rate_qps={rate_qps} must be positive")
        if duration_us <= 0:
            raise ValueError(f"duration_us={duration_us} must be positive")
        mm = mutation_mix if (mutation_mix is not None
                              and mutation_mix.mutating) else None
        if mm is not None:
            if not self._mutable:
                raise ValueError(
                    "mutation_mix with insert/delete arrivals needs an "
                    "AnnServer over a MutableIndex "
                    "(repro.mutation.MutableIndex) — a frozen DiskIndex "
                    "cannot absorb mutations")
            if mm.insert_frac > 0 and (insert_pool is None
                                       or len(insert_pool) == 0):
                raise ValueError(
                    "insert_frac > 0 needs a non-empty insert_pool of "
                    "vectors to draw inserts from")
        queries = np.asarray(queries, np.float32)
        d = queries.shape[1]
        scfg = self.server_cfg
        tenant_of = self._tenant_map(queries, tenants)
        multi_tenant = tenants is not None or scfg.tenants > 1

        # one generator for arrivals, arrival kinds and delete victims —
        # the single source of randomness the stamped seed reproduces
        gen = rng if rng is not None else np.random.default_rng(seed)
        run_seed = None if rng is not None else int(seed)
        if arrivals is None:
            mean_gap = 1e6 / rate_qps
            times: List[float] = []
            t = float(gen.exponential(mean_gap))
            while t < duration_us:
                times.append(t)
                t += float(gen.exponential(mean_gap))
            arr = np.asarray(times)
        else:
            arr = np.asarray(arrivals, np.float64).reshape(-1)
            if len(arr) and (np.any(arr < 0) or np.any(np.diff(arr) < 0)):
                raise ValueError(
                    "explicit arrivals must be non-negative and sorted")
        n = len(arr)
        ac = AdmissionController(scfg.admission)
        if n == 0:
            per_tenant = (self._per_tenant_report([], np.zeros(0), ac)
                          if multi_tenant else None)
            report = self._empty_open_report(rate_qps, duration_us, ac,
                                             per_tenant, seed=run_seed)
            sanitize.check_open_report(report)
            return report
        # arrival kinds: 0 = read, 1 = insert, 2 = delete. Reads index the
        # query pool round-robin BY READ ORDER, so a mutating mix serves
        # the same read sequence a pure-read run would
        if mm is not None:
            kinds = gen.choice(
                3, size=n, p=[mm.read_frac, mm.insert_frac, mm.delete_frac])
        else:
            kinds = np.zeros(n, np.int64)
        reads = kinds == 0
        n_reads = int(reads.sum())
        qidx = (np.where(reads, np.cumsum(reads) - 1, 0)) % len(queries)
        arr_tenant = tenant_of[qidx]

        # background-update device clock + per-outcome accounting: flush /
        # compaction I/O holds the device (dispatches wait on bg_free) and
        # is priced read/write asymmetrically
        mu = {"inserts": 0, "deletes": 0, "flushes": 0, "compactions": 0,
              "reads": 0, "writes": 0, "io_us": 0.0, "free": 0.0,
              "ins_i": 0, "journal": 0}
        rd_us = self.model.read_service_us(self.cfg.page_bytes)
        wr_us = self.model.write_service_us(self.cfg.page_bytes)
        compactor = Compactor(self.index, mm) if mm is not None else None
        # durable MutableIndex: journal commits occupy the same background
        # device clock as flush/compaction I/O, and a preceding recover()'s
        # cost is reported (once) without deferring this window's work —
        # recovery completed before the window opened
        jrn = (getattr(self.index, "journal", None)
               if self._mutable else None)
        rec_us = 0.0
        if self._mutable and getattr(self.index, "last_recovery_us", 0.0):
            rec_us = float(self.index.last_recovery_us)
            self.index.last_recovery_us = 0.0

        exec_free = 0.0
        est_service: Optional[float] = None
        lat_out, stats_out, batch_sizes = [], [], []
        que_out, svc_out, int_out = [], [], []
        qidx_out, tenant_out = [], []
        requested_total = issued_total = hits_total = 0
        overlap_w = 0.0
        shard_win = self._shard_window()
        degraded_n = 0
        t_end = 0.0

        def jrn_drain(t: float) -> None:
            """Bill journal pages committed since the last drain: one
            sequential write stream holding the device exactly like
            flush/compaction I/O (group commits amortize page rounding)."""
            if jrn is None:
                return
            pages = jrn.take_pending_io()
            if pages:
                us = pages * wr_us
                # REPRO_SANITIZE=1: priced durations are non-negative, so
                # the background clock below can only move forward
                sanitize.check(pages >= 0 and us >= 0.0,
                               f"journal drain billed negative time: "
                               f"{pages} pages, {us}us")
                bg_start = max(mu["free"], t)
                mu["free"] = bg_start + us
                mu["io_us"] += us
                mu["journal"] += pages
                if tracer:
                    tracer.span("journal_drain", "bg", bg_start, us,
                                pid=trace_pid, track="background",
                                args={"pages": pages})

        def bg_run(acct, t: float, kind: str) -> None:
            if not acct:
                return
            us = (acct["pages_read"] * rd_us
                  + acct["pages_written"] * wr_us)
            sanitize.check(us >= 0.0,
                           f"background {kind} billed negative time: {us}us "
                           f"(reads={acct['pages_read']}, "
                           f"writes={acct['pages_written']})")
            bg_start = max(mu["free"], t)
            mu["free"] = bg_start + us
            mu["io_us"] += us
            mu["reads"] += acct["pages_read"]
            mu["writes"] += acct["pages_written"]
            mu[kind] += 1
            shard_win.add_background(acct["read_pages"], rd_us)
            shard_win.add_background(acct["written_pages"], wr_us)
            if tracer:
                tracer.span(kind, "bg", bg_start, us, pid=trace_pid,
                            track="background",
                            args={"pages_read": acct["pages_read"],
                                  "pages_written": acct["pages_written"]})

        def ingest(j: int, executor_idle: bool = False) -> None:
            t = float(arr[j])
            if tracer:
                tracer.instant("arrival", "admission", t, pid=trace_pid,
                               track="admission", qid=j,
                               args={"kind": int(kinds[j])})
            if kinds[j] == 0:
                ac.offer(t, j, int(arr_tenant[j]),
                         executor_idle=executor_idle)
                return
            if kinds[j] == 1:
                self.index.insert(
                    insert_pool[mu["ins_i"] % len(insert_pool)])
                mu["ins_i"] += 1
                mu["inserts"] += 1
                bg_run(self.index.maybe_flush(), t, "flushes")
            else:
                vid = self.index.random_live_vid(gen)
                if vid is not None and self.index.delete(vid):
                    mu["deletes"] += 1
            bg_run(compactor.after_mutation(), t, "compactions")
            jrn_drain(t)

        i = 0
        mb = scfg.max_batch
        pend = ac.pending
        while i < n or pend:
            if not pend:
                # idle until the next arrival; its admission decision is
                # made at its own arrival instant
                ingest(i, executor_idle=exec_free <= float(arr[i]))
                i += 1
                continue
            t0 = pend[0][0]
            deadline = t0 + scfg.max_wait_us
            if scfg.slo_p99_us is not None:
                # the oldest query must still fit its p99 budget after the
                # (estimated) service time — dispatch before it cannot
                budget = scfg.slo_p99_us - (est_service or 0.0)
                deadline = min(deadline, t0 + max(budget, 0.0))
            # admissions while the batcher would still be waiting to fill
            while i < n and len(pend) < mb and arr[i] <= deadline:
                ingest(i)
                i += 1
            t_fill = pend[mb - 1][0] if len(pend) >= mb else np.inf
            # `base` is the dispatch instant an idle background device
            # would have allowed; waiting past it on mu["free"] is time
            # attributed to background interference (journal drain,
            # flush/compaction I/O) — the attribution split the per-query
            # queue_us/interference_us breakdown and the sanitizer's
            # conservation check both hang off
            base = max(exec_free, min(deadline, t_fill), t0)
            dispatch = max(base, mu["free"])
            # admissions up to the dispatch instant (under backlog this is
            # where the queue bound binds and shedding happens)
            while i < n and arr[i] <= dispatch:
                ingest(i)
                i += 1
            # mutations ingested above may have pushed the background
            # clock — the device must be free of flush/compaction work
            # before this batch can start
            dispatch = max(dispatch, mu["free"])
            level = ac.pressure_level()
            batch = ac.take_batch(mb)
            b_times = np.asarray([t for t, _, _ in batch])
            b_items = [it for _, it, _ in batch]
            b_tenants = np.asarray([tn for _, _, tn in batch], np.int64)
            stats = self._execute(queries[qidx[b_items]],
                                  self._level_cfg(level),
                                  collect=bool(tracer))
            stats.tenants = b_tenants
            lat, acct = self._batch_times_us(stats, len(batch), d)
            requested_total += acct["requested"]
            issued_total += acct["issued"]
            hits_total += acct["hits"]
            overlap_w += acct["overlap_frac"] * acct["issued"]
            shard_win.add(acct)
            if level > 0:
                degraded_n += len(batch)
            done = dispatch + lat
            exec_free = dispatch + float(lat.max())
            t_end = max(t_end, exec_free)
            lat_out.extend((done - b_times).tolist())
            # exact attribution: a query arriving after `base` (admitted
            # while the batch waited out the background clock) spent its
            # whole wait under interference, none of it queueing
            queue_b = np.maximum(base - b_times, 0.0)
            inter_b = (dispatch - b_times) - queue_b
            que_out.extend(queue_b.tolist())
            int_out.extend(inter_b.tolist())
            svc_out.extend(lat.tolist())
            if tracer:
                self._trace_batch(tracer, trace_pid, dispatch, lat, acct,
                                  stats, b_times, b_items, queue_b, inter_b,
                                  level, rd_us, d)
            qidx_out.extend(qidx[b_items].tolist())
            tenant_out.extend(b_tenants.tolist())
            batch_sizes.append(len(batch))
            stats_out.append(stats)
            mean_lat = float(lat.mean())
            est_service = (mean_lat if est_service is None
                           else 0.5 * est_service + 0.5 * mean_lat)
            if compactor is not None:
                # "continuous" policy: a bounded repair rides each batch
                bg_run(compactor.after_batch(), exec_free, "compactions")
                jrn_drain(exec_free)

        if mm is not None and jrn is not None:
            # persist the rng cursor: a crashed run's recover() +
            # recovered_rng() then resumes the exact arrival/victim stream
            self.index.journal_rng_state(gen.bit_generator.state)
            jrn_drain(exec_free)
        t_end = max(t_end, mu["free"])
        mut_kw = dict(journal_writes=mu["journal"], recovery_us=rec_us)
        if mm is not None:
            mut_kw.update(
                inserts=mu["inserts"], deletes=mu["deletes"],
                flushes=mu["flushes"], compactions=mu["compactions"],
                bg_pages_read=mu["reads"], bg_pages_written=mu["writes"],
                bg_io_us=mu["io_us"],
                bg_util=mu["io_us"] / t_end if t_end > 0 else 0.0,
                overlap_ratio=self.index.overlap_ratio())
        completed = len(lat_out)
        per_tenant = (self._per_tenant_report(tenant_out,
                                              np.asarray(lat_out), ac)
                      if multi_tenant else None)
        if completed == 0:
            report = self._empty_open_report(rate_qps, duration_us, ac,
                                             per_tenant, extra=mut_kw,
                                             seed=run_seed)
            sanitize.check_open_report(report)
            return report
        all_stats = QueryStats.concat(stats_out)
        lat_arr = np.asarray(lat_out)
        que_arr = np.asarray(que_out)
        svc_arr = np.asarray(svc_out)
        int_arr = np.asarray(int_out)
        # REPRO_SANITIZE=1: per-query queue + service + interference must
        # reproduce the reported latency exactly — no time invented, none
        # dropped (docs/observability.md: the conservation contract)
        sanitize.check_attribution(que_arr, svc_arr, int_arr, lat_arr)
        _, lat_mean, lat_p50, lat_p99 = _latency_summary(lat_arr)
        slo = scfg.slo_p99_us
        report = OpenLoopReport(
            rate_qps=rate_qps, duration_us=duration_us, offered=n_reads,
            completed=completed, elapsed_us=t_end,
            qps=completed / (t_end * 1e-6) if t_end > 0 else 0.0,
            mean_latency_us=lat_mean,
            p50_latency_us=lat_p50,
            p99_latency_us=lat_p99,
            mean_queue_us=float(que_arr.mean()),
            mean_service_us=float(svc_arr.mean()),
            mean_interference_us=float(int_arr.mean()),
            attribution={"queue_us": que_arr, "service_us": svc_arr,
                         "interference_us": int_arr, "latency_us": lat_arr},
            mean_batch_size=float(np.mean(batch_sizes)),
            pages_per_query=float(all_stats.page_reads.mean()),
            issued_pages_per_query=issued_total / completed,
            cache_hit_rate=(hits_total / requested_total
                            if requested_total else 0.0),
            overlap_frac=(overlap_w / issued_total if issued_total else 0.0),
            slo_p99_us=slo,
            slo_violation_frac=(float(np.mean(lat_arr > slo))
                                if slo is not None else 0.0),
            measured_step_us=_measured_step(all_stats),
            stats=all_stats,
            query_indices=np.asarray(qidx_out, np.int64),
            offered_qps=n_reads / (duration_us * 1e-6),
            admitted=ac.admitted, shed=ac.shed, degraded=degraded_n,
            per_tenant=per_tenant, per_shard=shard_win.report(t_end),
            seed=run_seed, **mut_kw)
        # REPRO_SANITIZE=1: offered == admitted + shed, completed == admitted
        sanitize.check_open_report(report)
        return report
