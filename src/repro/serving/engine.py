"""Serving loop: jit'd prefill + decode steps with a fixed-slot batch (the
production shapes prefill_32k/decode_32k/long_500k lower exactly these step
functions — see launch/dryrun.py)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill_step


class LMServer:
    def __init__(self, params, cfg, max_len: int = 512, parallel=None):
        self.params, self.cfg, self.max_len = params, cfg, max_len
        self.parallel = parallel
        self._prefill = jax.jit(
            lambda p, b: prefill_step(p, cfg, b, parallel=parallel))
        self._decode = jax.jit(
            lambda p, t, c, i, mp: decode_step(
                p, cfg, t, c, i, parallel=parallel, mrope_positions=mp))

    def generate(self, prompts: np.ndarray, new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts (B, S) int32 -> (B, new_tokens) int32 greedy/sampled."""
        b, s = prompts.shape
        assert s + new_tokens <= self.max_len
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = (jnp.asarray(frames) if frames is not None else
                               jnp.zeros((b, self.cfg.num_frames,
                                          self.cfg.d_model), jnp.float32))
        if self.cfg.rope_variant == "mrope":
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, None], (3, b, s))
        # prefill fills a max_len cache: pad prompt into a max_len buffer
        cache = init_cache(self.cfg, b, self.max_len)
        logits, pf_cache = self._prefill(self.params, batch)
        # copy prefilled kv into the serving cache (same tree structure,
        # prefill cache has seq dim s)
        cache = jax.tree.map(self._fit, cache, pf_cache)

        key = jax.random.PRNGKey(seed)
        out = np.empty((b, new_tokens), np.int32)
        tok = self._pick(logits, temperature, key)
        mp0 = (jnp.zeros((3, b, 1), jnp.int32)
               if self.cfg.rope_variant == "mrope" else None)
        for i in range(new_tokens):
            out[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(s + i), mp0)
            key, sub = jax.random.split(key)
            tok = self._pick(logits, temperature, sub)
        return out

    @staticmethod
    def _fit(dst, src):
        if dst.shape == src.shape:
            return src
        # kv caches: (ns, B, S, KV, hd) — write src's S into dst's prefix
        sl = tuple(slice(0, m) for m in src.shape)
        return dst.at[sl].set(src.astype(dst.dtype))

    @staticmethod
    def _pick(logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
