"""Admission control for open-loop serving: keep the queue — and p99 —
bounded when the offered load exceeds what the device can serve.

An open-loop arrival process does not wait for completions, so past the
saturation point the backlog grows without bound and every latency
percentile of the *admitted* work grows with it: the experimental
evaluations of disk-resident graph ANN systems flag exactly this regime as
the one where system-level policy, not kernel quality, decides behaviour.
The `AdmissionController` sits between the arrival process and the dynamic
batcher and decides, AT ARRIVAL TIME, what happens to each query:

  token bucket   `rate_qps` tokens/s refill into a bucket of depth `burst`;
                 an arrival that finds no token is shed immediately
                 (explicit per-deployment rate limiting, policy-independent;
                 rate_qps=0 disables the bucket).
  bounded queue  at most `queue_cap` queries may be awaiting dispatch.
                 An arrival that finds the queue full is handled by
                 `policy`:

    "reject"      — shed the NEW arrival (newest-dropped; admitted work is
                    never revoked, so queue wait stays FIFO-predictable).
    "shed-oldest" — drop the OLDEST waiting query and admit the new one
                    (freshest-first under overload: the oldest query is the
                    one whose SLO is already lost).
    "degrade"     — admit everything, but serve under pressure with a
                    SHRUNKEN search: the batcher maps queue occupancy to a
                    degrade level, and each level multiplies the beam
                    (`L`, `beam_width`, `dw_max`) by the configured factor.
                    Degraded queries trade recall for service rate, which
                    is what re-bounds the queue without dropping anyone.

  An arrival that finds the whole system idle (empty queue AND idle
  executor) is always queue-admitted — even at queue_cap=0, where the
  queue holds no *waiting* query but the in-service slot still exists.

Every decision is counted (offered / admitted / shed, globally and per
tenant), so `OpenLoopReport` can state goodput against offered load and
p99 over the admitted work only.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Deque, Optional, Tuple

ADMISSION_POLICIES = ("none", "reject", "shed-oldest", "degrade")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    policy: str = "reject"       # "none" | "reject" | "shed-oldest" | "degrade"
    queue_cap: int = 64          # max queries awaiting dispatch (>= 0)
    rate_qps: float = 0.0        # token-bucket refill rate (0 = no bucket)
    burst: int = 32              # token-bucket depth
    # beam multipliers by queue-pressure level (policy="degrade"): level 0
    # applies below queue_cap occupancy, level i at [i*cap, (i+1)*cap), the
    # last level everywhere beyond. Each distinct level compiles one more
    # kernel variant, so keep the ladder short.
    degrade_levels: Tuple[float, ...] = (1.0, 0.5, 0.25)

    def __post_init__(self):
        if self.policy not in ADMISSION_POLICIES:
            raise ValueError(f"policy={self.policy!r} must be one of "
                             f"{ADMISSION_POLICIES}")
        if self.queue_cap < 0:
            raise ValueError(f"queue_cap={self.queue_cap} must be >= 0 "
                             f"(0 = no waiting room beyond the in-service "
                             f"slot)")
        if self.rate_qps < 0:
            raise ValueError(f"rate_qps={self.rate_qps} must be >= 0 "
                             f"(0 disables the token bucket)")
        if self.burst < 1:
            raise ValueError(f"burst={self.burst} must be >= 1 "
                             f"(a bucket that holds no token admits "
                             f"nothing)")
        if not self.degrade_levels:
            raise ValueError("degrade_levels must not be empty")
        if any(not 0.0 < m <= 1.0 for m in self.degrade_levels):
            raise ValueError(
                f"degrade_levels={self.degrade_levels} must all be in "
                f"(0, 1] (multipliers on the configured beam)")
        if self.degrade_levels[0] != 1.0:
            raise ValueError(
                f"degrade_levels[0]={self.degrade_levels[0]} must be 1.0 "
                f"(below queue_cap occupancy the search is undegraded)")
        if any(b > a for a, b in zip(self.degrade_levels,
                                     self.degrade_levels[1:])):
            raise ValueError(
                f"degrade_levels={self.degrade_levels} must be "
                f"non-increasing (more pressure never widens the beam)")


class AdmissionController:
    """Arrival-time admission state machine for `AnnServer.serve_open_loop`.

    Owns the pending queue (entries are (arrival_time_us, item, tenant)
    tuples in arrival order) plus the token bucket and all shed/admit
    counters. The serving loop calls `offer()` once per arrival in time
    order, reads `pressure_level()` at each dispatch, and drains with
    `take_batch()`. Virtual time: every timestamp is microseconds on the
    server's simulated clock."""

    def __init__(self, cfg: Optional[AdmissionConfig] = None):
        self.cfg = cfg or AdmissionConfig(policy="none")
        self.pending: Deque[Tuple[float, int, int]] = deque()
        self._tokens = float(self.cfg.burst)
        self._last_refill = 0.0
        self.offered = 0
        self.admitted = 0            # net of shed-oldest revocations, so
        #                              offered == admitted + shed always
        self.shed_rate = 0           # shed by the token bucket
        self.shed_queue = 0          # shed by the bounded queue
        # keyed by tenant id, like every other per-tenant structure in the
        # stack — ids may be sparse (an unpartitioned cache accepts any)
        self.t_offered = Counter()
        self.t_admitted = Counter()
        self.t_shed = Counter()

    @property
    def shed(self) -> int:
        return self.shed_rate + self.shed_queue

    def _take_token(self, t_us: float) -> bool:
        """Refill the bucket up to time `t_us` and try to take one token.
        Arrivals must be offered in non-decreasing time order."""
        if self.cfg.rate_qps <= 0:
            return True
        self._tokens = min(
            float(self.cfg.burst),
            self._tokens
            + (t_us - self._last_refill) * self.cfg.rate_qps * 1e-6)
        self._last_refill = t_us
        if self._tokens < 1.0:
            return False
        self._tokens -= 1.0
        return True

    def offer(self, t_us: float, item: int, tenant: int = 0,
              executor_idle: bool = False) -> bool:
        """Admission decision for one arrival at virtual time `t_us`.
        Returns whether the arrival was admitted (it may still be revoked
        later by a shed-oldest drop). `executor_idle` tells the controller
        the batch executor has no work in flight, which is what makes the
        idle-system bypass at queue_cap=0 well defined."""
        self.offered += 1
        self.t_offered[tenant] += 1
        if not self._take_token(t_us):
            self.shed_rate += 1
            self.t_shed[tenant] += 1
            return False
        cfg = self.cfg
        queue_bound = cfg.policy in ("reject", "shed-oldest")
        if queue_bound and len(self.pending) >= cfg.queue_cap \
                and not (executor_idle and not self.pending):
            if cfg.policy == "reject" or not self.pending:
                # nothing older to shed at queue_cap=0: shed the arrival
                self.shed_queue += 1
                self.t_shed[tenant] += 1
                return False
            _, _, old_tenant = self.pending.popleft()
            self.shed_queue += 1
            self.admitted -= 1
            self.t_shed[old_tenant] += 1
            self.t_admitted[old_tenant] -= 1
        self.pending.append((t_us, item, tenant))
        self.admitted += 1
        self.t_admitted[tenant] += 1
        return True

    def pressure_level(self) -> int:
        """Degrade level from queue occupancy at dispatch: occupancy below
        `queue_cap` is level 0 (full-quality search), each further
        `queue_cap` of backlog steps one level down the ladder. Always 0
        for non-degrade policies."""
        if self.cfg.policy != "degrade":
            return 0
        cap = max(self.cfg.queue_cap, 1)
        return min(len(self.cfg.degrade_levels) - 1,
                   len(self.pending) // cap)

    def take_batch(self, max_batch: int) -> list:
        """Pop up to `max_batch` oldest pending entries for dispatch."""
        return [self.pending.popleft()
                for _ in range(min(max_batch, len(self.pending)))]

    def per_tenant_rows(self) -> dict:
        """{tenant: {offered, admitted, shed}} for every tenant that saw
        traffic — the admission half of the per-tenant report."""
        return {t: {"offered": o, "admitted": self.t_admitted[t],
                    "shed": self.t_shed[t]}
                for t, o in sorted(self.t_offered.items())}
