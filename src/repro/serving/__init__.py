from repro.serving.admission import (ADMISSION_POLICIES, AdmissionConfig,
                                     AdmissionController)
from repro.serving.ann_server import (AnnServer, OpenLoopReport, ServerConfig,
                                      ServingReport)

__all__ = ["ADMISSION_POLICIES", "AdmissionConfig", "AdmissionController",
           "AnnServer", "OpenLoopReport", "ServerConfig", "ServingReport"]
