from repro.serving.ann_server import AnnServer, ServerConfig, ServingReport

__all__ = ["AnnServer", "ServerConfig", "ServingReport"]
