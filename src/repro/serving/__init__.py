from repro.serving.admission import (ADMISSION_POLICIES, AdmissionConfig,
                                     AdmissionController)
from repro.serving.ann_server import (AnnServer, OpenLoopReport, ServerConfig,
                                      ServingReport)
from repro.serving.fleet import (ROUTING_POLICIES, AutoscaleConfig,
                                 FleetConfig, FleetReport, FleetServer,
                                 MigrationConfig)

__all__ = ["ADMISSION_POLICIES", "AdmissionConfig", "AdmissionController",
           "AnnServer", "AutoscaleConfig", "FleetConfig", "FleetReport",
           "FleetServer", "MigrationConfig", "OpenLoopReport",
           "ROUTING_POLICIES", "ServerConfig", "ServingReport"]
