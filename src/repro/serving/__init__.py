from repro.serving.ann_server import (AnnServer, OpenLoopReport, ServerConfig,
                                      ServingReport)

__all__ = ["AnnServer", "OpenLoopReport", "ServerConfig", "ServingReport"]
