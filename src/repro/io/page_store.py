"""I/O layer: the PageStore contract and its three implementations.

A `PageStore` is the only thing the kernel/serving layers know about the
"disk": it hands out page records on `fetch`, exposes the raw page arrays
the jitted kernel indexes (`kernel_arrays`), and keeps read/hit counters so
every layer accounts I/O through one object instead of ad-hoc fields.

  ArrayPageStore    — base store over a PageLayout's arrays (the simulated
                      SSD; every fetched page is a charged read).
  CachedPageStore   — decorator carrying the vertex cache mask (§4.1.2):
                      fetches for cached vertices are memory hits, and the
                      mask is what the kernel consumes to zero-charge
                      frontier reads of cached vertices.
  BatchedPageStore  — decorator that coalesces duplicate page requests
                      across the queries of a batch (cross-query dedup) —
                      the I/O reduction per-query accounting cannot express
                      and the serving layer's batch scheduler relies on.

The contract (duck-typed; see PageStore Protocol):
  fetch(page_ids, vids=None) -> dict(vids, vecs, nbrs)   [+ counters moving]
  charge(page_ids)        — accounting-only device reads (no records built):
                            every id is one read already past any dedup, so
                            each layer books it 1:1 and forwards down — the
                            conservation spine that keeps decorator counters
                            equal to inner movement on replay/coalesce paths
  note_write(page_ids=, kind=, count=) — device page WRITES (data pages by
                            id; journal/snapshot traffic count-only): each
                            layer books 1:1 and forwards down, keeping
                            pages_written == data_writes + journal_writes
                            + snapshot_writes at every layer (the write
                            half of the conservation spine)
  kernel_arrays() -> (page_vids, page_vecs, page_nbrs, vid2page, vid2slot)
  vertex_cache_mask() -> (n,) bool
  note_kernel_io(stats)   — fold kernel-measured reads/hits into counters
  counters: StoreCounters
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro import sanitize


@dataclasses.dataclass
class StoreCounters:
    pages_requested: int = 0   # pages callers asked for
    pages_fetched: int = 0     # pages actually charged to the device
    cache_hits: int = 0        # requests served from memory
    records_fetched: int = 0   # records moved (pages_fetched * n_p)
    pages_written: int = 0     # total device page writes (the sum of the
    #                            three kinds below — the write-conservation
    #                            invariant every layer keeps)
    data_writes: int = 0       # in-place page rewrites (flush/compaction)
    journal_writes: int = 0    # write-ahead journal commits (sequential)
    snapshot_writes: int = 0   # snapshot checkpoint pages (sequential)

    def __setattr__(self, name: str, value) -> None:
        # REPRO_SANITIZE=1: counters only count — non-negative and monotone
        # (reset() bypasses via object.__setattr__). A decrement means some
        # layer un-booked I/O, which the conservation property tests can
        # only catch after the fact; this catches it at the exact line.
        if sanitize.enabled():
            old = self.__dict__.get(name)
            sanitize.check(
                value >= 0,
                f"counter {name} set to negative value {value}")
            sanitize.check(
                old is None or value >= old,
                f"counter {name} moved backward: {old} -> {value}")
        object.__setattr__(self, name, value)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            object.__setattr__(self, f.name, 0)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def fetch_mirroring_inner(counters: StoreCounters, inner, page_ids,
                          vids) -> dict:
    """Forward a vertex-granular fetch to `inner`, mirroring its full
    counter movement (pages charged, hits served, records moved) into
    `counters` — the one idiom every pass-through decorator uses, so
    savings() and counter rollups agree across the stack."""
    c = inner.counters
    b_fetched, b_hits, b_recs = (c.pages_fetched, c.cache_hits,
                                 c.records_fetched)
    out = inner.fetch(page_ids, vids=vids)
    counters.pages_fetched += c.pages_fetched - b_fetched
    counters.cache_hits += c.cache_hits - b_hits
    counters.records_fetched += c.records_fetched - b_recs
    return out


def book_charged_reads(counters: StoreCounters, n_pages: int,
                       n_p: int) -> None:
    """Book `n_pages` accounting-only device reads (already past any dedup
    or cache decision) into `counters` — the shared body of every layer's
    `charge`."""
    counters.pages_requested += n_pages
    counters.pages_fetched += n_pages
    counters.records_fetched += n_pages * n_p


#: StoreCounters per-kind write fields, keyed by note_write(kind=).
WRITE_KINDS = ("data", "journal", "snapshot")


def book_writes(counters: StoreCounters, n_pages: int, kind: str) -> None:
    """Book `n_pages` device page writes of `kind` into `counters` — the
    shared body of every layer's `note_write`, keeping the invariant
    pages_written == data_writes + journal_writes + snapshot_writes at
    each layer (the WRITE half of the conservation spine `charge` keeps
    for reads)."""
    if kind not in WRITE_KINDS:
        raise ValueError(f"unknown write kind {kind!r}; one of "
                         f"{WRITE_KINDS}")
    counters.pages_written += n_pages
    setattr(counters, f"{kind}_writes",
            getattr(counters, f"{kind}_writes") + n_pages)
    # write conservation holds again at the end of every booking (it is
    # transiently broken between the two bumps above, so the check lives
    # here, not in __setattr__)
    sanitize.check_counters(counters)


def resolve_write(page_ids, count: Optional[int]) -> tuple:
    """Normalize a note_write call: data writes name their pages
    (`page_ids`), journal/snapshot writes are count-only sequential
    traffic (`count=`). Returns (page_ids array or None, n_pages)."""
    if count is not None:
        if page_ids is not None:
            raise ValueError("note_write takes page_ids OR count, not both")
        if count < 0:
            raise ValueError(f"count={count} must be >= 0")
        return None, int(count)
    if page_ids is None:
        raise ValueError("note_write needs page_ids (data writes) or "
                         "count= (sequential journal/snapshot writes)")
    pages = np.asarray(list(page_ids), np.int64).reshape(-1)
    return pages, len(pages)


def note_inner_writes(inner, page_ids, kind: str, count: int) -> None:
    """Forward a write booking down the spine, tolerating stores below a
    legacy/foreign stack that carry no write books."""
    if hasattr(inner, "note_write"):
        if page_ids is not None:
            inner.note_write(page_ids, kind=kind)
        else:
            inner.note_write(kind=kind, count=count)


def charge_inner_reads(inner, page_ids) -> None:
    """Charge `page_ids` to `inner` as device reads, preferring its
    accounting-only `charge` path. The fallback (a store without `charge`)
    issues `fetch` in rounds of unique ids so a coalescing store cannot
    dedup a genuine re-read: a page evicted and missed again IS two device
    reads, and conservation demands every layer book both."""
    if len(page_ids) == 0:
        return
    if hasattr(inner, "charge"):
        inner.charge(np.asarray(page_ids, np.int64).reshape(-1))
        return
    counts = {}
    for p in page_ids:
        counts[int(p)] = counts.get(int(p), 0) + 1
    while counts:
        inner.fetch(np.fromiter(counts.keys(), np.int64, len(counts)))
        counts = {p: c - 1 for p, c in counts.items() if c > 1}


@runtime_checkable
class PageStore(Protocol):
    """Anything that can serve pages to the kernel and serving layers."""

    counters: StoreCounters

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict: ...

    def charge(self, page_ids: np.ndarray) -> None: ...

    def kernel_arrays(self) -> tuple: ...

    def vertex_cache_mask(self) -> np.ndarray: ...

    def note_kernel_io(self, stats) -> None: ...


class ArrayPageStore:
    """Base store: a PageLayout's arrays stand in for the SSD. Every page in
    `fetch` is one charged read (callers dedup; see BatchedPageStore)."""

    def __init__(self, layout):
        self.layout = layout
        self.counters = StoreCounters()
        self._kernel_cache: Optional[tuple] = None

    @property
    def num_pages(self) -> int:
        return self.layout.num_pages

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        if np.any((page_ids < 0) | (page_ids >= self.layout.num_pages)):
            raise IndexError("page id out of range")
        self.counters.pages_requested += len(page_ids)
        self.counters.pages_fetched += len(page_ids)
        self.counters.records_fetched += len(page_ids) * self.layout.n_p
        return {"vids": self.layout.page_vids[page_ids],
                "vecs": self.layout.page_vecs[page_ids],
                "nbrs": self.layout.page_nbrs[page_ids]}

    def charge(self, page_ids: np.ndarray) -> None:
        """Accounting-only reads: same counter movement as `fetch`, no
        record materialization (the serving hot path's replay/coalesce
        charges are pure accounting — the kernel already holds the page
        arrays)."""
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        if np.any((page_ids < 0) | (page_ids >= self.layout.num_pages)):
            raise IndexError("page id out of range")
        book_charged_reads(self.counters, len(page_ids), self.layout.n_p)

    def note_write(self, page_ids=None, *, kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Book device page writes at the bottom of the spine: data writes
        name their (range-checked) pages, journal/snapshot writes are
        count-only sequential traffic appended past the page space."""
        pages, n = resolve_write(page_ids, count)
        if pages is not None and len(pages) and (
                pages.min() < 0 or pages.max() >= self.layout.num_pages):
            raise IndexError("page id out of range")
        book_writes(self.counters, n, kind)

    def kernel_arrays(self) -> tuple:
        if self._kernel_cache is None:
            lay = self.layout
            self._kernel_cache = tuple(jnp.asarray(a) for a in (
                lay.page_vids, lay.page_vecs, lay.page_nbrs,
                lay.vid2page, lay.vid2slot))
        return self._kernel_cache

    def vertex_cache_mask(self) -> np.ndarray:
        return np.zeros(self.layout.vid2page.shape[0], bool)

    def note_kernel_io(self, stats) -> None:
        pages = int(stats.page_reads.sum())
        self.counters.pages_requested += pages
        self.counters.pages_fetched += pages
        self.counters.records_fetched += int(stats.n_read_records.sum())


class CachedPageStore:
    """Decorator: a vertex cache mask in front of an inner store. A fetch
    that names its requesting vertices (`vids`) serves cached vertices from
    memory (hits) and forwards only the rest; the same mask is exported to
    the kernel, which zero-charges frontier reads of cached vertices."""

    def __init__(self, inner, cached_vertices: np.ndarray):
        self.inner = inner
        self.cached_vertices = np.asarray(cached_vertices, bool)
        self.counters = StoreCounters()

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        self.counters.pages_requested += len(page_ids)
        if vids is None:
            self.counters.pages_fetched += len(page_ids)
            self.counters.records_fetched += len(page_ids) * self.layout.n_p
            return self.inner.fetch(page_ids)
        vids = np.asarray(vids, np.int64).reshape(-1)
        hit = self.cached_vertices[vids]
        self.counters.cache_hits += int(hit.sum())
        self.counters.pages_fetched += int((~hit).sum())
        self.counters.records_fetched += int((~hit).sum()) * self.layout.n_p
        out = self.inner.fetch(page_ids[~hit])
        # cached vertices' records come from memory: single-record "pages"
        lay = self.layout
        hv = vids[hit]
        out["cached_vids"] = hv.astype(np.int32)
        out["cached_vecs"] = lay.page_vecs[lay.vid2page[hv], lay.vid2slot[hv]]
        out["cached_nbrs"] = lay.page_nbrs[lay.vid2page[hv], lay.vid2slot[hv]]
        return out

    def charge(self, page_ids: np.ndarray) -> None:
        """Accounting-only reads already past any cache decision above:
        book 1:1 and forward, so this layer's movement mirrors the inner
        store's."""
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        book_charged_reads(self.counters, len(page_ids), self.layout.n_p)
        self.inner.charge(page_ids)

    def note_write(self, page_ids=None, *, kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Write bookings pass the cache untouched (the vertex mask is a
        READ shortcut): book 1:1 and forward down the spine."""
        pages, n = resolve_write(page_ids, count)
        book_writes(self.counters, n, kind)
        note_inner_writes(self.inner, pages, kind, n)

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.cached_vertices

    def note_kernel_io(self, stats) -> None:
        self.counters.cache_hits += int(stats.cache_hits.sum())
        pages = int(stats.page_reads.sum())
        self.counters.pages_requested += pages
        self.counters.pages_fetched += pages
        self.inner.note_kernel_io(stats)


class BatchedPageStore:
    """Decorator: coalesce duplicate page requests across the queries of a
    batch. `fetch` dedups a flat request list; `fetch_for_queries` takes
    per-query charged-page bitmaps (QueryStats.visited_pages) and issues the
    union once — the cross-query I/O reduction the paper's per-query
    accounting cannot express. `savings()` reports requested - issued."""

    def __init__(self, inner):
        self.inner = inner
        self.counters = StoreCounters()

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        self.counters.pages_requested += len(page_ids)
        if vids is not None:
            # vertex-granular requests can name several records on one page,
            # so page coalescing doesn't apply — pass through to the inner
            # store (which may serve cache hits) uncoalesced
            return fetch_mirroring_inner(self.counters, self.inner,
                                         page_ids, vids)
        uniq, inv = np.unique(page_ids, return_inverse=True)
        self.counters.pages_fetched += len(uniq)
        out = self.inner.fetch(uniq)
        # scatter back so callers see one record-set per requested page
        return {k: v[inv] for k, v in out.items()}

    def fetch_for_queries(self, visited_pages: np.ndarray) -> dict:
        """visited_pages: (B, num_pages) bool per-query charged-page bitmaps.
        Issues the cross-query union once; returns the union's records plus
        the accounting from coalesce()."""
        acct = self.coalesce(visited_pages)
        union = np.flatnonzero(np.asarray(visited_pages, bool).any(axis=0))
        out = self.inner.fetch(union)
        out.update(acct)
        return out

    def coalesce(self, visited_pages: np.ndarray) -> dict:
        """Accounting-only variant of fetch_for_queries for the serving hot
        path: moves the same counters but skips materializing the union's
        records (the kernel already holds the page arrays, so re-copying
        vectors/neighbors per batch would be pure waste). The union IS
        charged to the inner store (`charge`), so cross-stack counter
        rollups stay conserved on the record-free path too."""
        visited_pages = np.asarray(visited_pages, bool)
        union = np.flatnonzero(visited_pages.any(axis=0))
        requested = int(visited_pages.sum())
        issued = len(union)
        self.counters.pages_requested += requested
        self.counters.pages_fetched += issued
        self.counters.records_fetched += issued * self.layout.n_p
        charge_inner_reads(self.inner, union)
        return {"requested": requested, "issued": issued}

    def savings(self) -> int:
        return self.counters.pages_requested - self.counters.pages_fetched

    def charge(self, page_ids: np.ndarray) -> None:
        """Accounting-only reads from a layer above (shared-cache replay,
        sharded stores): already past any coalescing decision, so they pass
        through uncoalesced — a cache miss re-issued after eviction is a
        genuine second device read."""
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        book_charged_reads(self.counters, len(page_ids), self.layout.n_p)
        self.inner.charge(page_ids)

    def note_write(self, page_ids=None, *, kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Writes never coalesce (each rewritten page is one device write
        past any dedup decision): book 1:1 and forward down the spine."""
        pages, n = resolve_write(page_ids, count)
        book_writes(self.counters, n, kind)
        note_inner_writes(self.inner, pages, kind, n)

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.inner.vertex_cache_mask()

    def note_kernel_io(self, stats) -> None:
        # kernel-internal reads are per-query; batching accounts its own
        # fetches in fetch_for_queries, so only forward to the inner store
        self.inner.note_kernel_io(stats)


def build_store(layout, cached_vertices: Optional[np.ndarray] = None,
                batched: bool = False, *, cache_policy: str = "none",
                cache_bytes: int = 0, prefetch: int = 0, tenants: int = 1,
                tenant_shares=None, rebalance_every: int = 0,
                shards: int = 1, placement: str = "round-robin",
                page_profile: Optional[np.ndarray] = None,
                placement_hot_frac: float = 0.25, mutable: bool = False,
                journal=None, crash=None):
    """Compose the store stack for an index. Bottom-up:

      ArrayPageStore                          (always — the simulated SSD)
      CachedPageStore                         cache_policy="static-vertex",
                                              or legacy `cached_vertices=`
      BatchedPageStore                        batched=True
      SharedCachePageStore / Prefetching...   cache_policy in DYNAMIC_POLICIES
                                              ("lru" | "fifo" | "2q"), sized
                                              by `cache_bytes`; `prefetch` > 0
                                              selects the look-ahead variant
      ShardedPageStore                        shards > 1: the page space
                                              split across S devices by
                                              `placement` (PLACEMENTS), the
                                              dynamic cache (if any) split
                                              into per-shard slices of the
                                              same `cache_bytes` budget —
                                              tenant-partitioned per shard
                                              when `tenants > 1`, with
                                              `prefetch` look-ahead issued
                                              against the owning shard's
                                              queue

    The static vertex mask (§4.1.2) is now just one policy of the cache
    subsystem: "static-vertex" requires `cached_vertices`; passing
    `cached_vertices` with the default policy keeps composing it (the
    pre-refactor surface). The stateful policies sit ABOVE the batch
    coalescer — their state outlives the batch boundary.

    `tenants > 1` partitions the SAME `cache_bytes` budget across tenants
    (PartitionedPageCache: static `tenant_shares` plus utility rebalance
    every `rebalance_every` accesses when set); replay callers then pass
    per-query tenant ids so each query charges its own partition.

    `shards > 1` replaces the single-device stateful top with a
    `ShardedPageStore`: placement "replicated" additionally needs
    `page_profile` (per-page access counts — `profile_from_trace` offline,
    or `profile_from_counters` from a live store's read counters). All
    three axes compose: `tenants > 1` makes each shard's cache slice a
    per-tenant partition, and `prefetch > 0` issues look-ahead against the
    owning shard's queue (both still need a dynamic `cache_policy` to hold
    the state, same as on one device).

    `mutable=True` wraps the finished stack in a `MutablePageStore`
    (repro/mutation/mutable_store.py): page-version tracking plus cache
    invalidation on rewrite, the store-side half of the streaming-update
    subsystem. `journal=` (a repro.mutation.MutationJournal) arms its
    two-phase write protocol — every data-page write is preceded by a
    synced intent record — and `crash=` (a repro.mutation.CrashPoint)
    injects a kill at a numbered I/O boundary; both require
    `mutable=True` (a frozen stack never writes). Every knob that only configures a subordinate layer is
    validated here: a silently ignored `cache_bytes`/`tenant_shares`/
    `rebalance_every`/`placement` is an accounting bug waiting to be
    measured, so unsupported compositions raise one error naming the
    combination instead."""
    from repro.io.page_cache import (DYNAMIC_POLICIES, PrefetchingPageStore,
                                     SharedCachePageStore, make_cache)
    from repro.io.sharded_store import (ShardedPageStore, make_placement,
                                        make_shard_caches)
    known = ("none", "static-vertex") + DYNAMIC_POLICIES
    if cache_policy not in known:
        raise ValueError(f"unknown cache_policy {cache_policy!r}; "
                         f"choose from {known}")
    if cache_policy == "static-vertex" and cached_vertices is None:
        raise ValueError(
            "cache_policy='static-vertex' needs `cached_vertices` (the "
            "vertex mask IS the policy's state)")
    if cache_bytes > 0 and cache_policy not in DYNAMIC_POLICIES:
        raise ValueError(
            f"cache_bytes={cache_bytes} with cache_policy="
            f"{cache_policy!r} configures no store: a byte budget only "
            f"sizes the stateful policies {DYNAMIC_POLICIES} — set one, or "
            f"drop cache_bytes")
    if prefetch < 0:
        raise ValueError(f"prefetch={prefetch} must be >= 0")
    if prefetch and cache_policy not in DYNAMIC_POLICIES:
        raise ValueError(
            f"prefetch={prefetch} needs a stateful cache_policy "
            f"{DYNAMIC_POLICIES} to hold the looked-ahead pages")
    if tenants < 1:
        raise ValueError(f"tenants={tenants} must be >= 1")
    if tenants == 1 and tenant_shares is not None:
        raise ValueError(
            "tenant_shares with tenants=1 splits nothing — one tenant owns "
            "the whole budget; set tenants > 1 or drop tenant_shares")
    if tenants == 1 and rebalance_every:
        raise ValueError(
            f"rebalance_every={rebalance_every} with tenants=1 has no "
            f"partitions to rebalance — set tenants > 1 or drop "
            f"rebalance_every")
    if shards == 1 and placement != "round-robin":
        raise ValueError(
            f"placement={placement!r} with shards=1 places nothing — a "
            f"single device has no placement decision; set shards > 1 or "
            f"leave placement at its default")
    if tenants > 1 and cache_policy not in DYNAMIC_POLICIES:
        raise ValueError(
            f"tenants={tenants} partitions a stateful page cache — set "
            f"cache_policy to one of {DYNAMIC_POLICIES}")
    if shards < 1:
        raise ValueError(f"shards={shards} must be >= 1")
    store = ArrayPageStore(layout)
    if cached_vertices is not None and cached_vertices.any():
        store = CachedPageStore(store, cached_vertices)
    if batched:
        store = BatchedPageStore(store)
    if shards > 1:
        pl = make_placement(placement, layout.num_pages, shards,
                            profile=page_profile,
                            hot_frac=placement_hot_frac)
        caches = (make_shard_caches(cache_policy, cache_bytes,
                                    layout.page_bytes, shards,
                                    tenants=tenants,
                                    tenant_shares=tenant_shares,
                                    rebalance_every=rebalance_every)
                  if cache_policy in DYNAMIC_POLICIES else None)
        store = ShardedPageStore(store, pl, caches, lookahead=prefetch)
    elif cache_policy in DYNAMIC_POLICIES:
        cache = make_cache(cache_policy, cache_bytes, layout.page_bytes,
                           tenants=tenants, tenant_shares=tenant_shares,
                           rebalance_every=rebalance_every)
        store = (PrefetchingPageStore(store, cache, lookahead=prefetch)
                 if prefetch > 0 else SharedCachePageStore(store, cache))
    if not mutable and (journal is not None or crash is not None):
        raise ValueError(
            "journal=/crash= configure the MutablePageStore's two-phase "
            "write protocol — set mutable=True (a frozen stack never "
            "writes, so there is nothing to journal or crash)")
    if mutable:
        from repro.mutation.mutable_store import MutablePageStore
        store = MutablePageStore(store, journal=journal, crash=crash)
    return store
