"""I/O layer: the sharded PageStore — the page space partitioned across S
simulated NVMe devices.

Past one device's saturation point the only way to keep pushing the
throughput frontier is more devices (the §8 concurrency guideline at the
multi-device scale disk-ANN systems are actually compared at), and the
page is the natural sharding unit of a page-aligned layout. This module
adds the distributed half of the store stack:

  Placement         — a page -> shard map plus a replicated-page mask; the
                      routing decision every sharded access goes through.
  make_placement    — the pluggable policies:
        round-robin   page p lives on shard p % S (balanced by id).
        contiguous    equal contiguous ranges (locality-preserving — and
                      deliberately the worst case when the workload's hot
                      pages share a range: they all land on one device).
        replicated    round-robin base placement, plus the top-k hottest
                      pages of a `page_trace` profile replicated on EVERY
                      shard; a replicated access routes to the least-loaded
                      shard of the batch, so a skewed workload's hot set
                      stops pinning one device.
  profile_from_trace — per-page access counts from a (B, hops, w) trace,
                      the profile `replicated` ranks by (offline seeding).
  profile_from_counters — the same profile from a LIVE store's per-page
                      issued-read counters (`ShardedPageStore.
                      page_read_counts`), so the hot set can be seeded or
                      re-ranked online, mid-serve, with no offline trace —
                      the cold-start path for "replicated" and the window
                      signal hot-page migration re-ranks on.
  ShardedPageStore  — decorator: each shard owns its own device queue
                      accounting, `StoreCounters`, and (optionally) its own
                      slice of ONE shared byte-budgeted page-cache budget —
                      tenant-partitioned per shard when the budget is
                      multi-tenant, with `lookahead > 0` issuing LAANN-style
                      prefetch against the owning shard's queue.

The fleet extensions (PR 7)
---------------------------
Three compositions that used to be rejected now land here: (1) per-shard
caches may be `PartitionedPageCache` slices (shard x tenant: each shard's
budget slice is itself split per tenant, so isolation holds on every
device); (2) `lookahead > 0` replays the trace with look-ahead — a hop's
future pages are admitted into (and charged on) the shard that OWNS them
before the demand access arrives, and the issued volume is reported as
`prefetch_issued`/`overlap_frac` for the device model's overlap rebate;
(3) `set_replicated(mask)` swaps the replicated hot set IN PLACE, the
store-side half of online hot-page migration (the serving layer bills the
copy I/O and invalidates stale residency via MutablePageStore).

The device-time contract
------------------------
A batch's device time is the MAX over per-shard completion times: shards
serve in parallel, so a query completes when its slowest shard does.
`replay_batch`/`coalesce` therefore return, beyond the flat accounting
every store returns, `per_query_shard_pages` ((B, S): the pages each query
charged on each shard) and `shard_depths` ((S,): queries with work on that
shard) — exactly the arguments `SSDModel.concurrent_latency_us(shard_pages=,
shard_depths=)` turns into the max-over-shards I/O term. An imbalanced
placement is visibly slower than a balanced one at equal total pages, which
is the whole point of measuring placement policies.

Counter conservation: every issued read is charged to the owning shard's
`StoreCounters`, to the roll-up `counters`, and forwarded down the stack
via the accounting-only `charge` path, so `pages_requested == cache_hits +
pages_fetched` holds at this layer and the decorator's movement mirrors the
inner store's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.io.page_cache import (POLICIES, PageCache, PartitionedPageCache,
                                 floor_capacity_pages)
from repro.io.page_store import (StoreCounters, book_charged_reads,
                                 book_writes, charge_inner_reads,
                                 fetch_mirroring_inner, note_inner_writes,
                                 resolve_write)

#: build_store() / ServerConfig placement policy names.
PLACEMENTS = ("round-robin", "contiguous", "replicated")


@dataclasses.dataclass(frozen=True)
class Placement:
    """A page -> shard assignment. `page_to_shard` fixes every page's home;
    pages with `replicated[p]` set are resident on EVERY shard and route
    per access to the least-loaded shard (`route`)."""

    name: str
    shards: int
    page_to_shard: np.ndarray   # (num_pages,) int64
    replicated: np.ndarray      # (num_pages,) bool

    def route(self, page: int, shard_loads: np.ndarray) -> int:
        """Shard serving this access; `shard_loads` is the batch's running
        per-shard issued-read count (the load-balance signal a replicated
        page's routing trades on)."""
        if self.replicated[page]:
            return int(np.argmin(shard_loads))
        return int(self.page_to_shard[page])

    def describe(self) -> dict:
        counts = np.bincount(self.page_to_shard, minlength=self.shards)
        return {"policy": self.name, "shards": self.shards,
                "pages_per_shard": counts.tolist(),
                "replicated_pages": int(self.replicated.sum())}

    def extend(self, num_pages: int) -> "Placement":
        """Placement for a GROWN page space (streaming updates append
        pages): existing homes are kept, appended pages are assigned
        round-robin starting from the currently lightest shard (whatever
        the base policy — the append zone has no profile to place by), and
        none are replicated. Returns a new Placement; the original is
        frozen."""
        old = len(self.page_to_shard)
        if num_pages < old:
            raise ValueError(
                f"cannot shrink a placement: {num_pages} < {old} pages")
        if num_pages == old:
            return self
        counts = np.bincount(self.page_to_shard, minlength=self.shards)
        start = int(np.argmin(counts))
        extra = (start + np.arange(num_pages - old)) % self.shards
        return dataclasses.replace(
            self,
            page_to_shard=np.concatenate([self.page_to_shard, extra]),
            replicated=np.concatenate(
                [self.replicated, np.zeros(num_pages - old, bool)]))


def profile_from_trace(page_trace: np.ndarray, num_pages: int) -> np.ndarray:
    """Per-page access counts from a (B, hops, w) `page_trace` (-1 padded)
    — the hotness profile the `replicated` placement ranks by."""
    trace = np.asarray(page_trace)
    flat = trace[trace >= 0].astype(np.int64)
    if len(flat) and int(flat.max()) >= num_pages:
        raise ValueError(
            f"trace names page {int(flat.max())} beyond num_pages={num_pages}")
    return np.bincount(flat, minlength=num_pages)


def profile_from_counters(store) -> np.ndarray:
    """Per-page access counts from a LIVE sharded store's own counters
    (`ShardedPageStore.page_read_counts`: every page-routed issued read,
    accumulated across the store's lifetime) — the ONLINE twin of
    `profile_from_trace`. This is how a "replicated" placement escapes its
    cold start: serve a warm-up window under any placement, rank the hot
    set from what the devices actually read, and re-place — no offline
    trace required. Hot-page migration re-ranks on successive deltas of
    this profile. Returns a copy; the live counters keep counting."""
    counts = getattr(store, "page_read_counts", None)
    if counts is None:
        raise ValueError(
            "profile_from_counters needs a store that tracks live per-page "
            "read counts (ShardedPageStore.page_read_counts) — build one "
            "with build_store(shards=...), or rank an offline trace with "
            "profile_from_trace instead")
    return np.asarray(counts, np.int64).copy()


def make_placement(policy: str, num_pages: int, shards: int, *,
                   profile: Optional[np.ndarray] = None,
                   hot_frac: float = 0.25,
                   hot_pages: Optional[int] = None) -> Placement:
    """Build a placement. `replicated` needs a per-page access `profile`
    (see `profile_from_trace`); the hot set is the top `hot_pages` pages by
    count (default: `hot_frac` of the page space), restricted to pages the
    profile actually saw.

    A missing profile is an ERROR here, deliberately: a caller composing a
    store by hand configured "replicated" on purpose and must supply the
    data it ranks by. The serving layer, where a `page_profile=None`
    default can legitimately flow in, instead falls back to round-robin
    with an explicit warning (AnnServer.__init__) — never silently."""
    if shards < 1:
        raise ValueError(f"shards={shards} must be >= 1")
    if num_pages < 1:
        raise ValueError(f"num_pages={num_pages} must be >= 1")
    if policy not in PLACEMENTS:
        raise ValueError(f"unknown placement {policy!r}; "
                         f"choose from {PLACEMENTS}")
    pages = np.arange(num_pages, dtype=np.int64)
    replicated = np.zeros(num_pages, bool)
    if policy == "contiguous":
        span = -(-num_pages // shards)           # ceil division
        p2s = np.minimum(pages // span, shards - 1)
    else:
        p2s = pages % shards
    if policy == "replicated":
        if profile is None:
            raise ValueError(
                "placement='replicated' needs a per-page access `profile` "
                "(profile_from_trace over a page_trace) to rank hotness")
        profile = np.asarray(profile, np.int64).reshape(-1)
        if len(profile) != num_pages:
            raise ValueError(
                f"profile has {len(profile)} entries for {num_pages} pages")
        k = hot_pages if hot_pages is not None else max(
            1, int(round(hot_frac * num_pages)))
        if k < 1:
            raise ValueError(f"hot_pages={k} must be >= 1")
        hot = np.argsort(profile, kind="stable")[::-1][:k]
        replicated[hot[profile[hot] > 0]] = True
    return Placement(policy, shards, p2s, replicated)


def make_shard_caches(policy: str, cache_bytes: int, page_bytes: int,
                      shards: int, *, tenants: int = 1,
                      tenant_shares=None,
                      rebalance_every: int = 0) -> List[PageCache]:
    """Split ONE byte budget into per-shard caches of `policy` (even split,
    1-page floor per shard) — the shard-local residency that keeps a hot
    shard's working set from competing with a cold shard's. With
    `tenants > 1` each shard's slice is itself a `PartitionedPageCache`
    (shard x tenant grid: the floor becomes one page per (shard, tenant)
    cell), so tenant isolation holds independently on every device and the
    utility rebalance runs per shard over that shard's own access stream."""
    if policy not in POLICIES:
        raise ValueError(f"unknown cache policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}")
    if tenants < 1:
        raise ValueError(f"tenants={tenants} must be >= 1")
    capacity = floor_capacity_pages(cache_bytes, page_bytes,
                                    shards * tenants,
                                    "shard x tenant cells")
    base, extra = divmod(capacity, shards)
    caps = [base + (1 if s < extra else 0) for s in range(shards)]
    if tenants == 1:
        return [POLICIES[policy](c) for c in caps]
    return [PartitionedPageCache(c, tenants, policy, shares=tenant_shares,
                                 rebalance_every=rebalance_every)
            for c in caps]


class ShardedPageStore:
    """Decorator: the page space partitioned across S simulated devices.
    Every access routes through the placement; each shard keeps its own
    `StoreCounters` (and, when `caches` is given, its own page cache), the
    roll-up lives in `counters`, and every issued read is forwarded to the
    inner store's accounting via `charge`. `replay_batch` (temporal trace,
    per-shard cache replay) and `coalesce` (order-free cross-query union)
    are the serving accounting paths — both return the per-shard split the
    device model's max-over-shards I/O term consumes."""

    def __init__(self, inner, placement: Placement,
                 caches: Optional[Sequence[PageCache]] = None,
                 lookahead: int = 0):
        if caches is not None and len(caches) != placement.shards:
            raise ValueError(
                f"{len(caches)} caches for {placement.shards} shards — "
                f"each shard owns exactly one")
        if lookahead < 0:
            raise ValueError(f"lookahead={lookahead} must be >= 0")
        if lookahead > 0 and caches is None:
            raise ValueError(
                "lookahead needs per-shard caches to hold the looked-ahead "
                "pages (a cacheless prefetch would charge reads it cannot "
                "keep)")
        self.inner = inner
        self.placement = placement
        self.shards = placement.shards
        self.caches = list(caches) if caches is not None else None
        self.lookahead = int(lookahead)
        # True when each shard cache is a PartitionedPageCache slice —
        # replay then routes accesses to (shard, tenant) cells
        self.tenant_aware = bool(self.caches) and all(
            getattr(c, "tenant_aware", False) for c in self.caches)
        self.shard_counters = [StoreCounters()
                               for _ in range(placement.shards)]
        self.counters = StoreCounters()
        self.accesses = 0
        self.prefetch_issued = 0
        # live per-page issued-read counts (profile_from_counters): the
        # online hotness signal replicated placement seeds / migration
        # re-ranks on. Counted at the routing point — every page-routed
        # DEVICE read, demand or prefetch; cache hits don't load a device
        # so they don't count toward the placement signal
        self.page_read_counts = np.zeros(inner.num_pages, np.int64)
        self.tenant_counters: Dict[int, Dict[str, int]] = {}

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    # -- PageStore protocol --------------------------------------------------

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        self.counters.pages_requested += len(page_ids)
        if vids is not None:
            # vertex-granular requests belong to the static-vertex layer
            # BELOW the shard abstraction — pass through, mirroring the
            # inner store's movement into the roll-up only (per-shard
            # counters cover page-routed traffic; see shard_rows)
            return fetch_mirroring_inner(self.counters, self.inner,
                                         page_ids, vids)
        loads = np.zeros(self.shards, np.int64)
        charged: List[int] = []
        n_p = self.layout.n_p
        for p in page_ids:
            p = int(p)
            s = self.placement.route(p, loads)
            sc = self.shard_counters[s]
            sc.pages_requested += 1
            self.accesses += 1
            # fetch() is tenant-blind (the protocol path carries no tenant);
            # partitioned shard caches default to partition 0
            hit = (self.caches[s].access(p)
                   if self.caches is not None else False)
            if hit:
                sc.cache_hits += 1
                self.counters.cache_hits += 1
            else:
                sc.pages_fetched += 1
                sc.records_fetched += n_p
                self.counters.pages_fetched += 1
                self.counters.records_fetched += n_p
                loads[s] += 1
                self.page_read_counts[p] += 1
                charged.append(p)
        charge_inner_reads(self.inner, charged)
        lay = self.layout
        return {"vids": lay.page_vids[page_ids],
                "vecs": lay.page_vecs[page_ids],
                "nbrs": lay.page_nbrs[page_ids]}

    def charge(self, page_ids: np.ndarray) -> None:
        """Accounting-only reads from a layer above: route to the owning
        shards (replicated pages balance on the charge's own load vector),
        book per shard + roll-up, forward down."""
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        loads = np.zeros(self.shards, np.int64)
        n_p = self.layout.n_p
        for p in page_ids:
            s = self.placement.route(int(p), loads)
            sc = self.shard_counters[s]
            book_charged_reads(sc, 1, n_p)
            loads[s] += 1
            self.page_read_counts[int(p)] += 1
        book_charged_reads(self.counters, len(page_ids), n_p)
        self.inner.charge(page_ids)

    def note_write(self, page_ids=None, *, kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Device page writes split by owning device: data writes land on
        each page's placement HOME (a rewrite must reach the authoritative
        copy; replica refresh is the migration layer's separate traffic),
        while count-only journal/snapshot writes are one sequential log
        stream and bill to shard 0 — the dedicated-log-device convention
        the serving layer's background clock shares. Booked per shard +
        roll-up, forwarded down the spine."""
        pages, n = resolve_write(page_ids, count)
        if pages is not None:
            homes = self.placement.page_to_shard[pages]
            for s, c in zip(*np.unique(homes, return_counts=True)):
                book_writes(self.shard_counters[int(s)], int(c), kind)
        elif n:
            book_writes(self.shard_counters[0], n, kind)
        book_writes(self.counters, n, kind)
        note_inner_writes(self.inner, pages, kind, n)

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.inner.vertex_cache_mask()

    def note_kernel_io(self, stats) -> None:
        # replay_batch / coalesce are this store's accounting paths
        self.inner.note_kernel_io(stats)

    # -- serving accounting paths --------------------------------------------

    def replay_batch(self, page_trace: np.ndarray,
                     tenants: Optional[np.ndarray] = None) -> dict:
        """Temporally ordered replay (QueryStats.page_trace) against the
        per-shard caches (a cold store with no caches charges every access).
        Tenant-partitioned shard caches route each access to the query's
        tenant cell on the owning shard; with `lookahead > 0` a hop's next
        `lookahead` hops' pages are admitted into the OWNING shard's cache
        before the hop's demand accesses (admit(), not access(): prefetch
        is not demand, so it moves no demand hit rates), charged on that
        shard and counted in `prefetch_issued`/`overlap_frac` for the
        device model's overlap rebate. Returns the SharedCachePageStore
        accounting contract plus the per-shard split:

          shard_requested / shard_hits / shard_issued   (S,) int
          per_query_shard_pages   (B, S) float64 — reads each query charged
                                  on each shard (feeds the max-over-shards
                                  device time)
          shard_depths            (S,) int — queries with >= 1 read on the
                                  shard (its device queue depth this batch)
        """
        trace = np.asarray(page_trace)
        if trace.ndim != 3:
            raise ValueError(
                f"page_trace must be (B, hops, w); got shape {trace.shape}")
        B, S = trace.shape[0], self.shards
        ta = self.tenant_aware
        if tenants is None:
            tns = np.zeros(B, np.int64)
        else:
            tns = np.asarray(tenants, np.int64).reshape(-1)
            if len(tns) != B:
                raise ValueError(
                    f"tenants has {len(tns)} entries for a {B}-query trace")
            if np.any(tns < 0):
                raise ValueError("tenant ids must be >= 0")
            if ta and len(tns) and \
                    int(tns.max()) >= self.caches[0].tenants:
                # validate BEFORE replaying: failing mid-loop would leave
                # the shard caches half-warmed by a rejected batch
                raise ValueError(
                    f"tenant id {int(tns.max())} out of range for "
                    f"{self.caches[0].tenants}-partition shard caches")
        per_query = np.zeros(B, np.float64)
        per_query_shard = np.zeros((B, S), np.float64)
        shard_req = np.zeros(S, np.int64)
        shard_hits = np.zeros(S, np.int64)
        shard_issued = np.zeros(S, np.int64)
        loads = np.zeros(S, np.int64)
        per_tenant: Dict[int, Dict[str, int]] = {
            int(t): {"requested": 0, "hits": 0, "issued": 0}
            for t in np.unique(tns)}
        requested = hits = issued = prefetched = 0
        charged: List[int] = []

        def resident(s: int, p: int, t: int) -> bool:
            return (p in self.caches[s].parts[t] if ta
                    else p in self.caches[s])

        for b in range(B):
            t = int(tns[b])
            tacct = per_tenant[t]
            hop_pages = [row[row >= 0] for row in trace[b]]
            for h, row in enumerate(hop_pages):
                if len(row) == 0:
                    continue
                # look-ahead against the OWNING shard's queue: the future
                # hop's page is admitted into — and gated on — the shard
                # (and tenant cell) the demand access will route to, so the
                # prefetch charge lands on the same device the demand read
                # would have
                for ahead in hop_pages[h + 1: h + 1 + self.lookahead]:
                    for p in ahead:
                        p = int(p)
                        s = self.placement.route(p, loads)
                        if resident(s, p, t):
                            continue
                        if ta:
                            self.caches[s].admit(p, t)
                        else:
                            self.caches[s].admit(p)
                        issued += 1
                        prefetched += 1
                        shard_issued[s] += 1
                        per_query[b] += 1
                        per_query_shard[b, s] += 1
                        loads[s] += 1
                        tacct["issued"] += 1
                        self.page_read_counts[p] += 1
                        charged.append(p)
                for p in row:
                    p = int(p)
                    s = self.placement.route(p, loads)
                    requested += 1
                    shard_req[s] += 1
                    tacct["requested"] += 1
                    if self.caches is None:
                        hit = False
                    elif ta:
                        hit = self.caches[s].access(p, t)
                    else:
                        hit = self.caches[s].access(p)
                    if hit:
                        hits += 1
                        shard_hits[s] += 1
                        tacct["hits"] += 1
                    else:
                        issued += 1
                        shard_issued[s] += 1
                        per_query[b] += 1
                        per_query_shard[b, s] += 1
                        loads[s] += 1
                        tacct["issued"] += 1
                        self.page_read_counts[p] += 1
                        charged.append(p)
        self.accesses += requested
        self.prefetch_issued += prefetched
        self.counters.pages_requested += requested
        self.counters.cache_hits += hits
        self.counters.pages_fetched += issued
        self.counters.records_fetched += issued * self.layout.n_p
        n_p = self.layout.n_p
        for s in range(S):
            sc = self.shard_counters[s]
            sc.pages_requested += int(shard_req[s])
            sc.cache_hits += int(shard_hits[s])
            sc.pages_fetched += int(shard_issued[s])
            sc.records_fetched += int(shard_issued[s]) * n_p
        for t, a in per_tenant.items():
            life = self.tenant_counters.setdefault(
                t, {"requested": 0, "hits": 0, "issued": 0})
            for k in life:
                life[k] += a[k]
            a["hit_rate"] = (a["hits"] / a["requested"]
                             if a["requested"] else 0.0)
        charge_inner_reads(self.inner, charged)
        return {"requested": requested, "issued": issued, "hits": hits,
                "per_query_issued": per_query,
                "prefetch_issued": prefetched,
                "overlap_frac": prefetched / issued if issued else 0.0,
                "hit_rate": hits / requested if requested else 0.0,
                "per_tenant": per_tenant,
                "shard_requested": shard_req, "shard_hits": shard_hits,
                "shard_issued": shard_issued,
                "per_query_shard_pages": per_query_shard,
                "shard_depths": (per_query_shard > 0).sum(axis=0)}

    def coalesce(self, visited_pages: np.ndarray) -> dict:
        """Order-free path (no per-shard caches needed): cross-query union
        per batch, split by shard. Each union page routes once (replicated
        pages balance on the union's load vector); a query's per-shard page
        count is its DISTINCT visited pages on that shard, so charges scale
        exactly like the single-device BatchedPageStore accounting."""
        visited = np.asarray(visited_pages, bool)
        if visited.ndim != 2:
            raise ValueError(
                f"visited_pages must be (B, num_pages); got {visited.shape}")
        B, S = visited.shape[0], self.shards
        union = np.flatnonzero(visited.any(axis=0))
        loads = np.zeros(S, np.int64)
        shard_of = np.empty(len(union), np.int64)
        for i, p in enumerate(union):
            s = self.placement.route(int(p), loads)
            shard_of[i] = s
            loads[s] += 1
        shard_issued = np.bincount(shard_of, minlength=S)
        if len(union):
            self.page_read_counts[union] += 1
        per_query_shard = np.zeros((B, S), np.float64)
        for i, p in enumerate(union):
            per_query_shard[visited[:, p], shard_of[i]] += 1
        requested = int(visited.sum())
        issued = len(union)
        shard_req = per_query_shard.sum(axis=0).astype(np.int64)
        self.counters.pages_requested += requested
        self.counters.pages_fetched += issued
        self.counters.records_fetched += issued * self.layout.n_p
        n_p = self.layout.n_p
        for s in range(S):
            sc = self.shard_counters[s]
            sc.pages_requested += int(shard_req[s])
            sc.pages_fetched += int(shard_issued[s])
            sc.records_fetched += int(shard_issued[s]) * n_p
        charge_inner_reads(self.inner, union)
        return {"requested": requested, "issued": issued, "hits": 0,
                "shard_requested": shard_req,
                "shard_hits": np.zeros(S, np.int64),
                "shard_issued": shard_issued,
                "per_query_shard_pages": per_query_shard,
                "shard_depths": (per_query_shard > 0).sum(axis=0)}

    # -- reporting -----------------------------------------------------------

    def savings(self) -> int:
        return self.counters.pages_requested - self.counters.pages_fetched

    def hit_rate(self) -> float:
        return (self.counters.cache_hits / self.accesses
                if self.accesses else 0.0)

    def tenant_hit_rates(self) -> Dict[int, float]:
        """Lifetime per-tenant replay hit rates (same contract as
        SharedCachePageStore's)."""
        return {t: (a["hits"] / a["requested"] if a["requested"] else 0.0)
                for t, a in sorted(self.tenant_counters.items())}

    def tenant_capacities(self) -> Optional[List[int]]:
        """Current per-tenant cache capacity summed across the shard
        slices (None unless the shard caches are tenant-partitioned) —
        the fleet-wide answer to "how many pages does tenant t hold"."""
        if not self.tenant_aware:
            return None
        caps = [c.capacities() for c in self.caches]
        return [sum(col) for col in zip(*caps)]

    def shard_rows(self) -> List[dict]:
        """Lifetime per-shard counter rows (placement + conservation
        audits; the serving reports add per-run depth/utilization). Covers
        page-routed traffic — vertex-granular pass-throughs mirror into the
        roll-up `counters` only, so the shard sum can undercut the roll-up
        by exactly that pass-through volume."""
        return [{"shard": s, **c.as_dict(),
                 "hit_rate": (c.cache_hits / c.pages_requested
                              if c.pages_requested else 0.0)}
                for s, c in enumerate(self.shard_counters)]

    def reset_cache(self) -> None:
        if self.caches is not None:
            for c in self.caches:
                c.reset()

    def extend_placement(self, num_pages: int) -> None:
        """Grow the page→shard map for an appended page space (streaming
        updates); see Placement.extend. The live read counters grow with
        it (appended pages start cold)."""
        self.placement = self.placement.extend(num_pages)
        grow = num_pages - len(self.page_read_counts)
        if grow > 0:
            self.page_read_counts = np.concatenate(
                [self.page_read_counts, np.zeros(grow, np.int64)])

    def set_replicated(self, replicated: np.ndarray) -> dict:
        """Swap the replicated hot set IN PLACE — the store-side half of
        online hot-page migration. Homes (`page_to_shard`) never move; only
        the every-shard-resident mask changes, so routing flips between
        "home only" and "least-loaded" per page. Returns the delta
        (`promoted` gained replication — the serving layer bills the page
        copies to the other S-1 shards and invalidates stale residency via
        MutablePageStore.invalidate; `demoted` lost it — a metadata-only
        change, their home copy was never stale)."""
        mask = np.asarray(replicated, bool).reshape(-1)
        if len(mask) != len(self.placement.page_to_shard):
            raise ValueError(
                f"replicated mask has {len(mask)} entries for "
                f"{len(self.placement.page_to_shard)} pages")
        old = self.placement.replicated
        promoted = np.flatnonzero(mask & ~old)
        demoted = np.flatnonzero(old & ~mask)
        self.placement = dataclasses.replace(self.placement,
                                             replicated=mask.copy())
        return {"promoted": promoted, "demoted": demoted}
