from repro.io.page_cache import (DYNAMIC_POLICIES, POLICIES, FIFOPageCache,
                                 LRUPageCache, PageCache,
                                 PartitionedPageCache, PrefetchingPageStore,
                                 SharedCachePageStore, TwoQPageCache,
                                 make_cache)
from repro.io.page_store import (ArrayPageStore, BatchedPageStore,
                                 CachedPageStore, PageStore, StoreCounters,
                                 build_store)

__all__ = ["ArrayPageStore", "BatchedPageStore", "CachedPageStore",
           "DYNAMIC_POLICIES", "FIFOPageCache", "LRUPageCache", "PageCache",
           "PageStore", "POLICIES", "PartitionedPageCache",
           "PrefetchingPageStore", "SharedCachePageStore", "StoreCounters",
           "TwoQPageCache", "build_store", "make_cache"]
