from repro.io.page_cache import (DYNAMIC_POLICIES, POLICIES, FIFOPageCache,
                                 LRUPageCache, PageCache,
                                 PartitionedPageCache, PrefetchingPageStore,
                                 SharedCachePageStore, TwoQPageCache,
                                 make_cache)
from repro.io.page_store import (ArrayPageStore, BatchedPageStore,
                                 CachedPageStore, PageStore, StoreCounters,
                                 build_store, charge_inner_reads)
from repro.io.sharded_store import (PLACEMENTS, Placement, ShardedPageStore,
                                    make_placement, make_shard_caches,
                                    profile_from_counters,
                                    profile_from_trace)

__all__ = ["ArrayPageStore", "BatchedPageStore", "CachedPageStore",
           "DYNAMIC_POLICIES", "FIFOPageCache", "LRUPageCache", "PLACEMENTS",
           "PageCache", "PageStore", "POLICIES", "PartitionedPageCache",
           "Placement", "PrefetchingPageStore", "ShardedPageStore",
           "SharedCachePageStore", "StoreCounters", "TwoQPageCache",
           "build_store", "charge_inner_reads", "make_cache",
           "make_placement", "make_shard_caches", "profile_from_counters",
           "profile_from_trace"]
