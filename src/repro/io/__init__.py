from repro.io.page_store import (ArrayPageStore, BatchedPageStore,
                                 CachedPageStore, PageStore, StoreCounters,
                                 build_store)

__all__ = ["ArrayPageStore", "BatchedPageStore", "CachedPageStore",
           "PageStore", "StoreCounters", "build_store"]
