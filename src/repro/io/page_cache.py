"""I/O layer: stateful shared page caches, multi-tenant partitioning, and
trace-driven prefetching.

The static vertex mask (`CachedPageStore`, §4.1.2) is order-free: whether a
read hits depends only on which vertex is asked for, never on *when*. The
paper's page-level complexity model (§5) says the next I/O reductions are
temporal — page locality × path length — so this module adds the stateful
half of the cache design space:

  PageCache           — the replacement-policy interface (capacity in pages;
                        `access(page)` probes AND admits, returning hit).
  FIFOPageCache       — evict in admission order (scan-friendly baseline).
  LRUPageCache        — evict least-recently-used (Starling-style shared
                        page cache over the page-aligned layout).
  TwoQPageCache       — simplified 2Q: a FIFO probation queue + a ghost
                        queue + a protected LRU, so one-touch scan pages
                        cannot flush the hot set.
  PartitionedPageCache — multi-tenant: ONE byte budget split into per-tenant
                        partitions of any of the above policies (static
                        shares + optional utility-based rebalance), so a
                        noisy neighbor cannot thrash another tenant's
                        working set.
  SharedCachePageStore — decorator replaying temporally ordered page-access
                        traces (QueryStats.page_trace) against one
                        byte-budgeted cache that persists ACROSS batches;
                        only misses are charged to the inner store's device.
  PrefetchingPageStore — SharedCachePageStore + LAANN-style look-ahead: the
                        next hops' frontier pages are issued while the
                        current hop computes, so their service time can be
                        hidden (the device model's `prefetch_overlap`
                        rebate); the reads are still charged.

The trace contract
------------------
`page_trace` is a (B, max_iters, w) int32 array emitted by the kernel under
the static `track_trace` flag (it compiles out entirely when off). Row
(b, h) holds the DISTINCT pages query b charged to the device at hop h, in
frontier order, -1 padded on the right; hops past the query's convergence
are all -1. The charged pages are exactly the pages the scalar `page_reads`
counter booked — the trace is the same charges in TEMPORAL order, which is
what makes replacement order (LRU/FIFO/2Q) and look-ahead meaningful.
`replay_batch` walks queries in dispatch order and hops in time order;
with `tenants=` it additionally routes each query's accesses to that
query's cache partition and returns per-tenant accounting.

Policy semantics
----------------
All policies are probe-and-admit (`access` returns hit and, on a miss,
admits the page, evicting per policy). FIFO evicts in admission order and a
hit does NOT renew residency; LRU renews on hit. 2Q (Johnson & Shasha)
splits capacity into a FIFO *probation* queue (A1in, a quarter of capacity)
and a *protected* LRU (Am): new pages must survive probation; pages evicted
from probation leave an id-only *ghost* entry (A1out, several times the
capacity — ids cost pennies against the byte budget), and a later miss that
hits the ghost is promoted straight into the protected LRU. One-touch
beam-search scan pages therefore die in probation instead of flushing the
revisited hot set.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.io.page_store import (StoreCounters, book_charged_reads,
                                 book_writes, charge_inner_reads,
                                 fetch_mirroring_inner, note_inner_writes,
                                 resolve_write)


class PageCache:
    """Replacement-policy interface: a set of resident pages with a page
    capacity. `access` is probe-and-admit: it returns whether the page was
    resident and, on a miss, admits it (evicting per policy). Policies with
    `tenant_aware` set accept `access(page, tenant)` and keep per-tenant
    state (see PartitionedPageCache)."""

    name = "base"
    tenant_aware = False

    def __init__(self, capacity_pages: int):
        if capacity_pages < 1:
            raise ValueError(
                f"capacity_pages={capacity_pages} must be >= 1 "
                f"(a cache that can hold no page cannot hit)")
        self.capacity = int(capacity_pages)

    def access(self, page: int) -> bool:
        raise NotImplementedError

    def admit(self, page: int) -> None:
        """Non-demand warm path (look-ahead prefetch): admit the page
        without the demand-side accounting a subclass may keep. The base
        policies keep no stats, so admission IS probe-and-admit; stats-
        keeping caches (PartitionedPageCache) override this so prefetch
        traffic cannot inflate demand hit rates or rebalance windows."""
        self.access(page)

    def resize(self, capacity_pages: int) -> None:
        """Change capacity in place, evicting per policy if shrinking —
        what the partitioned cache's utility rebalance relies on."""
        if capacity_pages < 1:
            raise ValueError(
                f"capacity_pages={capacity_pages} must be >= 1")
        self.capacity = int(capacity_pages)
        self._shrink_to_capacity()

    def invalidate(self, page: int) -> bool:
        """Drop a (possibly) resident page because its on-disk bytes were
        rewritten (streaming updates: flush/compaction). Returns whether a
        stale copy was actually evicted. NOT a policy eviction: residency
        simply ends, and the next demand access is a charged miss."""
        raise NotImplementedError

    def _shrink_to_capacity(self) -> None:
        """Evict, per policy, until residency fits the (new) capacity."""
        raise NotImplementedError

    def __contains__(self, page: int) -> bool:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def reset(self) -> None:
        raise NotImplementedError


class _QueueCache(PageCache):
    """Shared body of the single-OrderedDict policies (FIFO, LRU): the
    subclass's `access` decides whether a hit renews residency; eviction is
    always from the queue front."""

    def __init__(self, capacity_pages: int):
        super().__init__(capacity_pages)
        self._q: OrderedDict = OrderedDict()

    def _shrink_to_capacity(self) -> None:
        while len(self._q) > self.capacity:
            self._q.popitem(last=False)

    def invalidate(self, page: int) -> bool:
        if page in self._q:
            del self._q[page]
            return True
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._q

    def __len__(self) -> int:
        return len(self._q)

    def reset(self) -> None:
        self._q.clear()


class FIFOPageCache(_QueueCache):
    """Evict in admission order; a hit does not renew residency."""

    name = "fifo"

    def access(self, page: int) -> bool:
        if page in self._q:
            return True
        if len(self._q) >= self.capacity:
            self._q.popitem(last=False)
        self._q[page] = None
        return False


class LRUPageCache(_QueueCache):
    """Evict the least-recently-used page; a hit renews residency."""

    name = "lru"

    def access(self, page: int) -> bool:
        if page in self._q:
            self._q.move_to_end(page)
            return True
        if len(self._q) >= self.capacity:
            self._q.popitem(last=False)
        self._q[page] = None
        return False


class TwoQPageCache(PageCache):
    """Simplified 2Q (Johnson & Shasha): new pages enter a FIFO probation
    queue (A1in, a quarter of capacity); pages evicted from probation leave
    an id-only ghost entry (A1out); a miss that hits the ghost queue is
    promoted into the protected LRU (Am). One-touch beam-search scan pages
    therefore die in probation instead of flushing the revisited hot set."""

    name = "2q"

    def __init__(self, capacity_pages: int):
        super().__init__(capacity_pages)
        self._set_caps()
        self._a1in: OrderedDict = OrderedDict()
        self._ghost: OrderedDict = OrderedDict()
        self._am: OrderedDict = OrderedDict()

    def _set_caps(self) -> None:
        """Derive the queue capacities from self.capacity (construction and
        resize share this so the probation fraction cannot diverge)."""
        self._in_cap = max(1, self.capacity // 4)
        self._am_cap = max(1, self.capacity - self._in_cap)
        # ghost entries are page IDS, not pages — pennies against the byte
        # budget — so the re-use memory can run several times the capacity
        self._ghost_cap = 4 * self.capacity

    def access(self, page: int) -> bool:
        if page in self._am:
            self._am.move_to_end(page)
            return True
        if page in self._a1in:
            return True
        # miss: a ghost hit means the page proved re-use beyond probation
        if page in self._ghost:
            del self._ghost[page]
            if len(self._am) >= self._am_cap:
                self._am.popitem(last=False)
            self._am[page] = None
            return False
        if len(self._a1in) >= self._in_cap:
            old, _ = self._a1in.popitem(last=False)
            self._ghost[old] = None
            while len(self._ghost) > self._ghost_cap:
                self._ghost.popitem(last=False)
        self._a1in[page] = None
        return False

    def _shrink_to_capacity(self) -> None:
        self._set_caps()
        while len(self._a1in) > self._in_cap:
            old, _ = self._a1in.popitem(last=False)
            self._ghost[old] = None
        while len(self._am) > self._am_cap:
            self._am.popitem(last=False)
        while len(self._ghost) > self._ghost_cap:
            self._ghost.popitem(last=False)

    def invalidate(self, page: int) -> bool:
        """Evict stale BYTES (probation or protected residency). The ghost
        queue keeps its id-only entry: invalidation rewrites the page's
        content, not the evidence that the page is re-used."""
        hit = False
        if page in self._a1in:
            del self._a1in[page]
            hit = True
        if page in self._am:
            del self._am[page]
            hit = True
        return hit

    def __contains__(self, page: int) -> bool:
        return page in self._a1in or page in self._am

    def __len__(self) -> int:
        return len(self._a1in) + len(self._am)

    def reset(self) -> None:
        self._a1in.clear()
        self._ghost.clear()
        self._am.clear()


class PartitionedPageCache(PageCache):
    """Multi-tenant cache: ONE page budget split into per-tenant partitions
    of a base policy ("lru" | "fifo" | "2q"), so tenants share the byte
    budget but never each other's residency — the partition IS the
    isolation. `access(page, tenant)` routes to that tenant's partition;
    a page hot for two tenants occupies a slot in each (partitioned, not
    deduplicated, exactly like per-tenant OS page-cache cgroups).

    Static split: `shares` (fractions, default equal) sized by largest
    remainder with a 1-page floor per tenant.

    Utility-based rebalance (`rebalance_every` > 0): each tenant also
    maintains a *shadow* id-only LRU of TWICE its current capacity over its
    own access stream — a one-point probe of the tenant's hit curve (from
    its `page_trace` replay) at the doubled-capacity point; probing well
    past the current size is what sees over LRU's cyclic-workload cliff,
    where capacity + 1 still hits nothing. A real miss that the shadow
    would have served means more capacity would have converted it (marginal
    utility). Every `rebalance_every` accesses the window's highest-gain
    tenant takes `rebalance_step` pages of capacity from the lowest-gain
    tenant (ties keep the split; donors never shrink below one page). The
    shadow is LRU regardless of the partition policy — it approximates the
    stack-distance hit curve, which is the quantity the rebalance trades
    on.

    With `tenants=1` the single partition gets the whole budget and every
    access routes straight through — bit-identical to the base policy
    (tested in tests/test_page_cache.py)."""

    name = "partitioned"
    tenant_aware = True

    def __init__(self, capacity_pages: int, tenants: int,
                 policy: str = "lru",
                 shares: Optional[Sequence[float]] = None,
                 rebalance_every: int = 0,
                 rebalance_step: Optional[int] = None):
        super().__init__(capacity_pages)
        if tenants < 1:
            raise ValueError(f"tenants={tenants} must be >= 1")
        if capacity_pages < tenants:
            raise ValueError(
                f"capacity_pages={capacity_pages} cannot give each of "
                f"{tenants} tenants its 1-page floor")
        if policy not in POLICIES:
            raise ValueError(f"unknown partition policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        if rebalance_every < 0:
            raise ValueError(
                f"rebalance_every={rebalance_every} must be >= 0 (0 = off)")
        if shares is None:
            shares = [1.0 / tenants] * tenants
        shares = [float(s) for s in shares]
        if len(shares) != tenants:
            raise ValueError(
                f"shares has {len(shares)} entries for {tenants} tenants")
        if any(s <= 0 for s in shares):
            raise ValueError(f"shares={shares} must all be positive")
        total = sum(shares)
        # largest-remainder allocation with a 1-page floor per tenant
        raw = [s / total * capacity_pages for s in shares]
        caps = [max(1, int(f)) for f in raw]
        rem = sorted(range(tenants), key=lambda t: raw[t] - int(raw[t]),
                     reverse=True)
        r = 0
        while sum(caps) < capacity_pages:
            caps[rem[r % tenants]] += 1
            r += 1
        while sum(caps) > capacity_pages:
            t = max(range(tenants), key=lambda t: caps[t])
            caps[t] -= 1
        self.policy = policy
        self.tenants = tenants
        self.parts: List[PageCache] = [POLICIES[policy](c) for c in caps]
        self.rebalance_every = int(rebalance_every)
        self.rebalance_step = int(rebalance_step
                                  or max(1, capacity_pages // (8 * tenants)))
        self._shadow = [OrderedDict() for _ in range(tenants)]
        self._gain = [0] * tenants          # window shadow-convertible misses
        self._since = 0                     # accesses since last rebalance
        self.t_accesses = [0] * tenants     # lifetime per-tenant probes
        self.t_hits = [0] * tenants
        self.rebalances = 0                 # capacity moves actually applied

    def access(self, page: int, tenant: int = 0) -> bool:
        part = self.parts[tenant]
        hit = part.access(page)
        self.t_accesses[tenant] += 1
        self.t_hits[tenant] += hit
        if self.rebalance_every:
            sh = self._shadow[tenant]
            if page in sh:
                if not hit:
                    self._gain[tenant] += 1
                sh.move_to_end(page)
            else:
                while len(sh) >= 2 * part.capacity:
                    sh.popitem(last=False)
                sh[page] = None
            self._since += 1
            if self._since >= self.rebalance_every:
                self._rebalance()
        return hit

    def admit(self, page: int, tenant: int = 0) -> None:
        """Non-demand warm (look-ahead prefetch): admit into the tenant's
        partition WITHOUT touching `t_accesses`/`t_hits`, the shadow LRU,
        or the rebalance window — prefetch traffic is not demand, and
        counting it would skew `tenant_hit_rates()` and could flip the
        utility rebalance."""
        self.parts[tenant].access(page)

    def _rebalance(self) -> None:
        self._since = 0
        order = sorted(range(self.tenants), key=lambda t: self._gain[t])
        recipient, donor = order[-1], None
        for t in order:
            if t != recipient and self.parts[t].capacity > 1:
                donor = t
                break
        if donor is not None and self._gain[recipient] > self._gain[donor]:
            step = min(self.rebalance_step, self.parts[donor].capacity - 1)
            if step > 0:
                self.parts[donor].resize(self.parts[donor].capacity - step)
                self.parts[recipient].resize(
                    self.parts[recipient].capacity + step)
                self.rebalances += 1
        self._gain = [0] * self.tenants

    def invalidate(self, page: int) -> bool:
        """Drop stale copies from EVERY tenant's partition (a page hot for
        two tenants is resident twice) and from the shadow LRUs — a shadow
        entry for rewritten bytes would otherwise count a would-have-hit
        that could never have served the new content."""
        hit = False
        for p in self.parts:
            hit = p.invalidate(page) or hit
        for sh in self._shadow:
            sh.pop(page, None)
        return hit

    def capacities(self) -> List[int]:
        """Current per-tenant page capacities (moves under rebalance)."""
        return [p.capacity for p in self.parts]

    def tenant_hit_rates(self) -> List[float]:
        """Lifetime per-tenant hit rates — the fairness signal the overload
        benchmark reports."""
        return [h / a if a else 0.0
                for h, a in zip(self.t_hits, self.t_accesses)]

    def resize(self, capacity_pages: int) -> None:
        raise NotImplementedError(
            "resize the partitions (parts[t].resize), not the envelope — "
            "the total budget is fixed at construction")

    def __contains__(self, page: int) -> bool:
        return any(page in p for p in self.parts)

    def __len__(self) -> int:
        return sum(len(p) for p in self.parts)

    def reset(self) -> None:
        """Drop residency and rebalance window state; the current capacity
        split (including any rebalance moves) is kept."""
        for p in self.parts:
            p.reset()
        for sh in self._shadow:
            sh.clear()
        self._gain = [0] * self.tenants
        self._since = 0


POLICIES = {c.name: c for c in (LRUPageCache, FIFOPageCache, TwoQPageCache)}

#: build_store() cache_policy values that compose a stateful shared cache
#: (vs. "none" and the order-free "static-vertex" mask).
DYNAMIC_POLICIES = tuple(POLICIES)


def floor_capacity_pages(cache_bytes: int, page_bytes: int, parts: int,
                         noun: str) -> int:
    """Translate a byte budget to whole-page capacity, validating that each
    of `parts` partitions (`noun`: "tenants" | "shards") gets its 1-page
    floor — the error names the BYTES the caller configured, not just the
    derived page count."""
    capacity = cache_bytes // page_bytes
    if capacity < parts:
        raise ValueError(
            f"cache_bytes={cache_bytes} is only {capacity} page(s) of "
            f"{page_bytes} bytes — cannot give each of {parts} {noun} its "
            f"1-page floor (need cache_bytes >= {parts * page_bytes})")
    return capacity


def make_cache(policy: str, cache_bytes: int, page_bytes: int,
               tenants: int = 1,
               tenant_shares: Optional[Sequence[float]] = None,
               rebalance_every: int = 0) -> PageCache:
    """Instantiate a policy with a byte budget translated to whole pages.
    `tenants > 1` partitions the SAME budget across tenants (optionally
    with static `tenant_shares` and utility rebalance every
    `rebalance_every` accesses) — see PartitionedPageCache."""
    if policy not in POLICIES:
        raise ValueError(f"unknown cache policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}")
    if cache_bytes < page_bytes:
        raise ValueError(
            f"cache_bytes={cache_bytes} holds no {page_bytes}-byte page")
    if tenants < 1:
        raise ValueError(f"tenants={tenants} must be >= 1")
    if tenants > 1:
        # validate in BYTES here: the page-floor error the partition itself
        # raises never mentions the budget the caller actually configured
        capacity = floor_capacity_pages(cache_bytes, page_bytes, tenants,
                                        "tenants")
        return PartitionedPageCache(
            capacity, tenants, policy=policy,
            shares=tenant_shares, rebalance_every=rebalance_every)
    return POLICIES[policy](cache_bytes // page_bytes)


class SharedCachePageStore:
    """Decorator: one byte-budgeted page cache shared by every query and —
    unlike `BatchedPageStore`, whose union-dedup forgets everything at the
    batch boundary — persisting ACROSS batches for the lifetime of the
    store. `replay_batch` consumes temporally ordered `page_trace`s; only
    misses are charged as device reads, so a warm cache strictly undercuts
    batch-local coalescing whenever consecutive batches share pages (entry
    pages, hot regions).

    `lookahead > 0` adds LAANN-style prefetching: while hop h computes, the
    pages hops h+1..h+lookahead will charge are issued ahead. Prefetched
    reads still cost device I/O (they move `pages_fetched` and
    `prefetch_issued`) but their service overlaps compute — the returned
    `overlap_frac` feeds `SSDModel.concurrent_latency_us(prefetch_overlap=)`.
    Replay is the oracle form of look-ahead (the trace is the prediction);
    a small cache can still evict a prefetched page before use, which is
    exactly the wasted-I/O failure mode of real look-ahead.

    Tenancy: `replay_batch(tenants=)` is the tenant-aware path. The
    PageStore-protocol `fetch` below is tenant-blind — with a partitioned
    cache it probes and warms the DEFAULT partition (tenant 0) only, so
    multi-tenant serving must account I/O through replay, not fetch."""

    def __init__(self, inner, cache: PageCache, lookahead: int = 0):
        if lookahead < 0:
            raise ValueError(f"lookahead={lookahead} must be >= 0")
        self.inner = inner
        self.cache = cache
        self.lookahead = int(lookahead)
        self.counters = StoreCounters()
        self.accesses = 0          # trace/fetch page probes
        self.prefetch_issued = 0   # look-ahead reads charged to the device
        # lifetime per-tenant replay accounting (tenant -> requested/hits/
        # issued); the partitioned cache additionally tracks residency-level
        # per-tenant hit rates, but this dict exists for ANY cache so a
        # shared (unpartitioned) cache can expose noisy-neighbor interference
        self.tenant_counters: Dict[int, Dict[str, int]] = {}

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    # -- PageStore protocol --------------------------------------------------

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        self.counters.pages_requested += len(page_ids)
        if vids is not None:
            # vertex-granular requests belong to the static-vertex layer —
            # pass through, mirroring the inner store's counter movement
            return fetch_mirroring_inner(self.counters, self.inner,
                                         page_ids, vids)
        hit = np.fromiter((self.cache.access(int(p)) for p in page_ids),
                          bool, len(page_ids))
        self.accesses += len(page_ids)
        self.counters.cache_hits += int(hit.sum())
        misses = page_ids[~hit]
        self.counters.pages_fetched += len(misses)
        self.counters.records_fetched += len(misses) * self.layout.n_p
        charge_inner_reads(self.inner, misses)
        lay = self.layout
        return {"vids": lay.page_vids[page_ids],
                "vecs": lay.page_vecs[page_ids],
                "nbrs": lay.page_nbrs[page_ids]}

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.inner.vertex_cache_mask()

    def note_kernel_io(self, stats) -> None:
        # replay_batch is this store's accounting path; forward only
        self.inner.note_kernel_io(stats)

    def charge(self, page_ids: np.ndarray) -> None:
        """Accounting-only reads from a layer above: book 1:1 and forward.
        Charges bypass the cache (they are already-issued device reads, not
        probes), so residency is untouched."""
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        book_charged_reads(self.counters, len(page_ids), self.layout.n_p)
        self.inner.charge(page_ids)

    def note_write(self, page_ids=None, *, kind: str = "data",
                   count: Optional[int] = None) -> None:
        """Writes bypass the cache (invalidation is MutablePageStore's
        job; the write itself is device traffic): book 1:1, forward down."""
        pages, n = resolve_write(page_ids, count)
        book_writes(self.counters, n, kind)
        note_inner_writes(self.inner, pages, kind, n)

    # -- trace replay (the serving-path accounting) --------------------------

    def replay_batch(self, page_trace: np.ndarray,
                     tenants: Optional[np.ndarray] = None) -> dict:
        """page_trace: (B, hops, w) int32, -1 padded — each query's charged
        pages in hop order (QueryStats.page_trace). Replays queries in
        dispatch order against the shared cache; `tenants` (optional (B,)
        ints, default all 0) routes each query's accesses to that tenant's
        partition when the cache is tenant-aware, and keys the per-tenant
        accounting either way. Returns the batch's device accounting:

          requested         trace page accesses (== sum of page_reads)
          issued            reads charged to the device (demand misses +
                            look-ahead issues)
          hits              accesses served by the resident cache
          per_query_issued  (B,) float64 — reads charged while replaying
                            each query (its latency share)
          prefetch_issued   look-ahead reads within `issued`
          overlap_frac      prefetch_issued / issued (the latency-hiding
                            fraction for the device model)
          hit_rate          hits / requested
          per_tenant        {tenant: {requested, hits, issued, hit_rate}}
        """
        trace = np.asarray(page_trace)
        if trace.ndim != 3:
            raise ValueError(
                f"page_trace must be (B, hops, w); got shape {trace.shape}")
        B = trace.shape[0]
        ta = getattr(self.cache, "tenant_aware", False)
        if tenants is None:
            tns = np.zeros(B, np.int64)
        else:
            tns = np.asarray(tenants, np.int64).reshape(-1)
            if len(tns) != B:
                raise ValueError(
                    f"tenants has {len(tns)} entries for a {B}-query trace")
            if np.any(tns < 0):
                raise ValueError("tenant ids must be >= 0")
            if ta and len(tns) and int(tns.max()) >= self.cache.tenants:
                # validate BEFORE replaying: failing mid-loop would leave
                # the shared cache half-warmed by a rejected batch
                raise ValueError(
                    f"tenant id {int(tns.max())} out of range for a "
                    f"{self.cache.tenants}-partition cache")
        per_query = np.zeros(B, np.float64)
        per_tenant: Dict[int, Dict[str, int]] = {
            int(t): {"requested": 0, "hits": 0, "issued": 0}
            for t in np.unique(tns)}
        requested = hits = issued = prefetched = 0
        charged: List[int] = []     # every device read, in issue order
        for b in range(B):
            t = int(tns[b])
            tacct = per_tenant[t]
            hop_pages = [row[row >= 0] for row in trace[b]]
            for h, row in enumerate(hop_pages):
                if len(row) == 0:
                    continue
                # look-ahead: issue the next hops' pages while h computes
                # (into — and gated on — this query's own partition).
                # admit(), not access(): prefetch traffic is not demand,
                # so it must not move demand hit rates or the partitioned
                # cache's shadow/rebalance window
                for ahead in hop_pages[h + 1: h + 1 + self.lookahead]:
                    for p in ahead:
                        resident = (int(p) in self.cache.parts[t] if ta
                                    else int(p) in self.cache)
                        if not resident:
                            if ta:
                                self.cache.admit(int(p), t)
                            else:
                                self.cache.admit(int(p))
                            issued += 1
                            prefetched += 1
                            per_query[b] += 1
                            tacct["issued"] += 1
                            charged.append(int(p))
                for p in row:
                    requested += 1
                    tacct["requested"] += 1
                    hit = (self.cache.access(int(p), t) if ta
                           else self.cache.access(int(p)))
                    if hit:
                        hits += 1
                        tacct["hits"] += 1
                    else:
                        issued += 1
                        per_query[b] += 1
                        tacct["issued"] += 1
                        charged.append(int(p))
        self.accesses += requested
        self.prefetch_issued += prefetched
        self.counters.pages_requested += requested
        self.counters.cache_hits += hits
        self.counters.pages_fetched += issued
        self.counters.records_fetched += issued * self.layout.n_p
        # forward the misses' charge to the inner store: a decorator whose
        # reads never reach the device it decorates breaks every
        # cross-stack rollup (savings(), as_dict() audits)
        charge_inner_reads(self.inner, charged)
        for t, a in per_tenant.items():
            life = self.tenant_counters.setdefault(
                t, {"requested": 0, "hits": 0, "issued": 0})
            for k in life:
                life[k] += a[k]
            a["hit_rate"] = (a["hits"] / a["requested"]
                             if a["requested"] else 0.0)
        return {"requested": requested, "issued": issued, "hits": hits,
                "per_query_issued": per_query,
                "prefetch_issued": prefetched,
                "overlap_frac": prefetched / issued if issued else 0.0,
                "hit_rate": hits / requested if requested else 0.0,
                "per_tenant": per_tenant}

    def tenant_hit_rates(self) -> Dict[int, float]:
        """Lifetime per-tenant replay hit rates (every tenant this store
        has replayed), whatever the cache type."""
        return {t: (a["hits"] / a["requested"] if a["requested"] else 0.0)
                for t, a in sorted(self.tenant_counters.items())}

    def hit_rate(self) -> float:
        """Lifetime hit rate over every access this store has seen."""
        return (self.counters.cache_hits / self.accesses
                if self.accesses else 0.0)

    def reset_cache(self) -> None:
        self.cache.reset()


class PrefetchingPageStore(SharedCachePageStore):
    """SharedCachePageStore with look-ahead on by default: the named form
    `build_store(..., prefetch=k)` composes. Kept as its own class so the
    store stack reads as policy objects (isinstance tells the configuration)."""

    def __init__(self, inner, cache: PageCache, lookahead: int = 1):
        if lookahead < 1:
            raise ValueError(
                f"lookahead={lookahead} must be >= 1 for a prefetching "
                f"store (use SharedCachePageStore for pure caching)")
        super().__init__(inner, cache, lookahead=lookahead)
