"""I/O layer: stateful shared page caches + trace-driven prefetching.

The static vertex mask (`CachedPageStore`, §4.1.2) is order-free: whether a
read hits depends only on which vertex is asked for, never on *when*. The
paper's page-level complexity model (§5) says the next I/O reductions are
temporal — page locality × path length — so this module adds the stateful
half of the cache design space:

  PageCache           — the replacement-policy interface (capacity in pages;
                        `access(page)` probes AND admits, returning hit).
  FIFOPageCache       — evict in admission order (scan-friendly baseline).
  LRUPageCache        — evict least-recently-used (Starling-style shared
                        page cache over the page-aligned layout).
  TwoQPageCache       — simplified 2Q: a FIFO probation queue + a ghost
                        queue + a protected LRU, so one-touch scan pages
                        cannot flush the hot set.
  SharedCachePageStore — decorator replaying temporally ordered page-access
                        traces (QueryStats.page_trace) against one
                        byte-budgeted cache that persists ACROSS batches;
                        only misses are charged to the inner store's device.
  PrefetchingPageStore — SharedCachePageStore + LAANN-style look-ahead: the
                        next hops' frontier pages are issued while the
                        current hop computes, so their service time can be
                        hidden (the device model's `prefetch_overlap`
                        rebate); the reads are still charged.

The trace contract: `page_trace` is (B, hops, w) int32, row (b, h) holding
the distinct pages query b charged at hop h, -1 padded — exactly the pages
`page_reads` counted, now in arrival order. Replay walks queries in dispatch
order and hops in time order, which is what makes LRU/FIFO/2Q meaningful.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.io.page_store import StoreCounters, fetch_mirroring_inner


class PageCache:
    """Replacement-policy interface: a set of resident pages with a page
    capacity. `access` is probe-and-admit: it returns whether the page was
    resident and, on a miss, admits it (evicting per policy)."""

    name = "base"

    def __init__(self, capacity_pages: int):
        if capacity_pages < 1:
            raise ValueError(
                f"capacity_pages={capacity_pages} must be >= 1 "
                f"(a cache that can hold no page cannot hit)")
        self.capacity = int(capacity_pages)

    def access(self, page: int) -> bool:
        raise NotImplementedError

    def __contains__(self, page: int) -> bool:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def reset(self) -> None:
        raise NotImplementedError


class FIFOPageCache(PageCache):
    """Evict in admission order; a hit does not renew residency."""

    name = "fifo"

    def __init__(self, capacity_pages: int):
        super().__init__(capacity_pages)
        self._q: OrderedDict = OrderedDict()

    def access(self, page: int) -> bool:
        if page in self._q:
            return True
        if len(self._q) >= self.capacity:
            self._q.popitem(last=False)
        self._q[page] = None
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._q

    def __len__(self) -> int:
        return len(self._q)

    def reset(self) -> None:
        self._q.clear()


class LRUPageCache(PageCache):
    """Evict the least-recently-used page; a hit renews residency."""

    name = "lru"

    def __init__(self, capacity_pages: int):
        super().__init__(capacity_pages)
        self._q: OrderedDict = OrderedDict()

    def access(self, page: int) -> bool:
        if page in self._q:
            self._q.move_to_end(page)
            return True
        if len(self._q) >= self.capacity:
            self._q.popitem(last=False)
        self._q[page] = None
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._q

    def __len__(self) -> int:
        return len(self._q)

    def reset(self) -> None:
        self._q.clear()


class TwoQPageCache(PageCache):
    """Simplified 2Q (Johnson & Shasha): new pages enter a FIFO probation
    queue (A1in, a quarter of capacity); pages evicted from probation leave
    an id-only ghost entry (A1out); a miss that hits the ghost queue is
    promoted into the protected LRU (Am). One-touch beam-search scan pages
    therefore die in probation instead of flushing the revisited hot set."""

    name = "2q"

    def __init__(self, capacity_pages: int):
        super().__init__(capacity_pages)
        self._in_cap = max(1, self.capacity // 4)
        self._am_cap = max(1, self.capacity - self._in_cap)
        # ghost entries are page IDS, not pages — pennies against the byte
        # budget — so the re-use memory can run several times the capacity
        self._ghost_cap = 4 * self.capacity
        self._a1in: OrderedDict = OrderedDict()
        self._ghost: OrderedDict = OrderedDict()
        self._am: OrderedDict = OrderedDict()

    def access(self, page: int) -> bool:
        if page in self._am:
            self._am.move_to_end(page)
            return True
        if page in self._a1in:
            return True
        # miss: a ghost hit means the page proved re-use beyond probation
        if page in self._ghost:
            del self._ghost[page]
            if len(self._am) >= self._am_cap:
                self._am.popitem(last=False)
            self._am[page] = None
            return False
        if len(self._a1in) >= self._in_cap:
            old, _ = self._a1in.popitem(last=False)
            self._ghost[old] = None
            while len(self._ghost) > self._ghost_cap:
                self._ghost.popitem(last=False)
        self._a1in[page] = None
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._a1in or page in self._am

    def __len__(self) -> int:
        return len(self._a1in) + len(self._am)

    def reset(self) -> None:
        self._a1in.clear()
        self._ghost.clear()
        self._am.clear()


POLICIES = {c.name: c for c in (LRUPageCache, FIFOPageCache, TwoQPageCache)}

#: build_store() cache_policy values that compose a stateful shared cache
#: (vs. "none" and the order-free "static-vertex" mask).
DYNAMIC_POLICIES = tuple(POLICIES)


def make_cache(policy: str, cache_bytes: int, page_bytes: int) -> PageCache:
    """Instantiate a policy with a byte budget translated to whole pages."""
    if policy not in POLICIES:
        raise ValueError(f"unknown cache policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}")
    if cache_bytes < page_bytes:
        raise ValueError(
            f"cache_bytes={cache_bytes} holds no {page_bytes}-byte page")
    return POLICIES[policy](cache_bytes // page_bytes)


class SharedCachePageStore:
    """Decorator: one byte-budgeted page cache shared by every query and —
    unlike `BatchedPageStore`, whose union-dedup forgets everything at the
    batch boundary — persisting ACROSS batches for the lifetime of the
    store. `replay_batch` consumes temporally ordered `page_trace`s; only
    misses are charged as device reads, so a warm cache strictly undercuts
    batch-local coalescing whenever consecutive batches share pages (entry
    pages, hot regions).

    `lookahead > 0` adds LAANN-style prefetching: while hop h computes, the
    pages hops h+1..h+lookahead will charge are issued ahead. Prefetched
    reads still cost device I/O (they move `pages_fetched` and
    `prefetch_issued`) but their service overlaps compute — the returned
    `overlap_frac` feeds `SSDModel.concurrent_latency_us(prefetch_overlap=)`.
    Replay is the oracle form of look-ahead (the trace is the prediction);
    a small cache can still evict a prefetched page before use, which is
    exactly the wasted-I/O failure mode of real look-ahead."""

    def __init__(self, inner, cache: PageCache, lookahead: int = 0):
        if lookahead < 0:
            raise ValueError(f"lookahead={lookahead} must be >= 0")
        self.inner = inner
        self.cache = cache
        self.lookahead = int(lookahead)
        self.counters = StoreCounters()
        self.accesses = 0          # trace/fetch page probes
        self.prefetch_issued = 0   # look-ahead reads charged to the device

    @property
    def layout(self):
        return self.inner.layout

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    # -- PageStore protocol --------------------------------------------------

    def fetch(self, page_ids: np.ndarray,
              vids: Optional[np.ndarray] = None) -> dict:
        page_ids = np.asarray(page_ids, np.int64).reshape(-1)
        self.counters.pages_requested += len(page_ids)
        if vids is not None:
            # vertex-granular requests belong to the static-vertex layer —
            # pass through, mirroring the inner store's counter movement
            return fetch_mirroring_inner(self.counters, self.inner,
                                         page_ids, vids)
        hit = np.fromiter((self.cache.access(int(p)) for p in page_ids),
                          bool, len(page_ids))
        self.accesses += len(page_ids)
        self.counters.cache_hits += int(hit.sum())
        misses = page_ids[~hit]
        self.counters.pages_fetched += len(misses)
        self.counters.records_fetched += len(misses) * self.layout.n_p
        if len(misses):
            self.inner.fetch(misses)
        lay = self.layout
        return {"vids": lay.page_vids[page_ids],
                "vecs": lay.page_vecs[page_ids],
                "nbrs": lay.page_nbrs[page_ids]}

    def kernel_arrays(self) -> tuple:
        return self.inner.kernel_arrays()

    def vertex_cache_mask(self) -> np.ndarray:
        return self.inner.vertex_cache_mask()

    def note_kernel_io(self, stats) -> None:
        # replay_batch is this store's accounting path; forward only
        self.inner.note_kernel_io(stats)

    # -- trace replay (the serving-path accounting) --------------------------

    def replay_batch(self, page_trace: np.ndarray) -> dict:
        """page_trace: (B, hops, w) int32, -1 padded — each query's charged
        pages in hop order (QueryStats.page_trace). Replays queries in
        dispatch order against the shared cache; returns the batch's device
        accounting:

          requested         trace page accesses (== sum of page_reads)
          issued            reads charged to the device (demand misses +
                            look-ahead issues)
          hits              accesses served by the resident cache
          per_query_issued  (B,) float64 — reads charged while replaying
                            each query (its latency share)
          prefetch_issued   look-ahead reads within `issued`
          overlap_frac      prefetch_issued / issued (the latency-hiding
                            fraction for the device model)
          hit_rate          hits / requested
        """
        trace = np.asarray(page_trace)
        if trace.ndim != 3:
            raise ValueError(
                f"page_trace must be (B, hops, w); got shape {trace.shape}")
        B = trace.shape[0]
        per_query = np.zeros(B, np.float64)
        requested = hits = issued = prefetched = 0
        for b in range(B):
            hop_pages = [row[row >= 0] for row in trace[b]]
            for h, row in enumerate(hop_pages):
                if len(row) == 0:
                    continue
                # look-ahead: issue the next hops' pages while h computes
                for ahead in hop_pages[h + 1: h + 1 + self.lookahead]:
                    for p in ahead:
                        if int(p) not in self.cache:
                            self.cache.access(int(p))
                            issued += 1
                            prefetched += 1
                            per_query[b] += 1
                for p in row:
                    requested += 1
                    if self.cache.access(int(p)):
                        hits += 1
                    else:
                        issued += 1
                        per_query[b] += 1
        self.accesses += requested
        self.prefetch_issued += prefetched
        self.counters.pages_requested += requested
        self.counters.cache_hits += hits
        self.counters.pages_fetched += issued
        self.counters.records_fetched += issued * self.layout.n_p
        return {"requested": requested, "issued": issued, "hits": hits,
                "per_query_issued": per_query,
                "prefetch_issued": prefetched,
                "overlap_frac": prefetched / issued if issued else 0.0,
                "hit_rate": hits / requested if requested else 0.0}

    def hit_rate(self) -> float:
        """Lifetime hit rate over every access this store has seen."""
        return (self.counters.cache_hits / self.accesses
                if self.accesses else 0.0)

    def reset_cache(self) -> None:
        self.cache.reset()


class PrefetchingPageStore(SharedCachePageStore):
    """SharedCachePageStore with look-ahead on by default: the named form
    `build_store(..., prefetch=k)` composes. Kept as its own class so the
    store stack reads as policy objects (isinstance tells the configuration)."""

    def __init__(self, inner, cache: PageCache, lookahead: int = 1):
        if lookahead < 1:
            raise ValueError(
                f"lookahead={lookahead} must be >= 1 for a prefetching "
                f"store (use SharedCachePageStore for pure caching)")
        super().__init__(inner, cache, lookahead=lookahead)
