import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the very first lines — jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell on the production mesh and record roofline inputs.

For each cell this writes benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
with:
  - compiled.cost_analysis()  (per-device HLO FLOPs / bytes accessed)
  - compiled.memory_analysis() (argument/output/temp/peak bytes per device)
  - per-category collective bytes parsed from the post-SPMD HLO
  - compile wall time, HLO op histogram

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  ...
  (--force to recompute cached artifacts; --tag to write an alternative
   artifact set, used by the perf hillclimb)
"""
import argparse
import functools
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPE_NAMES, applicable_shapes,
                           get_config, get_shape)
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, decode_step, input_specs, loss_fn, prefill_step
from repro.parallel.api import ParallelContext
from repro.parallel import sharding as sh
from repro.training import optim

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"\b(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u8|u16|u32|u64|pred)"
    r"\[([\d,]*)\][^=]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b")
_DTYPE_BYTES = {"f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4,
                "f64": 8, "s4": 1, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
                "u8": 1, "u16": 2, "u32": 4, "u64": 8, "pred": 1}


def parse_collectives(hlo_text: str):
    """Per-device bytes by collective category from post-SPMD HLO.
    Result-shape bytes; -start/-done pairs counted once (via -start)."""
    out = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        dt, dims, kind = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + b
        out.setdefault(kind + "_count", 0)
        out[kind + "_count"] += 1
    return out


def pick_profile(cfg, shape) -> str:
    """Auto parallelism profile (§Perf iterations, EXPERIMENTS.md):
      - train/prefill of sub-8B dense models  -> "fsdp" (pure ZeRO-3)
      - decode when a 16-way TP shard fits    -> "tp"   (no per-token weight
                                                          gathers over data)
      - everything else                        -> "2d"  (FSDP x TP)
    Override with REPRO_PROFILE=2d|fsdp|tp."""
    env = os.environ.get("REPRO_PROFILE")
    if env:
        return env
    if (shape.mode == "train" and cfg.moe is None
            and cfg.param_count() < 8e9):
        return "fsdp"
    if (shape.mode == "prefill" and cfg.moe is None
            and cfg.param_count() < 8e9
            and (cfg.is_attention_free or cfg.num_kv_heads < 16)):
        # full-MHA archs (stablelm-3b kv=32) prefill better under 2d TP —
        # measured §Perf prefill iteration
        return "fsdp"
    if shape.mode == "decode" and cfg.param_count() * 2 / 16 < 4e9:
        return "tp"
    return "2d"


def build_cell(arch: str, shape_name: str, mesh, *, include_optimizer=True):
    """Returns (jitted_fn, kwargs_of_ShapeDtypeStructs)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    # sequence parallelism pays when the gathered K/V inside attention is
    # smaller than the (B,S,D) all-reduce it replaces — i.e. GQA (kv<heads),
    # attention-free mixers, or models small enough that gathers are noise.
    # Full-MHA stablelm-3b measured 0.6x under seq-shard (§Perf).
    seq_shard = (cfg.moe is None
                 and (cfg.is_attention_free
                      or cfg.num_kv_heads < cfg.num_heads
                      or cfg.param_count() < 1e9))
    ctx = ParallelContext(
        mesh, profile=pick_profile(cfg, shape),
        gather_quant=os.environ.get("REPRO_GATHER_QUANT", "0") == "1",
        seq_shard=seq_shard)
    specs = input_specs(cfg, shape)
    aparams = abstract_params(cfg)
    pspec = sh.param_pspecs(ctx, cfg, aparams)
    p_shard = jax.tree.map(ctx.sharding, pspec)
    in_pspec = sh.batch_pspecs(ctx, cfg, specs)

    if shape.mode == "train":
        opt_cfg = optim.for_model(cfg)
        astate = jax.eval_shape(functools.partial(optim.init_state, opt=opt_cfg),
                                aparams)
        spspec = sh.opt_state_pspecs(ctx, cfg, astate, pspec)
        s_shard = jax.tree.map(ctx.sharding, spspec)
        b_shard = jax.tree.map(ctx.sharding,
                               {k: in_pspec[k] for k in specs})

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, batch, parallel=ctx,
                                       remat_policy=os.environ.get(
                                           "REPRO_REMAT", "full"))
            params, opt_state, om = optim.apply_updates(
                params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": loss, **metrics, **om}

        fn = jax.jit(train_step,
                     in_shardings=(p_shard, s_shard, b_shard),
                     out_shardings=(p_shard, s_shard, None),
                     donate_argnums=(0, 1))
        args = (aparams, astate, specs)
        return fn, args, ctx

    if shape.mode == "prefill":
        b_shard = jax.tree.map(ctx.sharding, {k: in_pspec[k] for k in specs})
        cache_spec = jax.eval_shape(
            lambda: __import__("repro.models.transformer", fromlist=["init_cache"]
                               ).init_cache(cfg, shape.global_batch, shape.seq_len))
        c_shard = jax.tree.map(ctx.sharding, sh.cache_pspecs(ctx, cfg, cache_spec))
        logit_shard = ctx.sharding(sh.logits_pspec(ctx, shape.global_batch))

        def pf(params, batch):
            return prefill_step(params, cfg, batch, parallel=ctx)

        fn = jax.jit(pf, in_shardings=(p_shard, b_shard),
                     out_shardings=(logit_shard, c_shard))
        return fn, (aparams, specs), ctx

    # decode
    cache = specs.pop("cache")
    c_shard = jax.tree.map(ctx.sharding, sh.cache_pspecs(ctx, cfg, cache))
    tok_shard = ctx.sharding(in_pspec["tokens"])
    logit_shard = ctx.sharding(sh.logits_pspec(ctx, shape.global_batch))
    mrope = specs.get("mrope_positions")

    def dec(params, tokens, cache, cur_index, mrope_positions=None):
        return decode_step(params, cfg, tokens, cache, cur_index,
                           parallel=ctx, mrope_positions=mrope_positions)

    in_sh = [p_shard, tok_shard, c_shard, ctx.sharding(jax.sharding.PartitionSpec())]
    args = [aparams, specs["tokens"], cache, specs["cur_index"]]
    if mrope is not None:
        in_sh.append(ctx.sharding(in_pspec["mrope_positions"]))
        args.append(mrope)
    fn = jax.jit(dec, in_shardings=tuple(in_sh),
                 out_shardings=(logit_shard, c_shard),
                 donate_argnums=(2,))
    return fn, tuple(args), ctx


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             force=False):
    mesh_tag = "multi" if multi_pod else "single"
    out = out_dir / mesh_tag / f"{arch}__{shape_name}.json"
    if out.exists() and not force:
        print(f"[skip cached] {mesh_tag}/{arch}/{shape_name}")
        return json.loads(out.read_text())
    out.parent.mkdir(parents=True, exist_ok=True)
    cfg = get_config(arch)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, ctx = build_cell(arch, shape_name, mesh)
        t1 = time.time()
        lowered = fn.lower(*args)
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(f"  memory_analysis[{arch}/{shape_name}]: {mem}")
        print(f"  cost_analysis[{arch}/{shape_name}]: flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
        hlo = compiled.as_text()
        from repro.parallel.hloanalysis import analyze_hlo
        ana = analyze_hlo(hlo)
        rec.update({
            "ok": True,
            "lower_s": round(t2 - t1, 2), "compile_s": round(t3 - t2, 2),
            # raw XLA numbers (while bodies counted ONCE — see hloanalysis.py)
            "xla_flops_raw": cost.get("flops", 0.0),
            "xla_bytes_raw": cost.get("bytes accessed", 0.0),
            # trip-count-corrected per-device numbers
            "flops": ana["flops"],
            "traffic_bytes": ana["traffic_bytes"],
            "collectives": ana["collectives"],
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            },
            "n_devices": int(mesh.size),
        })
        print(f"[ok] {mesh_tag}/{arch}/{shape_name}: compile={t3-t2:.1f}s "
              f"flops={rec['flops']:.3e} "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"coll={sum(v for k, v in ana['collectives'].items() if not k.endswith('count'))/2**30:.2f}GiB")
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {mesh_tag}/{arch}/{shape_name}: {type(e).__name__}: {e}")
    rec["total_s"] = round(time.time() - t0, 2)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = ART_DIR if not args.tag else ART_DIR.parent / f"dryrun_{args.tag}"
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = n_skip = 0
    for mp in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = (applicable_shapes(cfg) if args.shape == "all"
                      else [args.shape])
            for s in shapes:
                if s not in applicable_shapes(cfg):
                    print(f"[n/a] {arch}/{s} (long-context skip, see DESIGN.md)")
                    n_skip += 1
                    continue
                rec = run_cell(arch, s, multi_pod=(mp == "multi"),
                               out_dir=out_dir, force=args.force)
                if rec.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"\ndry-run done: ok={n_ok} fail={n_fail} skipped-n/a={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
