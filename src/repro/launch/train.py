"""Training launcher: jit'd train step + checkpoint/restart + straggler
monitor + optional gradient compression. Runs REAL training on this CPU
container with reduced configs (--smoke) and lowers unchanged for the
production mesh (launch/dryrun.py proves the full-scale compile).

Die-and-resume drill (used by tests/test_training_checkpoint.py):
  python -m repro.launch.train --arch tinyllama-1.1b --smoke --steps 60 \
      --ckpt-dir /tmp/ck --die-at 25        # simulated failure
  python -m repro.launch.train ... --resume # restarts from step 25
"""
from __future__ import annotations

import argparse
import functools
import json
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import init_params, loss_fn
from repro.training import checkpoint as ckpt
from repro.training import compression, optim


class StragglerMonitor:
    """Flags steps (or, multi-host, peers) slower than 3x the running
    median — on a real cluster this triggers hot-spare promotion; here it
    logs and records (the mitigation hook is the same code path)."""

    def __init__(self, factor=3.0, warmup=5):
        self.times, self.factor, self.warmup = [], factor, warmup
        self.flagged = 0

    def record(self, dt: float):
        self.times.append(dt)
        if len(self.times) > self.warmup:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                self.flagged += 1
                print(f"[straggler] step took {dt*1e3:.0f}ms "
                      f"(median {med*1e3:.0f}ms) — would trigger "
                      f"re-assignment on a cluster")


def make_train_step(cfg, opt_cfg, compress=False, accum=1):
    @jax.jit
    def step_fn(params, opt_state, err_state, batch):
        if accum > 1:
            from repro.training.accumulate import accumulated_grads
            (loss, metrics), grads = accumulated_grads(
                lambda p, b: loss_fn(p, cfg, b, remat_policy="none"),
                params, batch, accum)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, batch,
                                       remat_policy="none")
        if compress:
            grads, err_state = compression.ef_compress_tree(grads, err_state)
        params, opt_state, om = optim.apply_updates(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, err_state, {"loss": loss, **om}

    return step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient accumulation microbatches")
    ap.add_argument("--die-at", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = optim.for_model(cfg, lr=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt_state = optim.init_state(params, opt_cfg)
    err_state = compression.init_error_state(params)
    step_fn = make_train_step(cfg, opt_cfg, compress=args.compress_grads,
                              accum=args.accum)

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"[resume] restored step {start} from {args.ckpt_dir}")

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    mon = StragglerMonitor()
    losses = []
    for step in range(start, args.steps):
        if step == args.die_at:
            print(f"[failure-sim] dying at step {step} (checkpointed "
                  f"through step {step - step % args.ckpt_every})")
            sys.exit(42)
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        if cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.num_frames, cfg.d_model), jnp.float32)
        if cfg.rope_variant == "mrope":
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None], (3, args.batch, args.seq))
        params, opt_state, err_state, m = step_fn(
            params, opt_state, err_state, batch)
        loss = float(m["loss"])
        losses.append(loss)
        mon.record(time.time() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
    print(f"done: first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": losses, "start": start,
                       "straggler_flags": mon.flagged}, f)
    return losses


if __name__ == "__main__":
    main()
