"""Serving launcher: batched generation (optionally RAG-augmented) with the
selected --arch, plus simple request-level continuous batching: a waiting
queue feeds fixed decode slots; finished requests free their slot each step.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 12 --batch-slots 4 --new-tokens 16 [--rag]

On hardware the same step functions lower onto the production mesh with the
`tp` decode profile (launch/dryrun.py proves prefill_32k/decode_32k compile
at 256/512 chips).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.engine import LMServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--rag", action="store_true",
                    help="prepend OctopusANN retrievals to each prompt")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    server = LMServer(params, cfg,
                      max_len=args.prompt_len * 2 + args.new_tokens)

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, cfg.vocab_size, args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]

    retriever = None
    if args.rag:
        from repro.core import build_index, get_preset, make_dataset
        ds = make_dataset("deep-like", n=2048, nq=1)
        retriever = (build_index(ds, get_preset("octopusann",
                                                memgraph_frac=0.02),
                                 R=16, L_build=32), ds)

    done, t0 = 0, time.time()
    while queue:
        batch = queue[:args.batch_slots]
        queue = queue[args.batch_slots:]
        prompts = np.stack(batch)
        if retriever is not None:
            idx, ds = retriever
            qvecs = ds.vectors[rng.choice(ds.n, len(batch))]
            res = idx.search(qvecs)
            ctx = (res.ids[:, :args.prompt_len] % cfg.vocab_size).astype(np.int32)
            prompts = np.concatenate([ctx, prompts], axis=1)
        out = server.generate(prompts, new_tokens=args.new_tokens)
        done += len(batch)
        print(f"[serve] completed {done}/{args.requests} "
              f"({done*args.new_tokens/(time.time()-t0):.1f} tok/s)")
    print(f"served {done} requests in {time.time()-t0:.1f}s")
    return done


if __name__ == "__main__":
    main()
