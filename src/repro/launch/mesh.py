"""Production mesh factory. A FUNCTION (not a module-level constant) so that
importing this module never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:
        # dry-run host platform exposes 512 placeholder devices; the
        # single-pod mesh uses the first 256
        dev = np.asarray(devices[:n]).reshape(shape)
        return jax.sharding.Mesh(dev, axes)
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devices)} — the "
        "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "before any jax import")


def make_local_mesh(axes=("data", "model")):
    """1-device mesh for CPU tests/examples (everything replicated)."""
    dev = np.asarray(jax.devices()[:1]).reshape((1,) * len(axes))
    return jax.sharding.Mesh(dev, axes)
