"""ParallelContext: the single source of truth for mesh-axis decisions.

Both the GSPMD param/input shardings (parallel/sharding.py) and the explicit
shard_map collectives (models/moe.py) consult this object, so the two can
never disagree about where a tensor lives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# params above this count get their expert d_model FSDP-sharded over `pod`
_POD_FSDP_THRESHOLD = 3e11


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """profile:
      "2d"   — FSDP x TP (batch over (pod,data), weights (data, model)) —
               the right scheme for TP-worthy models and for decode latency.
      "fsdp" — pure ZeRO-3: batch AND params sharded over every mesh axis,
               no tensor parallelism — the right scheme for <8B dense models
               on a 256-chip pod, where TP=16 activation all-reduces dwarf
               FSDP param gathers (§Perf iteration 1).
    gather_quant: fp8 weight gathers for the MoE FSDP path (§Perf, kimi).
    """
    mesh: Mesh
    profile: str = "2d"          # "2d" | "fsdp" | "tp"
    gather_quant: bool = False
    seq_shard: bool = True       # sequence parallelism (off for MoE archs —
                                 # their EP design token-replicates over model)

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.mesh.shape

    def has_axis(self, name: str) -> bool:
        return name in self.mesh.shape and self.mesh.shape[name] > 1

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def axes_size(self, names: Sequence[str]) -> int:
        n = 1
        for a in names:
            n *= self.axis_size(a)
        return n

    def batch_axes(self, batch: int) -> Tuple[str, ...]:
        """Largest divisible prefix of the profile's data axes."""
        cands = ([("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",)]
                 if self.profile == "fsdp" else
                 [("pod", "data"), ("data",)])
        for axes in cands:
            if not all(a in self.mesh.shape for a in axes):
                continue
            if batch % self.axes_size(axes) == 0 and self.axes_size(axes) > 1:
                return axes
        return ()

    def fsdp_weight_axes(self, dim: int):
        """Best divisible axis combo for ZeRO-3 weight sharding."""
        for axes in (("pod", "data", "model"), ("data", "model"),
                     ("data",), ("model",)):
            if all(a in self.mesh.shape for a in axes) and dim % self.axes_size(axes) == 0:
                return axes
        return None

    def dp_spec(self, batch: int):
        ax = self.batch_axes(batch)
        return ax if ax else None

    def divides(self, dim: int, axes) -> bool:
        if axes is None:
            return True
        if isinstance(axes, str):
            axes = (axes,)
        return dim % self.axes_size(axes) == 0

    def moe_weight_axes(self, cfg) -> dict:
        """How expert weights (E, d_model, d_ff) are sharded beyond EP."""
        d_ff_ax = None
        if (self.profile != "tp" and self.has_axis("data")
                and cfg.moe.d_ff_expert % self.axis_size("data") == 0):
            d_ff_ax = "data"
        d_model_ax = None
        if (self.multi_pod and cfg.param_count() > _POD_FSDP_THRESHOLD
                and cfg.d_model % self.axis_size("pod") == 0):
            d_model_ax = "pod"
        return {"d_ff": d_ff_ax, "d_model": d_model_ax}

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, *spec):
        """with_sharding_constraint helper (no-op on a trivial mesh)."""
        if self.mesh.size == 1:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def constrain_tokens_major(self, x, batch: int):
        """Activation layout between blocks: batch -> (pod, data); under the
        2d profile the SEQUENCE dim is additionally sharded over `model`
        (Megatron-style sequence parallelism — §Perf iteration: turns the
        per-layer (B,S,D) all-reduce into gathers of the much smaller GQA
        K/V tensors inside attention)."""
        dp = self.batch_axes(batch)
        seq_ax = None
        if (self.profile in ("2d", "fsdp") and self.seq_shard and x.ndim == 3
                and self.has_axis("model")
                and "model" not in (dp or ())
                and x.shape[1] % self.axis_size("model") == 0
                and x.shape[1] > 1):
            # 2d: Megatron sequence parallelism. fsdp-prefill: the batch may
            # not cover (data x model) — without seq-sharding the model axis
            # idles and GSPMD REPLICATES compute 4-5x (measured, §Perf)
            seq_ax = "model"
        if x.ndim == 3:
            return self.constrain(x, dp if dp else None, seq_ax, None)
        return self.constrain(x, dp if dp else None,
                              *([None] * (x.ndim - 1)))
