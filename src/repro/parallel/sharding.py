"""Logical-axis sharding rules: param / input / state PartitionSpecs.

Name-based rules (MaxText-style) with divisibility fallbacks: an axis is only
assigned if it divides the dimension; otherwise that dim stays replicated and
GSPMD inserts the resharding collectives (visible in the roofline — e.g.
whisper's 12 heads on a TP=16 mesh).

Conventions (mesh axes: optional "pod", "data", "model"):
  - 2-D param sharding (FSDP x TP): weights (d_model, d_ff)-like get
    (data, model); their transposes (model, data).
  - embeddings/lm_head: vocab -> model, d_model unsharded (gathers stay local)
  - MoE experts: E -> model (EP); d_ff -> data; d_model -> pod for 1T-class
  - KV caches: kv_heads -> model when divisible, else sequence -> model
    (flash-decoding style); batch -> (pod, data).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.api import ParallelContext


def _spec(ctx: ParallelContext, shape, axes):
    """Build a PartitionSpec, dropping any axis that doesn't divide."""
    out = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
        elif ctx.divides(dim, ax) and all(
                ctx.axis_size(a) >= 1 for a in ((ax,) if isinstance(ax, str) else ax)):
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _param_rule(ctx: ParallelContext, cfg, path: str, leaf) -> P:
    shape = leaf.shape
    nd = len(shape)
    stacked = path.startswith("stages/") or path.startswith("encoder/")
    body = shape[1:] if stacked else shape

    def done(axes):
        axes = tuple(axes)
        sp = _spec(ctx, body, axes)
        if stacked:
            return P(None, *sp)
        return sp

    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""
    # "tp" profile (decode): weights sharded over model ONLY — 2D (data x
    # model) sharding makes every decode step all-gather weight shards over
    # `data` (§Perf chatglm iteration: 1.35 GiB -> ~0 per token)
    da = None if ctx.profile == "tp" else "data"

    if ctx.profile == "fsdp" and parent != "moe":
        # ZeRO-3: shard the last dim over every divisible mesh axis,
        # replicate the rest (GSPMD inserts per-layer AG / grad RS).
        # 1-D params (norm scales, mixing coefficients) are sharded too —
        # replicating them makes their grads full all-reduces (§Perf rwkv).
        if len(body) >= 1:
            ax = ctx.fsdp_weight_axes(body[-1])
            return done((None,) * (len(body) - 1) + (ax,))
        return done((None,) * len(body))

    if parent == "moe" and name in ("wi", "wg"):   # (E, D, F) experts
        w = ctx.moe_weight_axes(cfg)
        return done(("model", w["d_model"], w["d_ff"]))
    if parent == "moe" and name == "wo":           # (E, F, D)
        w = ctx.moe_weight_axes(cfg)
        return done(("model", w["d_ff"], w["d_model"]))
    if parent == "moe" and name == "router":
        return done((None, None))

    if name == "table":                      # embedding (V, D)
        return done(("model", None))
    if name == "lm_head":                    # (D, V)
        return done((None, "model"))

    if name in ("wq", "wk", "wv", "wi", "wg", "cm_wk", "cm_wr", "wr",
                "in_proj", "x_proj_in"):
        if len(body) == 2:
            return done((da, "model"))
    if name in ("wo", "cm_wv", "out_proj", "dt_proj"):
        if len(body) == 2:
            return done(("model", da))
    if name == "x_proj":
        return done(("model", None))
    if name == "conv_w":
        return done((None, "model"))
    if name in ("conv_b", "dt_bias", "d_skip"):
        return done(("model",))
    if name == "a_log":
        return done(("model", None))
    if name == "lora_a":
        return done((da, None))
    if name == "lora_b":
        return done((None, da))
    # norms, biases, mixing coefficients, u: replicated
    return done((None,) * len(body))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(ctx: ParallelContext, cfg, abstract_params):
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _param_rule(ctx, cfg, _path_str(kp), leaf),
        abstract_params)


def opt_state_pspecs(ctx: ParallelContext, cfg, abstract_state, param_specs):
    """Optimizer state mirrors param sharding; factored stats drop the
    corresponding trailing dim."""
    def per_param(pspec, stats):
        base = list(pspec)
        out = {}
        for k in stats:
            if k in ("m", "v"):
                out[k] = pspec
            elif k == "vr":
                out[k] = P(*base[:-1])
            elif k == "vc":
                out[k] = P(*(base[:-2] + base[-1:]))
        return out

    mu = jax.tree.map(per_param, param_specs, abstract_state["mu"],
                      is_leaf=lambda x: isinstance(x, P))
    return {"mu": mu, "step": P()}


def batch_pspecs(ctx: ParallelContext, cfg, specs: Dict[str, Any]):
    """Shardings for input_specs() pytrees (train/prefill/decode)."""
    out: Dict[str, Any] = {}
    for k, v in specs.items():
        if k == "tokens":
            b = v.shape[0]
            out[k] = P(ctx.dp_spec(b), None)
        elif k == "frames":
            out[k] = P(ctx.dp_spec(v.shape[0]), None, None)
        elif k == "mrope_positions":
            out[k] = P(None, ctx.dp_spec(v.shape[1]), None)
        elif k == "cur_index":
            out[k] = P()
        elif k == "cache":
            out[k] = cache_pspecs(ctx, cfg, v)
        else:
            out[k] = P()
    return out


def cache_pspecs(ctx: ParallelContext, cfg, abstract_cache):
    """KV/SSM state shardings (leading dim = stages stack)."""
    def rule(kp, leaf):
        path = _path_str(kp)
        shape = leaf.shape  # (ns, B, ...)
        name = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""
        b = shape[1]
        dp = ctx.dp_spec(b)
        if parent in ("kv", "xkv"):            # (ns, B, S, KV, hd)
            kvh, s = shape[3], shape[2]
            if ctx.divides(kvh, "model") and ctx.has_axis("model"):
                return P(None, dp, None, "model", None)
            if ctx.divides(s, "model"):
                return P(None, dp, "model", None, None)
            return P(None, dp, None, None, None)
        if name == "wkv":                       # (ns, B, H, K, V)
            if ctx.divides(shape[2], "model") and ctx.has_axis("model"):
                return P(None, dp, "model", None, None)
            if ctx.divides(shape[4], "model"):
                return P(None, dp, None, None, "model")
            return P(None, dp, None, None, None)
        if name in ("shift_tm", "shift_cm"):    # (ns, B, D)
            ax = "model" if ctx.divides(shape[2], "model") else None
            return P(None, dp, ax)
        if name == "conv":                      # (ns, B, K-1, Di)
            ax = "model" if ctx.divides(shape[3], "model") else None
            return P(None, dp, None, ax)
        if name == "ssm":                       # (ns, B, Di, N)
            ax = "model" if ctx.divides(shape[2], "model") else None
            return P(None, dp, ax, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def logits_pspec(ctx: ParallelContext, batch):
    return P(ctx.dp_spec(batch), "model")
