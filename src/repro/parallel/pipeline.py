"""Pipeline parallelism (GPipe schedule) over the `pod` axis.

For multi-pod meshes the inter-pod links are the scarcest resource; pipeline
parallelism sends only layer activations across pods — one
(microbatch, seq, d_model) tensor per stage boundary per tick — instead of
gradient/param traffic over the slow axis. This module provides the schedule
as a reusable combinator:

  y = gpipe(stage_fn, stage_params, x, n_micro, axis="pod", mesh=mesh)

  - `stage_params` leaves carry a leading stage axis sharded over `axis`
    (each pod holds ONLY its stage's parameters);
  - activations hop stage->stage+1 with `jax.lax.ppermute` (the canonical
    pipeline collective);
  - the bubble is the standard (S-1)/(M+S-1) GPipe bubble; microbatches keep
    it small.

Used by tests/test_pipeline.py (2-stage compile + exactness vs the
unpipelined reference) and available as a `pp` building block for pod-scale
depth sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn, stage_params, x, n_micro: int, *, axis: str, mesh):
    """stage_fn(params_slice, x_micro) -> y_micro, applied as S pipeline
    stages over mesh axis `axis`. x: (B, ...) with B % n_micro == 0.
    Returns the same-shaped output after all S stages."""
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0

    def body(params_local, x_rep):
        """Runs on every pod; params_local: this pod's stage params
        (leading stage axis stripped to size 1)."""
        sid = jax.lax.axis_index(axis)
        p_stage = jax.tree.map(lambda a: a[0], params_local)
        micro = x_rep.reshape(n_micro, b // n_micro, *x_rep.shape[1:])
        n_ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, out = carry
            # stage sid works on microbatch (t - sid) when in range
            mb_id = t - sid
            active = (mb_id >= 0) & (mb_id < n_micro)
            # stage 0 reads fresh input; others read the handed-over buf
            x_in = jnp.where(sid == 0,
                             micro[jnp.clip(mb_id, 0, n_micro - 1)], buf)
            y = stage_fn(p_stage, x_in)
            y = jnp.where(active, y, buf)
            # last stage deposits finished microbatches
            done_id = t - (n_stages - 1)
            deposit = (sid == n_stages - 1) & (done_id >= 0) & (done_id < n_micro)
            out = jax.lax.cond(
                deposit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.clip(done_id, 0, n_micro - 1),)
                    + (0,) * y.ndim),
                lambda o: o, out)
            # hand activations to the next stage
            buf_next = jax.lax.ppermute(y, axis, perm)
            return (buf_next, out), None

        buf0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros_like(micro)
        (b_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                    jnp.arange(n_ticks))
        # every pod computed `out`; only the last stage's is real — share it
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(b, *x_rep.shape[1:])

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    if hasattr(jax, "shard_map"):           # jax >= 0.6 top-level API
        smap = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=P(), check_vma=False)
    else:                                   # 0.4.x experimental spelling
        from jax.experimental.shard_map import shard_map
        smap = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                         check_rep=False)
    return smap(stage_params, x)
