"""Trip-count-aware analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers model under-reports FLOPs by ~num_layers x. This module
parses the optimized HLO, builds the computation call graph (fusion/call/
while/conditional), multiplies while bodies by their ``known_trip_count``
(present in backend_config after XLA loop analysis), and aggregates:

  - flops           : 2 * prod(result_dims) * prod(contracting_dims) per dot
                      (+ convolutions), trip-count weighted
  - traffic_bytes   : HBM-traffic estimate — sum of operand+result bytes of
                      top-level ops (fusion internals excluded: on TPU those
                      stay in registers/VMEM), trip-count weighted
  - collectives     : per-category bytes+counts (all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute),
                      trip-count weighted; result-shape bytes

All numbers are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "s4": 1, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u4": 1, "u8": 1, "u16": 2, "u32": 4, "u64": 8, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type may be a tuple containing /*index=N*/ comments (hence the lazy .*?);
# the earliest `word(` after the type is the opcode.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(([^)]*)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Op:
    __slots__ = ("name", "type_str", "opcode", "rest")

    def __init__(self, name, type_str, opcode, rest):
        self.name, self.type_str, self.opcode, self.rest = (
            name, type_str, opcode, rest)


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.shapes: Dict[str, str] = {}
        self.trip: Dict[str, int] = {}   # body computation name -> trip count
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            if not line.strip():
                continue
            if not line.startswith(" "):
                # computation header: `%name (params...) -> ret {` — params may
                # contain nested tuple parens, so match loosely
                stripped = line.rstrip()
                if stripped.endswith("{") and "->" in stripped:
                    mc = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
                    if mc:
                        cur = mc.group(1)
                        self.comps[cur] = []
                        if stripped.startswith("ENTRY"):
                            self.entry = cur
                        for pm in re.finditer(r"([\w\.\-]+):\s*([\w\[\],]+)",
                                              stripped.split("->")[0]):
                            self.shapes[pm.group(1)] = pm.group(2)
                        continue
            if line.strip() == "}":
                # computations end; nested ops are indented so this is safe
                continue
            mo = _OP_RE.match(line)
            if not mo or cur is None:
                continue
            name, type_str, opcode, rest = mo.groups()
            self.shapes[name] = type_str.strip()
            op = Op(name, type_str.strip(), opcode, rest)
            self.comps[cur].append(op)
            if opcode == "while":
                mb = _BODY_RE.search(rest)
                mt = _TRIP_RE.search(rest)
                if mb:
                    self.trip[mb.group(1)] = int(mt.group(1)) if mt else 1

    # -- per-op costs ------------------------------------------------------

    def _dot_flops(self, op: Op) -> float:
        out_dims = _shape_dims(op.type_str)
        mc = _CONTRACT_RE.search(op.rest)
        operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
        flops = 2.0
        for d in out_dims:
            flops *= d
        if mc and operands:
            lhs_shape = _shape_dims(self.shapes.get(operands[0], ""))
            for idx in mc.group(1).split(","):
                if idx and lhs_shape and int(idx) < len(lhs_shape):
                    flops *= lhs_shape[int(idx)]
        return flops

    def _op_traffic(self, op: Op) -> int:
        b = _shape_bytes(op.type_str)
        operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
        for o in operands:
            b += _shape_bytes(self.shapes.get(o, ""))
        return b

    # -- aggregation -------------------------------------------------------

    def analyze(self, entry: Optional[str] = None) -> Dict[str, float]:
        if entry is None:
            entry = self.entry
        if entry is None:
            mains = [c for c in self.comps if c.startswith("main")]
            entry = mains[0] if mains else next(iter(self.comps))

        acc = {"flops": 0.0, "traffic_bytes": 0.0, "transcendentals": 0.0}
        coll: Dict[str, float] = {}
        seen_stack = []

        def walk(comp: str, mult: float):
            if comp not in self.comps or comp in seen_stack:
                return
            seen_stack.append(comp)
            for op in self.comps[comp]:
                oc = op.opcode
                if oc == "while":
                    mb, mc_ = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                    trips = self.trip.get(mb.group(1), 1) if mb else 1
                    if mb:
                        walk(mb.group(1), mult * trips)
                    if mc_:
                        walk(mc_.group(1), mult * (trips + 1))
                    acc["traffic_bytes"] += mult * _shape_bytes(op.type_str)
                    continue
                if oc in ("fusion", "call", "async-start"):
                    m = _CALLS_RE.search(op.rest)
                    if m and oc == "call":
                        walk(m.group(1), mult)
                    elif m:  # fusion: count interior dots, traffic at boundary
                        for iop in self.comps.get(m.group(1), ()):
                            if iop.opcode == "dot":
                                acc["flops"] += mult * self._dot_flops(iop)
                            elif iop.opcode in ("exponential", "tanh", "log",
                                                "rsqrt", "power"):
                                acc["transcendentals"] += mult
                    acc["traffic_bytes"] += mult * self._op_traffic(op)
                    continue
                if oc == "conditional":
                    mb = _BRANCHES_RE.search(op.rest)
                    if mb:
                        for c in mb.group(1).split(","):
                            walk(c.strip().lstrip("%"), mult)
                    acc["traffic_bytes"] += mult * self._op_traffic(op)
                    continue
                base = oc.replace("-start", "")
                if base in COLLECTIVE_KINDS:
                    if oc.endswith("-done"):
                        continue
                    b = _shape_bytes(op.type_str)
                    if oc.endswith("-start"):
                        b //= 2  # async tuple type carries (operand, result)
                    coll[base] = coll.get(base, 0.0) + mult * b
                    coll[base + "_count"] = coll.get(base + "_count", 0.0) + mult
                    acc["traffic_bytes"] += mult * b
                    continue
                if oc == "dot":
                    acc["flops"] += mult * self._dot_flops(op)
                    acc["traffic_bytes"] += mult * self._op_traffic(op)
                    continue
                if oc == "convolution":
                    # flops ~= 2 * prod(out) * prod(kernel_spatial) * in_ch
                    out = _shape_dims(op.type_str)
                    operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
                    k = (_shape_dims(self.shapes.get(operands[1], ""))
                         if len(operands) > 1 else [])
                    f = 2.0
                    for d in out:
                        f *= d
                    for d in k[:-1]:
                        f *= d
                    acc["flops"] += mult * f
                    acc["traffic_bytes"] += mult * self._op_traffic(op)
                    continue
                if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                          "bitcast", "after-all", "iota"):
                    continue
                acc["traffic_bytes"] += mult * self._op_traffic(op)
            seen_stack.pop()

        walk(entry, 1.0)
        acc["collectives"] = coll
        return acc

    # -- per-op collective profile (hillclimb tool) -------------------------

    def collective_profile(self, entry: Optional[str] = None, top: int = 20):
        """Top collective ops by trip-weighted bytes, with shapes and the
        source op_name metadata — the 'profile' for the §Perf loop."""
        entry = entry or self.entry or next(iter(self.comps))
        rows = []

        def walk(comp, mult, stack):
            if comp not in self.comps or comp in stack:
                return
            stack.append(comp)
            for op in self.comps[comp]:
                oc = op.opcode
                if oc == "while":
                    mb, mc_ = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                    if mb:
                        walk(mb.group(1), mult * self.trip.get(mb.group(1), 1),
                             stack)
                    continue
                if oc == "call":
                    m = _CALLS_RE.search(op.rest)
                    if m:
                        walk(m.group(1), mult, stack)
                    continue
                base = oc.replace("-start", "")
                if base in COLLECTIVE_KINDS and not oc.endswith("-done"):
                    b = _shape_bytes(op.type_str)
                    if oc.endswith("-start"):
                        b //= 2
                    mm = re.search(r'op_name="([^"]*)"', op.rest)
                    rows.append({
                        "kind": base, "bytes": b * mult, "mult": mult,
                        "shape": op.type_str[:48],
                        "op_name": (mm.group(1)[-80:] if mm else ""),
                    })
            stack.pop()

        walk(entry, 1.0, [])
        rows.sort(key=lambda r: -r["bytes"])
        return rows[:top]


def analyze_hlo(text: str) -> Dict[str, float]:
    return HloModule(text).analyze()


def collective_profile(text: str, top: int = 20):
    return HloModule(text).collective_profile(top=top)
