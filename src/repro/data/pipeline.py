"""Deterministic synthetic token pipeline, host-sharded.

Every batch is a pure function of (seed, host, step): restarts resume exactly
(no data-order drift after a failure), hosts never overlap shards, and a
straggling host can be re-assigned a shard deterministically. Zipf-ish token
marginals + an order-2 mixing process give non-trivial learnable structure so
example training losses actually fall.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed random bigram mixer: t_{i+1} = perm[t_i] with prob .7
        self.perm = rng.permutation(v)
        ranks = np.arange(1, v + 1)
        self.marginal = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + c.host_index) * 1_000_033 + step)
        b, s = c.host_batch, c.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.choice(c.vocab_size, b, p=self.marginal)
        follow = rng.random((b, s)) < 0.7
        fresh = rng.choice(c.vocab_size, (b, s), p=self.marginal)
        for t in range(1, s):
            toks[:, t] = np.where(follow[:, t], self.perm[toks[:, t - 1]],
                                  fresh[:, t])
        return {"tokens": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
