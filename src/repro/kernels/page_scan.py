"""page_scan — the paper's disk path, TPU-native (DESIGN.md §2).

One kernel fuses three of the paper's techniques:
  * the "4 KB random page read" becomes a dynamic-index HBM->VMEM block DMA
    driven by scalar-prefetched page ids (PrefetchScalarGridSpec);
  * *Pipeline* (§4.3.2) is the Pallas grid pipeline: the DMA for page i+1
    overlaps the MXU compute on page i (double buffering) — no speculation,
    so the Finding-5 penalty does not exist on TPU;
  * *PageSearch* (§4.3.3) is free: the MXU scores ALL n_p records of the
    fetched tile against the whole query block in one (n_p, d) x (d, Q)
    matmul — computing only the target record would waste the tile anyway.

Layout contract (TPU tiling): d padded to 128 lanes, n_p to 8 sublanes,
Q (query block) a multiple of 128 for MXU efficiency. The CPU container runs
the kernel in interpret mode; tests/test_kernels.py sweeps shapes/dtypes
against ref.page_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(page_ids_ref, q_ref, qsq_ref, pages_ref, out_ref):
    """Grid step i handles page page_ids[i].
    q_ref (d, Q) VMEM; pages_ref block (1, n_p, d); out (1, n_p, Q)."""
    x = pages_ref[0].astype(jnp.float32)                  # (n_p, d)
    q = q_ref[...].astype(jnp.float32)                    # (d, Q)
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)   # (n_p, 1)
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)  # MXU (n_p, Q)
    out_ref[0] = x2 - 2.0 * xq + qsq_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_scan(pages, page_ids, q, *, interpret=True):
    """pages (P, n_p, d); page_ids (W,); q (Q, d) -> (W, n_p, Q) f32."""
    p, n_p, d = pages.shape
    w = page_ids.shape[0]
    qn = q.shape[0]
    qt = jnp.swapaxes(q, 0, 1)                            # (d, Q)
    qsq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)[None, :]  # (1, Q)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((d, qn), lambda i, ids: (0, 0)),         # q
            pl.BlockSpec((1, qn), lambda i, ids: (0, 0)),         # qsq
            pl.BlockSpec((1, n_p, d), lambda i, ids: (ids[i], 0, 0)),  # page
        ],
        out_specs=pl.BlockSpec((1, n_p, qn), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w, n_p, qn), jnp.float32),
        interpret=interpret,
    )(page_ids.astype(jnp.int32), qt, qsq, pages)
