"""Pure-jnp oracles for the Pallas kernels (the correctness reference for
every shape/dtype sweep in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def page_scan_ref(pages, page_ids, q):
    """pages (P, n_p, d); page_ids (W,) int32; q (Q, d).
    Returns dists (W, n_p, Q) f32: squared L2 from every record of every
    fetched page to every query."""
    gathered = pages[page_ids]                                   # (W, n_p, d)
    g = gathered.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    x2 = jnp.sum(jnp.square(g), -1)[..., None]                   # (W,n_p,1)
    q2 = jnp.sum(jnp.square(qf), -1)[None, None, :]              # (1,1,Q)
    xq = jnp.einsum("wnd,qd->wnq", g, qf)
    return x2 - 2.0 * xq + q2


def pq_adc_ref(codes, lut):
    """codes (N, M) uint8; lut (M, 256) f32 -> dists (N,) f32 (ADC scan)."""
    m = lut.shape[0]
    gathered = jnp.take_along_axis(lut.T, codes.astype(jnp.int32), axis=0)
    return jnp.sum(gathered, axis=-1)


def fused_page_rank_ref(pages, page_codes, page_ids, q, lut):
    """Oracle for kernels/fused_search.fused_page_rank: the composition of
    page_scan_ref with a per-page, per-query ADC scan. pages (P, n_p, d);
    page_codes (P, n_p, M) uint8; page_ids (W,); q (Q, d); lut (Q, M, 256).
    Returns (exact (W, n_p, Q), adc (W, n_p, Q)) f32."""
    exact = page_scan_ref(pages, page_ids, q)
    codes = page_codes[page_ids].astype(jnp.int32)            # (W, n_p, M)
    onehot = jax.nn.one_hot(codes, 256, dtype=jnp.float32)    # (W,n_p,M,256)
    adc = jnp.einsum("wnmc,qmc->wnq", onehot, lut.astype(jnp.float32))
    return exact, adc
