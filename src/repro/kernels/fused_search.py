"""fused_search — the beam loop's page stream as ONE pipelined Pallas grid.

Before this kernel the disk hot path was two separately-jitted calls per
hop: page_scan (exact scoring of the fetched tiles) and pq_adc (ADC LUT
ranking of the residents' codes), each with its own grid, its own HBM pass
and its own dispatch. The fused kernel runs the WHOLE multi-hop page
schedule as a single PrefetchScalarGridSpec grid:

  grid step i handles page schedule[i] (the schedule is hop-major: hop t's
  pages first, then the pages LAANN-style look-ahead staged for hop t+1
  from the current frontier's best unexpanded candidates, and so on) —

    * the HBM->VMEM DMAs for step i+1's vector tile AND code tile are
      issued by the Pallas pipeline while step i computes: this is the
      double buffer the analytic `prefetch_overlap` rebate only modeled;
    * the body fuses both distance computations over the SAME resident
      tile: the exact (n_p, d) x (d, Q) page-scan matmul and the ADC LUT
      scan — with the whole stacked LUT resident in VMEM, the M
      per-subspace one-hot matmuls collapse into ONE (n_p, M*256) x
      (M*256, Q) MXU matmul — so hop t's PQ ranking overlaps hop t+1's
      fetch instead of serializing behind it.

VMEM budget per step (f32): page tile n_p*d*4 + code tile n_p*M + query
block d*Q*4 + stacked LUT M*256*Q*4 (the per-query LUTs live transposed as
(M, 256, Q) so each subspace's scan is one MXU matmul for the whole query
block) + two output tiles n_p*Q*4 — at the default shape (n_p=8, d=128,
M=16, Q=256) that is ~4.3 MiB, double-buffered well inside 16 MiB.

The kernel is a MEASUREMENT surface, not a result path: `pipeline="fused"`
searches still take their results from the reference beam search (bit
identity is golden-locked), and this kernel re-executes the traced page
schedule to produce a measured wall-clock step time next to the modeled
device time. tests/test_kernels.py sweeps it against composing
ref.page_scan_ref + ref.pq_adc_ref per page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(page_ids_ref, q_ref, qsq_ref, lut_ref, pages_ref,
                  codes_ref, out_exact_ref, out_adc_ref):
    """Grid step i: fused exact scan + ADC scan of page page_ids[i].
    q_ref (d, Q); lut_ref (M, 256, Q); pages block (1, n_p, d); codes block
    (1, n_p, M); outputs (1, n_p, Q) each."""
    x = pages_ref[0].astype(jnp.float32)                    # (n_p, d)
    q = q_ref[...].astype(jnp.float32)                      # (d, Q)
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)     # (n_p, 1)
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)  # MXU (n_p, Q)
    out_exact_ref[0] = x2 - 2.0 * xq + qsq_ref[...]

    # Fusion keeps the WHOLE stacked LUT resident as one VMEM block, so the
    # per-subspace scan collapses into a single MXU matmul: the (n_p, M)
    # codes become one (n_p, M*256) one-hot whose column layout matches the
    # LUT flattened to (M*256, Q) — summing the M per-subspace products is
    # the matmul's own reduction. (The standalone page_adc/pq_adc path keeps
    # the per-subspace form; this bigger matmul is what the fused schedule
    # buys on top of the double buffer.)
    codes = codes_ref[0]                                    # (n_p, M) uint8
    n_p, m = codes.shape
    qn = q_ref.shape[1]
    onehot = (codes[:, :, None].astype(jnp.int32)
              == jax.lax.broadcasted_iota(jnp.int32, (n_p, m, 256), 2))
    out_adc_ref[0] = jnp.dot(
        onehot.astype(jnp.float32).reshape(n_p, m * 256),
        lut_ref[...].reshape(m * 256, qn),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_page_rank(pages, page_codes, page_ids, q, lut, *, interpret=True):
    """One pipelined grid over the page schedule.

    pages (P, n_p, d); page_codes (P, n_p, M) uint8; page_ids (W,) int32
    (the hop-major schedule); q (Q, d); lut (Q, M, 256) per-query ADC LUTs.
    Returns (exact (W, n_p, Q), adc (W, n_p, Q)) f32.
    """
    p, n_p, d = pages.shape
    m = page_codes.shape[2]
    w = page_ids.shape[0]
    qn = q.shape[0]
    qt = jnp.swapaxes(q, 0, 1)                              # (d, Q)
    qsq = jnp.sum(jnp.square(q.astype(jnp.float32)), -1)[None, :]  # (1, Q)
    lut_t = jnp.transpose(lut.astype(jnp.float32), (1, 2, 0))  # (M, 256, Q)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((d, qn), lambda i, ids: (0, 0)),          # q
            pl.BlockSpec((1, qn), lambda i, ids: (0, 0)),          # qsq
            pl.BlockSpec((m, 256, qn), lambda i, ids: (0, 0, 0)),  # lut
            pl.BlockSpec((1, n_p, d), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, n_p, m), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_p, qn), lambda i, ids: (i, 0, 0)),
            pl.BlockSpec((1, n_p, qn), lambda i, ids: (i, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((w, n_p, qn), jnp.float32),
                   jax.ShapeDtypeStruct((w, n_p, qn), jnp.float32)],
        interpret=interpret,
    )(page_ids.astype(jnp.int32), qt, qsq, lut_t, pages, page_codes)


# --- the unfused counterpart (two separately-jitted grids) -----------------


def _adc_kernel(page_ids_ref, lut_ref, codes_ref, out_ref):
    codes = codes_ref[0]                                    # (n_p, M)
    n_p, m = codes.shape
    qn = lut_ref.shape[2]
    acc = jnp.zeros((n_p, qn), jnp.float32)
    for j in range(m):
        onehot = (codes[:, j][:, None].astype(jnp.int32)
                  == jax.lax.broadcasted_iota(jnp.int32, (n_p, 256), 1))
        acc = acc + jnp.dot(onehot.astype(jnp.float32), lut_ref[j],
                            preferred_element_type=jnp.float32)
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_adc(page_codes, page_ids, lut, *, interpret=True):
    """The ADC half alone, its own grid and dispatch — the second of the
    two calls the fused kernel replaces (the exact half alone is
    kernels/page_scan.py). page_codes (P, n_p, M) uint8; page_ids (W,);
    lut (Q, M, 256) -> (W, n_p, Q) f32."""
    p, n_p, m = page_codes.shape
    w = page_ids.shape[0]
    qn = lut.shape[0]
    lut_t = jnp.transpose(lut.astype(jnp.float32), (1, 2, 0))  # (M, 256, Q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((m, 256, qn), lambda i, ids: (0, 0, 0)),
            pl.BlockSpec((1, n_p, m), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_p, qn), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _adc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w, n_p, qn), jnp.float32),
        interpret=interpret,
    )(page_ids.astype(jnp.int32), lut_t, page_codes)
