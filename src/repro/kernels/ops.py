"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as Python/jnp per grid step); on a real TPU set interpret=False (the
default flips automatically on TPU backends).

Shape bucketing: the raw kernels are jitted per exact shape, so a beam
width that moves every step (DynamicWidth shrinking/growing the frontier,
the admission controller's degrade ladder) would trigger a recompile per
distinct width. The wrappers here pad the varying axis up to a power-of-two
bucket (mirroring MutableIndex's chunked-capacity trick, which bounds
recompiles the same way on the vid axis) and slice the result back, so the
whole width ladder 1..2^k shares k+1 compiled variants. Padding ids point
at page 0 (always valid); padded pq_adc rows are guarded to +inf by the
kernel itself (`nvalid`), so a bucket can never leak garbage distances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_search import fused_page_rank as _fused_page_rank
from repro.kernels.fused_search import page_adc as _page_adc
from repro.kernels.page_scan import page_scan as _page_scan
from repro.kernels.pq_adc import pq_adc as _pq_adc

_MIN_BUCKET = 4     # smallest width bucket (floor of the power-of-two ladder)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bucket_size(n: int, floor: int = _MIN_BUCKET) -> int:
    """Next power of two >= n (>= floor): the padded size whose compiled
    kernel this call shares with every other length in the bucket."""
    if n < 1:
        raise ValueError(f"bucket_size needs n >= 1, got {n}")
    b = floor
    while b < n:
        b *= 2
    return b


def _pad_ids(page_ids, bucket: int):
    """Pad a page-id schedule to its bucket with id 0 (always a valid page;
    the padded grid steps score page 0 and are sliced away)."""
    w = page_ids.shape[0]
    if w == bucket:
        return page_ids
    return jnp.concatenate(
        [page_ids, jnp.zeros((bucket - w,), page_ids.dtype)])


def page_scan(pages, page_ids, q):
    """Fused page-fetch + score-all-residents (PageSearch+Pipeline on TPU).
    Width-bucketed: all widths in (bucket/2, bucket] share one compile."""
    w = page_ids.shape[0]
    b = bucket_size(w)
    out = _page_scan(pages, _pad_ids(page_ids, b), q,
                     interpret=not _on_tpu())
    return out[:w]


def pq_adc(codes, lut, block_n=512):
    """ADC LUT scan over PQ codes (memory-layout PQ filter). Length-bucketed
    above the kernel's own block padding: all N in (bucket/2, bucket] share
    one compile, with the true length passed as a traced scalar and the pad
    tail guarded to +inf inside the kernel."""
    n = codes.shape[0]
    b = bucket_size(n, floor=min(block_n, bucket_size(n)))
    if b > n:
        codes = jnp.pad(codes, ((0, b - n), (0, 0)))
    out = _pq_adc(codes, lut, block_n=block_n, interpret=not _on_tpu(),
                  nvalid=jnp.int32(n))
    return out[:n]


def fused_page_rank(pages, page_codes, page_ids, q, lut):
    """The fused pipelined hot path (kernels/fused_search.py): one grid,
    double-buffered page DMA overlapping exact-scan + ADC compute.
    Width-bucketed like page_scan."""
    w = page_ids.shape[0]
    b = bucket_size(w)
    exact, adc = _fused_page_rank(pages, page_codes, _pad_ids(page_ids, b),
                                  q, lut, interpret=not _on_tpu())
    return exact[:w], adc[:w]


def page_adc(page_codes, page_ids, lut):
    """The ADC half as its own grid (the unfused counterpart the fused
    kernel absorbs; used for measured-overlap comparisons)."""
    w = page_ids.shape[0]
    b = bucket_size(w)
    out = _page_adc(page_codes, _pad_ids(page_ids, b), lut,
                    interpret=not _on_tpu())
    return out[:w]
