"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as Python/jnp per grid step); on a real TPU set interpret=False (the
default flips automatically on TPU backends).
"""
from __future__ import annotations

import jax

from repro.kernels.page_scan import page_scan as _page_scan
from repro.kernels.pq_adc import pq_adc as _pq_adc


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def page_scan(pages, page_ids, q):
    """Fused page-fetch + score-all-residents (PageSearch+Pipeline on TPU)."""
    return _page_scan(pages, page_ids, q, interpret=not _on_tpu())


def pq_adc(codes, lut, block_n=512):
    """ADC LUT scan over PQ codes (memory-layout PQ filter)."""
    return _pq_adc(codes, lut, block_n=block_n, interpret=not _on_tpu())
