from repro.kernels.ops import page_scan, pq_adc
