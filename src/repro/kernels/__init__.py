from repro.kernels.ops import (bucket_size, fused_page_rank, page_adc,
                               page_scan, pq_adc)
