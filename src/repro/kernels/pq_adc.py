"""pq_adc — MXU-native ADC (asymmetric distance computation) LUT scan.

The paper's PQ filter (§4.1.1) scans memory-resident codes against a per-query
lookup table. A CPU implementation gathers lut[m, code]; gathers are the weak
operation on TPU's vector unit, so the TPU-native form turns each subspace
scan into a one-hot (bn, 256) x (256,) matmul on the MXU — gather-free and
sublane-aligned. The LUT (M, 256) f32 = 16 KiB lives wholly in VMEM; codes
stream from HBM block-by-block through the grid pipeline (double-buffered).

Tiling contract: block_n multiple of 8 (sublanes); 256 = 2 lanes of 128.

Pad guard: N is padded up to a block_n multiple, and the padded tail used to
score the zero pad's codes as if they were real records — garbage distances
that any caller consuming the padded buffer (the shape-bucketed wrappers in
kernels/ops.py keep it) could mistake for candidates. The kernel now masks
every row at or past the true length to +inf; `nvalid` lets a bucketing
caller that pre-padded name the true length as a TRACED scalar, so one
compiled kernel serves every length inside a bucket.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nvalid_ref, codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]                                # (bn, M) uint8
    lut = lut_ref[...]                                    # (M, 256) f32
    bn, m = codes.shape
    acc = jnp.zeros((bn,), jnp.float32)
    for j in range(m):  # M is small and static: unrolled, each an MXU matmul
        onehot = (codes[:, j][:, None].astype(jnp.int32)
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, 256), 1))
        acc = acc + jnp.dot(onehot.astype(jnp.float32), lut[j],
                            preferred_element_type=jnp.float32)
    # pad-tail guard: rows past the true length scored the zero pad's codes
    # — poison them so no caller can rank the pad as a candidate
    row = pl.program_id(0) * bn + jax.lax.broadcasted_iota(
        jnp.int32, (bn,), 0)
    out_ref[...] = jnp.where(row < nvalid_ref[0], acc, jnp.inf)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "interpret", "keep_pad"))
def pq_adc(codes, lut, *, block_n=512, interpret=True, keep_pad=False,
           nvalid=None):
    """codes (N, M) uint8; lut (M, 256) f32 -> (N,) f32.

    `nvalid` (traced scalar, defaults to N) marks the true row count when
    the caller already padded `codes` (shape bucketing): rows >= nvalid
    come back +inf. `keep_pad=True` returns the full padded buffer (its
    tail guarded to +inf) instead of slicing — the bucketed wrappers slice
    once at their own bucket boundary."""
    n, m = codes.shape
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    np_ = codes.shape[0]
    nv = jnp.asarray([n if nvalid is None else nvalid], jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i, nv: (i, 0)),
            pl.BlockSpec((m, 256), lambda i, nv: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, nv: (i,)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(nv, codes, lut)
    return out if keep_pad else out[:n]
