"""pq_adc — MXU-native ADC (asymmetric distance computation) LUT scan.

The paper's PQ filter (§4.1.1) scans memory-resident codes against a per-query
lookup table. A CPU implementation gathers lut[m, code]; gathers are the weak
operation on TPU's vector unit, so the TPU-native form turns each subspace
scan into a one-hot (bn, 256) x (256,) matmul on the MXU — gather-free and
sublane-aligned. The LUT (M, 256) f32 = 16 KiB lives wholly in VMEM; codes
stream from HBM block-by-block through the grid pipeline (double-buffered).

Tiling contract: block_n multiple of 8 (sublanes); 256 = 2 lanes of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]                                # (bn, M) uint8
    lut = lut_ref[...]                                    # (M, 256) f32
    bn, m = codes.shape
    acc = jnp.zeros((bn,), jnp.float32)
    for j in range(m):  # M is small and static: unrolled, each an MXU matmul
        onehot = (codes[:, j][:, None].astype(jnp.int32)
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, 256), 1))
        acc = acc + jnp.dot(onehot.astype(jnp.float32), lut[j],
                            preferred_element_type=jnp.float32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_adc(codes, lut, *, block_n=512, interpret=True):
    """codes (N, M) uint8; lut (M, 256) f32 -> (N,) f32."""
    n, m = codes.shape
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    np_ = codes.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(codes, lut)
    return out[:n]
