"""Gradient accumulation (microbatching): the standard lever when the global
batch exceeds per-step memory — `lax.scan` over microbatches accumulating
grads in f32, one optimizer step at the end. Composes with any loss_fn and
with the EF compressor (compression applies to the accumulated gradient,
i.e. once per step, not per microbatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulated_grads(loss_fn, params, batch, n_micro: int, *loss_args,
                      **loss_kw):
    """batch: pytree with leading global-batch dims divisible by n_micro.
    Returns ((loss, aux_of_last_micro), grads) — grads averaged in f32."""
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(carry, mb):
        acc, loss_acc = carry
        (loss, aux), g = gfn(params, mb, *loss_args, **loss_kw)
        acc = jax.tree.map(
            lambda a, gi: a + gi.astype(jnp.float32) / n_micro, acc, g)
        return (acc, loss_acc + loss / n_micro), aux

    acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), aux = jax.lax.scan(step, (acc0, jnp.zeros((), jnp.float32)),
                                      micro)
    aux_last = jax.tree.map(lambda x: x[-1], aux)
    grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
    return (loss, aux_last), grads
