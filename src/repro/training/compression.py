"""Gradient compression for the data-parallel reducer.

int8 quantization with error feedback (EF-SGD style): each step transmits
round(g/scale) int8 + one f32 scale per tensor (≈4x wire reduction vs bf16,
8x vs f32); the quantization residual is fed back into the next step so the
optimizer sees an unbiased long-run gradient.

Under GSPMD the all-reduce is compiler-inserted, so the wire format is
emulated by quantize->dequantize around the gradient (numerics identical to
a compressed collective); under the explicit shard_map DP path
(launch/train.py --dp-shardmap) the psum genuinely carries int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_state):
    """Returns (compressed-dequantized grads, new error state)."""
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), (gf - deq).astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(g, axis_name):
    """int8 all-reduce for the shard_map DP path: quantize locally, sum the
    int8 payload (int32 accumulator), dequantize with the max scale."""
    q, s = quantize(g)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(s, axis_name)
    return (total.astype(jnp.float32) * smax).astype(g.dtype)
