"""Fault-tolerant checkpointing (pure JAX/numpy, no orbax dependency).

  - atomic writes (tmp file + rename) so a killed process never leaves a
    half-written checkpoint
  - keep-last-k pruning
  - per-process file naming for multi-host meshes (each host saves its
    addressable shards; restore resharding re-places them onto the current
    mesh, so restarts may change topology — elastic restart)
  - restore() accepts target shardings: arrays are device_put with the new
    sharding, which is what makes "resume on a different mesh" work
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # ml_dtypes (bfloat16/fp8) don't survive an npz round trip —
            # store as f32 (lossless upcast); restore() casts back via the
            # target tree's dtypes
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(ckpt_dir, step: int, tree: Any, *, keep: int = 3,
         process_index: Optional[int] = None, background: bool = False):
    """Atomic checkpoint write; returns path (or thread if background)."""
    if background:
        # snapshot to host memory synchronously, write asynchronously
        flat, _ = _flatten(tree)
        th = threading.Thread(
            target=_write, args=(ckpt_dir, step, flat, keep, process_index))
        th.start()
        return th
    flat, _ = _flatten(tree)
    return _write(ckpt_dir, step, flat, keep, process_index)


def _write(ckpt_dir, step, flat, keep, process_index):
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    pidx = process_index if process_index is not None else jax.process_index()
    name = f"step_{step:08d}.proc{pidx}.npz"
    with tempfile.NamedTemporaryFile(dir=d, suffix=".tmp", delete=False) as f:
        np.savez(f, **flat)
        tmp = f.name
    os.replace(tmp, d / name)
    (d / f"manifest_{step:08d}.json").write_text(json.dumps(
        {"step": step, "time": time.time(), "n_arrays": len(flat)}))
    _prune(d, keep)
    return str(d / name)


def _prune(d: Path, keep: int):
    steps = sorted({int(m.group(1)) for p in d.glob("step_*.npz")
                    if (m := re.match(r"step_(\d+)\.", p.name))})
    for s in steps[:-keep] if keep else []:
        for p in d.glob(f"step_{s:08d}.*"):
            p.unlink(missing_ok=True)
        (d / f"manifest_{s:08d}.json").unlink(missing_ok=True)


def latest_step(ckpt_dir) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted({int(m.group(1)) for p in d.glob("step_*.npz")
                    if (m := re.match(r"step_(\d+)\.", p.name))})
    return steps[-1] if steps else None


def restore(ckpt_dir, target_tree: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of target_tree. If `shardings` (a matching
    pytree of Sharding) is given, arrays are placed with those shardings —
    this is the elastic-restart reshard path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    pidx = jax.process_index()
    path = Path(ckpt_dir) / f"step_{step:08d}.proc{pidx}.npz"
    data = np.load(path)
    flat, treedef = _flatten(target_tree)
    leaves = []
    flat_target, _ = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_shard = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None else [None] * len(flat_target))
    for (kp, ref), shd in zip(flat_target, flat_shard):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = data[key]
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves), step
