"""Pure-JAX AdamW with large-model options (no optax dependency):

  - global-norm gradient clipping
  - decoupled weight decay
  - configurable optimizer-state dtype (bf16 states halve HBM — used by the
    1T-class config)
  - adafactor-style *factored second moment* for >=2D params (row+col
    statistics instead of a full tensor — O(n+m) vs O(n*m)), the standard
    trick for trillion-parameter optimizer state
  - linear-warmup + cosine decay schedule
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    state_dtype: str = "float32"
    factored: bool = False
    min_factored_size: int = 2 ** 16  # below this, keep the full 2nd moment


def for_model(cfg, **overrides) -> OptimizerConfig:
    return OptimizerConfig(
        state_dtype=cfg.opt_state_dtype,
        factored=cfg.factored_second_moment,
        **overrides,
    )


def schedule(opt: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(opt.warmup_steps, 1))
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return opt.lr * warm * (0.1 + 0.9 * cos)


def _is_factored(p, opt: OptimizerConfig) -> bool:
    return (opt.factored and p.ndim >= 2
            and p.shape[-1] * p.shape[-2] >= opt.min_factored_size)


def init_state(params, opt: OptimizerConfig):
    sdt = jnp.dtype(opt.state_dtype)

    def leaf(p):
        st = {"m": jnp.zeros(p.shape, sdt)}
        if _is_factored(p, opt):
            st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        else:
            st["v"] = jnp.zeros(p.shape, sdt)
        return st

    return {"mu": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, state, opt: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - opt.b1 ** step.astype(jnp.float32)
    bc2 = 1 - opt.b2 ** step.astype(jnp.float32)

    def leaf(p, g, st):
        g = g.astype(jnp.float32) * scale
        m = opt.b1 * st["m"].astype(jnp.float32) + (1 - opt.b1) * g
        if "vr" in st:
            g2 = jnp.square(g) + 1e-30
            vr = opt.b2 * st["vr"] + (1 - opt.b2) * g2.mean(-1)
            vc = opt.b2 * st["vc"] + (1 - opt.b2) * g2.mean(-2)
            # rank-1 reconstruction of the second moment
            denom = jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
            v = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            nst = {"m": m.astype(st["m"].dtype), "vr": vr, "vc": vc}
        else:
            v = opt.b2 * st["v"].astype(jnp.float32) + (1 - opt.b2) * jnp.square(g)
            nst = {"m": m.astype(st["m"].dtype), "v": v.astype(st["v"].dtype)}
            v = v  # full
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + opt.eps)
        if p.ndim >= 2:
            upd = upd + opt.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * upd
        return newp.astype(p.dtype), nst

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tdef.flatten_up_to(state["mu"])
    out = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
