"""Runtime sanitizer: TSan-style asserts over the simulator's accounting.

``REPRO_SANITIZE=1`` arms cheap invariant checks at the boundaries every
measurement flows through:

- `StoreCounters` fields are non-negative and monotone (outside `reset()`),
  and every write booking leaves ``pages_written == data_writes +
  journal_writes + snapshot_writes`` — the conservation spine, enforced
  live instead of only by after-the-fact property tests;
- the serving loops' background clock only moves forward and only by
  non-negative priced durations;
- every open-loop/fleet report satisfies ``offered == admitted + shed``
  and ``completed == admitted`` (nothing admitted vanishes, nothing shed
  is double-counted).

Disabled (the default) the hooks are a single falsy-global test, so the
fast path costs nothing; tests flip the switch with `set_enabled`.
A violation raises `SanitizeError` (an `AssertionError` subclass: pytest
and plain `python -O`-free runs both fail loudly).

Registered in README ("Running the tests"); rule catalog companion:
docs/contracts.md.
"""
from __future__ import annotations

import os

__all__ = ["SanitizeError", "enabled", "set_enabled", "check",
           "check_counters", "check_open_report", "check_attribution"]


class SanitizeError(AssertionError):
    """An accounting invariant the measurements depend on was violated."""


_ENABLED = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip the sanitizer (returns the previous state) — test hook, so a
    single process can exercise both armed and disarmed paths."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def check(cond: bool, msg: str) -> None:
    """Assert `cond` when the sanitizer is armed."""
    if _ENABLED and not cond:
        raise SanitizeError(msg)


def check_counters(counters) -> None:
    """Full-state check of one `StoreCounters`: non-negative fields and
    write conservation. Called at every `book_writes` boundary."""
    if not _ENABLED:
        return
    d = counters.as_dict()
    for name, value in d.items():
        if value < 0:
            raise SanitizeError(f"counter {name} is negative: {value}")
    total = d["data_writes"] + d["journal_writes"] + d["snapshot_writes"]
    if d["pages_written"] != total:
        raise SanitizeError(
            f"write conservation broken: pages_written="
            f"{d['pages_written']} != data+journal+snapshot={total} "
            f"({d['data_writes']}+{d['journal_writes']}"
            f"+{d['snapshot_writes']})")


def check_open_report(report) -> None:
    """Admission conservation on a finished serving report: every offered
    query was either admitted or shed, and everything admitted completed."""
    if not _ENABLED:
        return
    offered = int(report.offered)
    admitted = int(report.admitted)
    shed = int(report.shed)
    completed = int(report.completed)
    if offered != admitted + shed:
        raise SanitizeError(
            f"admission conservation broken: offered={offered} != "
            f"admitted={admitted} + shed={shed}")
    if completed != admitted:
        raise SanitizeError(
            f"admitted queries vanished: completed={completed} != "
            f"admitted={admitted}")


def check_attribution(queue_us, service_us, interference_us,
                      latency_us, tol_us: float = 1e-3) -> None:
    """Latency conservation on per-query phase arrays: each phase is
    non-negative and ``queue + service + interference == latency`` within
    ``tol_us`` — every microsecond of a reported latency is attributed,
    none is invented. Called before any open-loop/fleet report returns."""
    if not _ENABLED:
        return
    import numpy as np
    q = np.asarray(queue_us, dtype=np.float64)
    s = np.asarray(service_us, dtype=np.float64)
    i = np.asarray(interference_us, dtype=np.float64)
    lat = np.asarray(latency_us, dtype=np.float64)
    if not (q.shape == s.shape == i.shape == lat.shape):
        raise SanitizeError(
            f"attribution arrays disagree on shape: queue={q.shape} "
            f"service={s.shape} interference={i.shape} latency={lat.shape}")
    for name, arr in (("queue", q), ("service", s), ("interference", i)):
        if arr.size and float(arr.min()) < -tol_us:
            raise SanitizeError(
                f"negative {name} time: min={float(arr.min())}us")
    if q.size:
        resid = np.abs(q + s + i - lat)
        worst = int(np.argmax(resid))
        if float(resid[worst]) > tol_us:
            raise SanitizeError(
                f"latency attribution broken at query {worst}: "
                f"queue={q[worst]} + service={s[worst]} + "
                f"interference={i[worst]} != latency={lat[worst]} "
                f"(residual {float(resid[worst])}us)")
