"""Runtime sanitizer: TSan-style asserts over the simulator's accounting.

``REPRO_SANITIZE=1`` arms cheap invariant checks at the boundaries every
measurement flows through:

- `StoreCounters` fields are non-negative and monotone (outside `reset()`),
  and every write booking leaves ``pages_written == data_writes +
  journal_writes + snapshot_writes`` — the conservation spine, enforced
  live instead of only by after-the-fact property tests;
- the serving loops' background clock only moves forward and only by
  non-negative priced durations;
- every open-loop/fleet report satisfies ``offered == admitted + shed``
  and ``completed == admitted`` (nothing admitted vanishes, nothing shed
  is double-counted).

Disabled (the default) the hooks are a single falsy-global test, so the
fast path costs nothing; tests flip the switch with `set_enabled`.
A violation raises `SanitizeError` (an `AssertionError` subclass: pytest
and plain `python -O`-free runs both fail loudly).

Registered in README ("Running the tests"); rule catalog companion:
docs/contracts.md.
"""
from __future__ import annotations

import os

__all__ = ["SanitizeError", "enabled", "set_enabled", "check",
           "check_counters", "check_open_report"]


class SanitizeError(AssertionError):
    """An accounting invariant the measurements depend on was violated."""


_ENABLED = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip the sanitizer (returns the previous state) — test hook, so a
    single process can exercise both armed and disarmed paths."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def check(cond: bool, msg: str) -> None:
    """Assert `cond` when the sanitizer is armed."""
    if _ENABLED and not cond:
        raise SanitizeError(msg)


def check_counters(counters) -> None:
    """Full-state check of one `StoreCounters`: non-negative fields and
    write conservation. Called at every `book_writes` boundary."""
    if not _ENABLED:
        return
    d = counters.as_dict()
    for name, value in d.items():
        if value < 0:
            raise SanitizeError(f"counter {name} is negative: {value}")
    total = d["data_writes"] + d["journal_writes"] + d["snapshot_writes"]
    if d["pages_written"] != total:
        raise SanitizeError(
            f"write conservation broken: pages_written="
            f"{d['pages_written']} != data+journal+snapshot={total} "
            f"({d['data_writes']}+{d['journal_writes']}"
            f"+{d['snapshot_writes']})")


def check_open_report(report) -> None:
    """Admission conservation on a finished serving report: every offered
    query was either admitted or shed, and everything admitted completed."""
    if not _ENABLED:
        return
    offered = int(report.offered)
    admitted = int(report.admitted)
    shed = int(report.shed)
    completed = int(report.completed)
    if offered != admitted + shed:
        raise SanitizeError(
            f"admission conservation broken: offered={offered} != "
            f"admitted={admitted} + shed={shed}")
    if completed != admitted:
        raise SanitizeError(
            f"admitted queries vanished: completed={completed} != "
            f"admitted={admitted}")
