#!/usr/bin/env python
"""Fail on broken RELATIVE links in markdown files.

    python tools/check_links.py README.md ARCHITECTURE.md docs

Arguments are markdown files or directories (scanned recursively for
*.md). For every inline link or image `[text](target)` whose target is
not an absolute URL or a pure anchor, the target must exist on disk
relative to the file that references it (an optional `#fragment` suffix is
stripped; fragments themselves are not validated). Exit code 1 lists every
broken link. Used by the CI docs job and tests/test_docs.py.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links/images: [text](target) — skips reference-style and autolinks
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_md_files(args):
    for a in args:
        p = Path(a)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p
        else:
            raise SystemExit(f"not a markdown file or directory: {a}")


def check_file(md: Path) -> list:
    broken = []
    text = md.read_text(encoding="utf-8")
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            line = text[:m.start()].count("\n") + 1
            broken.append(f"{md}:{line}: broken link -> {target}")
    return broken


def main(argv) -> int:
    files = list(iter_md_files(argv or ["."]))
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    broken = [b for md in files for b in check_file(md)]
    for b in broken:
        print(b, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(broken)} broken relative links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
