"""reprolint core: findings, the rule registry, suppressions, and drivers.

A rule is a class with a ``check(tree, src)`` method yielding `Finding`s;
registering it is one decorator::

    @rule
    class R999Example(Rule):
        rule_id = "R999"
        name = "example"
        description = "what the invariant is"

        def check(self, tree, src):
            yield self.finding(node, "message")

Suppressions are comment-driven so they live next to the code they excuse:

- ``# reprolint: disable=R001`` (or ``disable=R001,R003``) on the flagged
  line silences those rules for that line only;
- ``# reprolint: disable-file=R001`` anywhere in a file silences the rule
  for the whole file (use sparingly — the catalog in docs/contracts.md asks
  every suppression to carry a justification in prose nearby).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

_DISABLE_LINE = re.compile(r"#\s*reprolint:\s*disable=([A-Z0-9,\s]+)")
_DISABLE_FILE = re.compile(r"#\s*reprolint:\s*disable-file=([A-Z0-9,\s]+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location."""

    rule_id: str
    path: str
    line: int
    col: int
    message: str

    def as_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule_id,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule_id} {self.message}"


class Rule:
    """Base class for reprolint rules.

    Subclasses set `rule_id` / `name` / `description` and implement
    `check(tree, src)`; `self.path` holds the file being linted (rules that
    only apply to a subtree — kernels, serving — gate on it).
    """

    rule_id: str = ""
    name: str = ""
    description: str = ""

    def __init__(self, path: str):
        self.path = path

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule_id=self.rule_id,
            path=self.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            message=message,
        )


_REGISTRY: Dict[str, type] = {}


def rule(cls: type) -> type:
    """Class decorator registering a Rule subclass under its rule_id."""
    rid = getattr(cls, "rule_id", "")
    if not rid:
        raise ValueError(f"{cls.__name__} has no rule_id")
    if rid in _REGISTRY:
        raise ValueError(f"duplicate rule id {rid}")
    _REGISTRY[rid] = cls
    return cls


def all_rules() -> Dict[str, type]:
    """rule_id -> Rule subclass, in registration order."""
    return dict(_REGISTRY)


@dataclass
class _Suppressions:
    file_wide: Set[str] = field(default_factory=set)
    by_line: Dict[int, Set[str]] = field(default_factory=dict)

    def active(self, rule_id: str, line: int) -> bool:
        if rule_id in self.file_wide:
            return True
        return rule_id in self.by_line.get(line, set())


def _parse_suppressions(src: str) -> _Suppressions:
    sup = _Suppressions()
    for lineno, text in enumerate(src.splitlines(), start=1):
        m = _DISABLE_FILE.search(text)
        if m:
            sup.file_wide.update(
                r.strip() for r in m.group(1).split(",") if r.strip())
            continue
        m = _DISABLE_LINE.search(text)
        if m:
            ids = {r.strip() for r in m.group(1).split(",") if r.strip()}
            sup.by_line.setdefault(lineno, set()).update(ids)
    return sup


def lint_source(
    src: str,
    path: str,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint one source string as if it lived at `path`.

    `rules` restricts the run to specific rule ids (default: all).
    Unparseable files yield a single synthetic E000 finding rather than
    crashing the run — a syntax error is itself a contract violation.
    """
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [Finding("E000", path, exc.lineno or 0, exc.offset or 0,
                        f"syntax error: {exc.msg}")]
    sup = _parse_suppressions(src)
    wanted = set(rules) if rules is not None else None
    out: List[Finding] = []
    for rid, cls in _REGISTRY.items():
        if wanted is not None and rid not in wanted:
            continue
        checker = cls(path)
        for f in checker.check(tree, src):
            if not sup.active(f.rule_id, f.line):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return out


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def lint_paths(
    paths: Sequence[str],
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint every .py file under `paths` (files or directories)."""
    out: List[Finding] = []
    for f in iter_python_files(paths):
        try:
            src = f.read_text()
        except OSError as exc:
            out.append(Finding("E001", str(f), 0, 0, f"unreadable: {exc}"))
            continue
        out.extend(lint_source(src, str(f), rules=rules))
    return out
