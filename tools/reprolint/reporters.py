"""Render reprolint findings as text (default) or JSON (for CI tooling)."""
from __future__ import annotations

import json
from collections import Counter
from typing import List

from tools.reprolint.core import Finding


def render_text(findings: List[Finding]) -> str:
    if not findings:
        return "reprolint: clean"
    lines = [f.render() for f in findings]
    by_rule = Counter(f.rule_id for f in findings)
    summary = ", ".join(f"{rid}: {n}" for rid, n in sorted(by_rule.items()))
    lines.append(f"reprolint: {len(findings)} finding(s) ({summary})")
    return "\n".join(lines)


def render_json(findings: List[Finding]) -> str:
    by_rule = Counter(f.rule_id for f in findings)
    doc = {
        "findings": [f.as_dict() for f in findings],
        "counts": dict(sorted(by_rule.items())),
        "total": len(findings),
    }
    return json.dumps(doc, indent=2)
