"""reprolint — AST contract checker for the repo's measurement invariants.

Every number this repo reports rests on a handful of conventions that
ordinary tests only probe after the fact: stores must forward reads and
writes down the conservation spine, mutations must hit the journal before
they touch state, device time may only be billed through the SSD model,
kernels must stay pure under tracing, report schemas must stay stable, and
RNGs must be seeded.  reprolint turns those conventions into machine-checked
rules (R001–R006, catalogued in docs/contracts.md) that run over the source
tree in CI:

    python -m tools.reprolint src tests benchmarks

Suppress a finding with a trailing ``# reprolint: disable=R001`` (comma
separated for several rules) on the flagged line, or exempt a whole file
with ``# reprolint: disable-file=R001`` on its own line.
"""
from tools.reprolint.core import (  # noqa: F401
    Finding,
    Rule,
    all_rules,
    lint_paths,
    lint_source,
    rule,
)
from tools.reprolint import rules  # noqa: F401  (registers R001–R006)

__all__ = [
    "Finding",
    "Rule",
    "all_rules",
    "lint_paths",
    "lint_source",
    "rule",
]
