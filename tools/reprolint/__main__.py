"""CLI: ``python -m tools.reprolint src tests benchmarks``.

Exit codes: 0 = clean, 1 = findings reported, 2 = usage error.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from tools.reprolint import rules  # noqa: F401  (registers R001–R006)
from tools.reprolint.core import all_rules, lint_paths
from tools.reprolint.reporters import render_json, render_text


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="contract checker for the repo's measurement "
                    "invariants (rule catalog: docs/contracts.md)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, cls in all_rules().items():
            print(f"{rid} {cls.name}: {cls.description}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (try: src tests benchmarks)",
              file=sys.stderr)
        return 2
    wanted = None
    if args.rules:
        wanted = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = sorted(set(wanted) - set(all_rules()))
        if unknown:
            print(f"error: unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    findings = lint_paths(args.paths, rules=wanted)
    render = render_json if args.format == "json" else render_text
    print(render(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
